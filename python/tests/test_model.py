"""L2 model graphs + AOT lowering checks."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def args_for(name):
    _, specs = model.ARTIFACTS[name]
    return [randn(*s.shape) for s in specs]


class TestLayers:
    def test_all_artifacts_execute(self):
        for name, (fn, _) in model.ARTIFACTS.items():
            out = fn(*args_for(name))
            assert isinstance(out, tuple), name
            assert all(np.isfinite(np.asarray(o)).all() for o in out), name

    def test_attention_layer_matches_ref(self):
        q, k, v = args_for("llama3_attention")
        (out,) = model.llama3_attention_layer(q, k, v)
        np.testing.assert_allclose(
            out, ref.attention_ref(q, k, v), rtol=1e-3, atol=1e-4
        )

    def test_moe_layer_matches_ref(self):
        x, we, rl = args_for("deepseek_moe")
        (out,) = model.deepseek_moe_layer(x, we, rl)
        np.testing.assert_allclose(out, ref.moe_ref(x, we, rl), rtol=1e-3, atol=1e-3)

    def test_conv_layer_matches_ref(self):
        x, w = args_for("flux_conv")
        (out,) = model.flux_conv_layer(x, w)
        np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-3)

    def test_mlp_layer_matches_ref(self):
        x, wg, wu, wd = args_for("llama4_mlp")
        (out,) = model.llama4_mlp_layer(x, wg, wu, wd)
        np.testing.assert_allclose(
            out, ref.mlp_ref(x, wg, wu, wd), rtol=1e-3, atol=1e-2
        )

    def test_e2e_block_matches_ref(self):
        args = args_for("llama3_block")
        # Gammas at 1; weights scaled like real initializations (~1/sqrt(d))
        # so activations stay O(1) and tolerances are meaningful.
        args[1] = jnp.ones_like(args[1])
        args[6] = jnp.ones_like(args[6])
        args = args[:2] + [w * 0.08 for w in args[2:6]] + [args[6]] + [
            w * 0.08 for w in args[7:]
        ]
        (out,) = model.llama3_block(*args)
        want = model.llama3_block_ref(*args)
        np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)

    def test_block_output_shape(self):
        args = args_for("llama3_block")
        (out,) = model.llama3_block(*args)
        assert out.shape == (model.E2E_SEQ, model.E2E_HIDDEN)


class TestAot:
    def test_lower_artifact_produces_hlo_text(self):
        text, entry = aot.lower_artifact("deepseek_moe")
        assert "HloModule" in text
        assert "ENTRY" in text
        assert len(entry["inputs"]) == 3
        assert entry["outputs"][0]["shape"] == [model.MOE_TOKENS, model.MOE_DOUT]

    def test_artifact_registry_consistent(self):
        for name, (fn, specs) in model.ARTIFACTS.items():
            shapes = jax.eval_shape(fn, *specs)
            assert isinstance(shapes, tuple), name
            assert len(shapes) >= 1, name

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
        reason="artifacts not built (run `make artifacts`)",
    )
    def test_manifest_matches_registry(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        with open(path) as f:
            manifest = json.load(f)
        for name in model.ARTIFACTS:
            assert name in manifest, name
            entry = manifest[name]
            assert os.path.exists(
                os.path.join(os.path.dirname(path), entry["file"])
            ), name
            _, specs = model.ARTIFACTS[name]
            assert len(entry["inputs"]) == len(specs)
