"""Kernel vs oracle — the core correctness signal of the build path.

Fixed-shape checks plus hypothesis sweeps over shapes and block sizes.
Everything runs interpret=True on CPU; tolerances absorb the float32
reassociation that tiled accumulation introduces.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, conv2d, matmul, mlp, moe, ref

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

class TestMatmul:
    def test_square(self):
        a, b = randn(64, 64), randn(64, 64)
        np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4)

    def test_rectangular(self):
        a, b = randn(16, 512), randn(512, 256)
        np.testing.assert_allclose(
            matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_small_blocks(self):
        a, b = randn(32, 48), randn(48, 24)
        np.testing.assert_allclose(
            matmul(a, b, bm=8, bn=8, bk=8), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-5
        )

    def test_block_larger_than_dim_clamps(self):
        a, b = randn(4, 8), randn(8, 4)
        np.testing.assert_allclose(
            matmul(a, b, bm=128, bn=128, bk=128), ref.matmul_ref(a, b), rtol=1e-5
        )

    def test_identity(self):
        a = randn(16, 16)
        eye = jnp.eye(16, dtype=jnp.float32)
        np.testing.assert_allclose(matmul(a, eye), a, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([4, 8, 16, 32]),
        n=st.sampled_from([4, 8, 24, 64]),
        k=st.sampled_from([4, 16, 48, 128]),
        bm=st.sampled_from([4, 8, 128]),
    )
    def test_hypothesis_shapes(self, m, n, k, bm):
        a, b = randn(m, k), randn(k, n)
        np.testing.assert_allclose(
            matmul(a, b, bm=bm), ref.matmul_ref(a, b), rtol=1e-3, atol=1e-4
        )


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

class TestAttention:
    def test_basic(self):
        q, k, v = randn(2, 64, 16), randn(2, 64, 16), randn(2, 64, 16)
        np.testing.assert_allclose(
            attention(q, k, v, bq=32, bk=32),
            ref.attention_ref(q, k, v),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_single_block(self):
        q, k, v = randn(1, 16, 8), randn(1, 16, 8), randn(1, 16, 8)
        np.testing.assert_allclose(
            attention(q, k, v), ref.attention_ref(q, k, v), rtol=1e-4, atol=1e-5
        )

    def test_many_heads(self):
        q, k, v = randn(8, 32, 16), randn(8, 32, 16), randn(8, 32, 16)
        np.testing.assert_allclose(
            attention(q, k, v, bq=16, bk=16),
            ref.attention_ref(q, k, v),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_softmax_rows_consistent(self):
        # Uniform V: attention output must equal V rows regardless of scores.
        q, k = randn(1, 32, 8), randn(1, 32, 8)
        v = jnp.ones((1, 32, 8), jnp.float32)
        out = attention(q, k, v, bq=8, bk=8)
        np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-4)

    def test_large_magnitudes_stable(self):
        # Online softmax must not overflow with large score magnitudes.
        q = randn(1, 32, 8) * 30.0
        k = randn(1, 32, 8) * 30.0
        v = randn(1, 32, 8)
        out = attention(q, k, v, bq=8, bk=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(
            out, ref.attention_ref(q, k, v), rtol=1e-3, atol=1e-4
        )

    @settings(max_examples=12, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([8, 16, 128]),
    )
    def test_hypothesis_shapes(self, h, s, d, bq):
        q, k, v = randn(h, s, d), randn(h, s, d), randn(h, s, d)
        np.testing.assert_allclose(
            attention(q, k, v, bq=bq, bk=bq),
            ref.attention_ref(q, k, v),
            rtol=1e-3,
            atol=1e-4,
        )

    def test_causal_matches_ref(self):
        q, k, v = randn(2, 64, 16), randn(2, 64, 16), randn(2, 64, 16)
        np.testing.assert_allclose(
            attention(q, k, v, bq=16, bk=16, causal=True),
            ref.causal_attention_ref(q, k, v),
            rtol=1e-3,
            atol=1e-4,
        )

    def test_causal_first_row_attends_only_itself(self):
        # Position 0 may only see key 0: output row 0 == v[0].
        q, k, v = randn(1, 32, 8), randn(1, 32, 8), randn(1, 32, 8)
        out = attention(q, k, v, bq=8, bk=8, causal=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-4, atol=1e-5)

    def test_causal_ignores_future_keys(self):
        # Perturbing future keys/values must not change earlier outputs.
        q, k, v = randn(1, 32, 8), randn(1, 32, 8), randn(1, 32, 8)
        base = attention(q, k, v, bq=8, bk=8, causal=True)
        k2 = k.at[:, 16:].set(randn(1, 16, 8))
        v2 = v.at[:, 16:].set(randn(1, 16, 8))
        pert = attention(q, k2, v2, bq=8, bk=8, causal=True)
        np.testing.assert_allclose(base[:, :16], pert[:, :16], rtol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        s=st.sampled_from([16, 32, 64]),
        bq=st.sampled_from([8, 16, 32]),
    )
    def test_causal_hypothesis(self, s, bq):
        q, k, v = randn(2, s, 8), randn(2, s, 8), randn(2, s, 8)
        np.testing.assert_allclose(
            attention(q, k, v, bq=bq, bk=bq, causal=True),
            ref.causal_attention_ref(q, k, v),
            rtol=1e-3,
            atol=1e-4,
        )


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

class TestConv:
    def test_basic(self):
        x, w = randn(8, 12, 12), randn(6, 8, 3, 3)
        np.testing.assert_allclose(
            conv2d(x, w, bc=4), ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-4
        )

    def test_1x1_kernel(self):
        x, w = randn(4, 8, 8), randn(4, 4, 1, 1)
        np.testing.assert_allclose(
            conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-5
        )

    def test_5x5_kernel(self):
        x, w = randn(2, 16, 16), randn(3, 2, 5, 5)
        np.testing.assert_allclose(
            conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-4
        )

    def test_channel_blocking_invariant(self):
        x, w = randn(16, 10, 10), randn(8, 16, 3, 3)
        full = conv2d(x, w, bc=16)
        blocked = conv2d(x, w, bc=4)
        np.testing.assert_allclose(full, blocked, rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        cin=st.sampled_from([2, 4, 8]),
        cout=st.sampled_from([2, 4, 6]),
        hw=st.sampled_from([8, 12, 16]),
        k=st.sampled_from([1, 3]),
    )
    def test_hypothesis_shapes(self, cin, cout, hw, k):
        x, w = randn(cin, hw, hw), randn(cout, cin, k, k)
        np.testing.assert_allclose(
            conv2d(x, w, bc=2), ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-4
        )


# --------------------------------------------------------------------------
# mlp
# --------------------------------------------------------------------------

class TestMlp:
    def test_basic(self):
        x = randn(16, 32)
        wg, wu, wd = randn(32, 64), randn(32, 64), randn(64, 32)
        np.testing.assert_allclose(
            mlp(x, wg, wu, wd, bf=16), ref.mlp_ref(x, wg, wu, wd), rtol=1e-3, atol=1e-4
        )

    def test_single_ffn_block(self):
        x = randn(8, 16)
        wg, wu, wd = randn(16, 16), randn(16, 16), randn(16, 8)
        np.testing.assert_allclose(
            mlp(x, wg, wu, wd), ref.mlp_ref(x, wg, wu, wd), rtol=1e-3, atol=1e-4
        )

    def test_blocking_invariant(self):
        x = randn(4, 24)
        wg, wu, wd = randn(24, 96), randn(24, 96), randn(96, 24)
        np.testing.assert_allclose(
            mlp(x, wg, wu, wd, bf=96),
            mlp(x, wg, wu, wd, bf=8),
            rtol=2e-3,
            atol=1e-3,
        )

    @settings(max_examples=10, deadline=None)
    @given(
        t=st.sampled_from([2, 8, 16]),
        din=st.sampled_from([8, 32]),
        ffn=st.sampled_from([16, 64, 96]),
        bf=st.sampled_from([8, 16, 256]),
    )
    def test_hypothesis_shapes(self, t, din, ffn, bf):
        x = randn(t, din)
        wg, wu, wd = randn(din, ffn), randn(din, ffn), randn(ffn, din)
        np.testing.assert_allclose(
            mlp(x, wg, wu, wd, bf=bf), ref.mlp_ref(x, wg, wu, wd), rtol=1e-3, atol=1e-4
        )


# --------------------------------------------------------------------------
# moe
# --------------------------------------------------------------------------

class TestMoe:
    def test_basic(self):
        x, we, rl = randn(16, 24), randn(4, 24, 32), randn(16, 4)
        np.testing.assert_allclose(
            moe(x, we, rl), ref.moe_ref(x, we, rl), rtol=1e-3, atol=1e-4
        )

    def test_single_expert(self):
        x, we, rl = randn(8, 16), randn(1, 16, 16), randn(8, 1)
        # One expert: MoE == plain matmul with that expert.
        np.testing.assert_allclose(
            moe(x, we, rl), ref.matmul_ref(x, we[0]), rtol=1e-4, atol=1e-5
        )

    def test_routing_exclusive(self):
        # Tokens hard-routed to expert 0 must be unaffected by expert 1.
        x = randn(4, 8)
        we = randn(2, 8, 8)
        rl = jnp.asarray([[10.0, -10.0]] * 4, jnp.float32)
        out = moe(x, we, rl)
        np.testing.assert_allclose(out, ref.matmul_ref(x, we[0]), rtol=1e-4, atol=1e-5)
        we2 = we.at[1].set(randn(8, 8))
        np.testing.assert_allclose(moe(x, we2, rl), out, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        t=st.sampled_from([2, 8, 16]),
        e=st.sampled_from([1, 2, 4, 8]),
        din=st.sampled_from([8, 24]),
        dout=st.sampled_from([8, 32]),
    )
    def test_hypothesis_shapes(self, t, e, din, dout):
        x, we, rl = randn(t, din), randn(e, din, dout), randn(t, e)
        np.testing.assert_allclose(
            moe(x, we, rl), ref.moe_ref(x, we, rl), rtol=1e-3, atol=1e-4
        )


# --------------------------------------------------------------------------
# degenerate inputs
# --------------------------------------------------------------------------

class TestEdgeCases:
    def test_zeros_propagate(self):
        z = jnp.zeros((8, 8), jnp.float32)
        np.testing.assert_array_equal(matmul(z, z), z)

    def test_matmul_shape_mismatch_raises(self):
        with pytest.raises(AssertionError):
            matmul(randn(4, 8), randn(4, 8))

    def test_attention_deterministic(self):
        q, k, v = randn(2, 16, 8), randn(2, 16, 8), randn(2, 16, 8)
        a = attention(q, k, v)
        b = attention(q, k, v)
        np.testing.assert_array_equal(a, b)
