#!/usr/bin/env python3
"""Cross-language mirror of the Rust structural fingerprints.

Reimplements `rust/src/tir/hash.rs` (StructHasher: FNV-1a-style feeds with
per-field tags and a splitmix64 avalanche tail) plus the exact feed
sequences of `db::fingerprint::workload_fingerprint` and
`db::fingerprint::shape_class`, over the five stock workloads of
`tir::workload`. Running it regenerates
`rust/tests/golden/fingerprints.json`, the golden file pinned by
`rust/tests/golden_fingerprints.rs` so database and transfer records stay
readable across refactors: if either implementation drifts, the Rust test
fails and points here.

Usage: python3 python/tools/golden_fingerprints.py [output.json]
"""

import json
import os
import sys

MASK = (1 << 64) - 1

# BufKind / ReduceOp discriminants (rust enum order).
INPUT, OUTPUT, INTERMEDIATE = 0, 1, 2
SUM = 0


class StructHasher:
    """Mirror of tir::hash::StructHasher."""

    def __init__(self):
        self.h = 0xCBF29CE484222325

    def feed(self, x):
        self.h ^= x & MASK
        self.h = (self.h * 0x100000001B3) & MASK

    def feed_i64(self, x):
        self.feed(x & MASK)

    def tag(self, t):
        self.feed(0x9E3779B97F4A7C15 ^ t)

    def finish(self):
        z = self.h
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)


def axis(a):
    """LinIdx::axis — one (axis, coeff=1) term, offset 0."""
    return (0, [(a, 1)])


def axis_sum(a, b):
    """LinIdx::axis_sum — (a,1) + (b,1), offset 0."""
    return (0, [(a, 1), (b, 1)])


def feed_linidx(h, idx):
    offset, terms = idx
    h.tag(10)
    h.feed_i64(offset)
    for ax, coeff in terms:
        h.feed(ax)
        h.feed_i64(coeff)


def feed_block_expr(h, e):
    kind = e[0]
    if kind == "load":
        _, buf, idx = e
        h.tag(20)
        h.feed(buf)
        for i in idx:
            feed_linidx(h, i)
    elif kind == "mul":
        _, a, b = e
        h.tag(24)
        feed_block_expr(h, a)
        feed_block_expr(h, b)
    else:
        raise ValueError(kind)


def feed_buffers(h, buffers):
    for kind, shape in buffers:
        h.feed(kind + 1)
        h.feed(len(shape))
        for d in shape:
            h.feed_i64(d)


def feed_stage_structure(h, stage):
    axes, out, out_idx, rhs, reduce = stage
    h.tag(2)
    for extent, is_reduction in axes:
        h.feed_i64(extent)
        h.feed((1 if is_reduction else 0) + 1)
    h.tag(3)
    h.feed(out)
    for idx in out_idx:
        feed_linidx(h, idx)
    feed_block_expr(h, rhs)
    h.feed(reduce + 1)


def workload_fingerprint(buffers, stages):
    h = StructHasher()
    h.tag(1)
    feed_buffers(h, buffers)
    for s in stages:
        feed_stage_structure(h, s)
    return h.finish()


def shape_class(buffers, stages):
    h = StructHasher()
    h.tag(7)
    for kind, shape in buffers:
        h.feed(kind + 1)
        h.feed(len(shape))
    for axes, out, out_idx, rhs, reduce in stages:
        h.tag(8)
        for _, is_reduction in axes:
            h.feed((1 if is_reduction else 0) + 1)
        h.tag(9)
        h.feed(out)
        for idx in out_idx:
            feed_linidx(h, idx)
        feed_block_expr(h, rhs)
        h.feed(reduce + 1)
    return h.finish()


# ---- tir::workload builders (structure only; names are never hashed) ----

def moe_matmul(tokens, out_dim, in_dim):
    buffers = [
        (INPUT, [tokens, in_dim]),
        (INPUT, [in_dim, out_dim]),
        (OUTPUT, [tokens, out_dim]),
    ]
    axes = [(tokens, False), (out_dim, False), (in_dim, True)]
    rhs = ("mul", ("load", 0, [axis(0), axis(2)]), ("load", 1, [axis(2), axis(1)]))
    stage = (axes, 2, [axis(0), axis(1)], rhs, SUM)
    return buffers, [stage]


def attention(heads, seq, dim):
    buffers = [
        (INPUT, [heads, seq, dim]),
        (INPUT, [heads, seq, dim]),
        (INPUT, [heads, seq, dim]),
        (INTERMEDIATE, [heads, seq, seq]),
        (OUTPUT, [heads, seq, dim]),
    ]
    axes1 = [(heads, False), (seq, False), (seq, False), (dim, True)]
    rhs1 = (
        "mul",
        ("load", 0, [axis(0), axis(1), axis(3)]),
        ("load", 1, [axis(0), axis(2), axis(3)]),
    )
    stage1 = (axes1, 3, [axis(0), axis(1), axis(2)], rhs1, SUM)
    axes2 = [(heads, False), (seq, False), (dim, False), (seq, True)]
    rhs2 = (
        "mul",
        ("load", 3, [axis(0), axis(1), axis(3)]),
        ("load", 2, [axis(0), axis(3), axis(2)]),
    )
    stage2 = (axes2, 4, [axis(0), axis(1), axis(2)], rhs2, SUM)
    return buffers, [stage1, stage2]


def conv2d(c_out, c_in, height, width, ksize):
    oh = height - ksize + 1
    ow = width - ksize + 1
    buffers = [
        (INPUT, [c_in, height, width]),
        (INPUT, [c_out, c_in, ksize, ksize]),
        (OUTPUT, [c_out, oh, ow]),
    ]
    axes = [
        (c_out, False),
        (oh, False),
        (ow, False),
        (c_in, True),
        (ksize, True),
        (ksize, True),
    ]
    rhs = (
        "mul",
        ("load", 0, [axis(3), axis_sum(1, 4), axis_sum(2, 5)]),
        ("load", 1, [axis(0), axis(3), axis(4), axis(5)]),
    )
    stage = (axes, 2, [axis(0), axis(1), axis(2)], rhs, SUM)
    return buffers, [stage]


WORKLOADS = {
    # name -> (production build, test build)
    "llama3_attention": (attention(32, 1024, 128), attention(2, 8, 4)),
    "deepseek_moe": (moe_matmul(16, 2048, 7168), moe_matmul(4, 6, 8)),
    "flux_attention": (attention(24, 1024, 128), attention(2, 6, 4)),
    "flux_conv": (conv2d(128, 128, 64, 64, 3), conv2d(4, 4, 6, 6, 3)),
    "llama4_mlp": (moe_matmul(16, 8192, 5120), moe_matmul(4, 8, 6)),
}


def main():
    out_path = (
        sys.argv[1]
        if len(sys.argv) > 1
        else os.path.join(
            os.path.dirname(__file__), "..", "..", "rust", "tests", "golden",
            "fingerprints.json",
        )
    )
    entries = []
    for name, ((buffers, stages), (tb, ts)) in sorted(WORKLOADS.items()):
        entries.append(
            {
                "workload": name,
                "workload_fp": f"{workload_fingerprint(buffers, stages):016x}",
                "shape_class": f"{shape_class(buffers, stages):016x}",
                "test_workload_fp": f"{workload_fingerprint(tb, ts):016x}",
                "test_shape_class": f"{shape_class(tb, ts):016x}",
            }
        )
    with open(out_path, "w") as f:
        json.dump(entries, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(out_path)}")
    for e in entries:
        print(
            f"{e['workload']:<18} fp {e['workload_fp']} class {e['shape_class']}"
        )


if __name__ == "__main__":
    main()
