"""AOT lowering: JAX -> HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME]

Outputs one `<name>.hlo.txt` per entry of `model.ARTIFACTS` plus a
`manifest.json` describing argument/output shapes, which the rust
`runtime::artifacts` module reads.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name):
    """Lower one registered artifact; returns (hlo_text, manifest_entry)."""
    fn, specs = model.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_shapes = [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in jax.eval_shape(fn, *specs)
    ]
    entry = {
        "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
        "outputs": out_shapes,
    }
    return text, entry


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="lower a single artifact")
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [args.only] if args.only else list(model.ARTIFACTS)
    manifest = {}
    for name in names:
        text, entry = lower_artifact(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = f"{name}.hlo.txt"
        manifest[name] = entry
        print(f"  wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when lowering a single artifact.
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote {manifest_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
