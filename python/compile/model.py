"""L2: the JAX layer graphs that the serving path executes.

Each function composes the L1 Pallas kernels into one of the paper's five
evaluation layers, plus a miniature end-to-end Llama-3-style transformer
block used by the serving example. `aot.py` lowers every entry of
`ARTIFACTS` to HLO text; the rust runtime (`rust/src/runtime/`) loads and
executes them — Python is never on the request path.

Artifact shapes are scaled-down versions of the production shapes (the
schedule search in rust uses the full shapes analytically; the PJRT
executables are the *numerically real* counterparts sized for fast CPU
execution — DESIGN.md §Substitutions).
"""

import jax
import jax.numpy as jnp

from .kernels import attention, conv2d, matmul, mlp, moe
from .kernels import ref


# --------------------------------------------------------------------------
# The five evaluation layers (kernel-backed).
# --------------------------------------------------------------------------

def llama3_attention_layer(q, k, v):
    """Llama-3-8B self-attention core: fused flash attention."""
    return (attention(q, k, v),)


def llama3_causal_attention_layer(q, k, v):
    """Llama-3 decode-path attention: causal mask fused into the kernel."""
    return (attention(q, k, v, causal=True),)


def deepseek_moe_layer(x, w_experts, router_logits):
    """DeepSeek-R1 top-1 routed MoE FFN."""
    return (moe(x, w_experts, router_logits),)


def flux_attention_layer(q, k, v):
    """FLUX DiT self-attention (same fused kernel, DiT shapes)."""
    return (attention(q, k, v),)


def flux_conv_layer(x, w):
    """FLUX convolution block: implicit-GEMM conv2d."""
    return (conv2d(x, w),)


def llama4_mlp_layer(x, w_gate, w_up, w_down):
    """Llama-4-Scout gated MLP."""
    return (mlp(x, w_gate, w_up, w_down),)


def dense_layer(x, w):
    """Dense projection used by the e2e block (MXU-tiled matmul)."""
    return (matmul(x, w),)


# --------------------------------------------------------------------------
# Miniature end-to-end Llama-3-style transformer block (serving example).
# --------------------------------------------------------------------------

HEAD_DIM = 32


def llama3_block(x, gamma1, wq, wk, wv, wo, gamma2, w_gate, w_up, w_down):
    """One pre-norm transformer block over [seq, hidden] activations.

    heads = hidden // HEAD_DIM. All matmuls go through the L1 kernels;
    norms/residuals are cheap jnp glue.
    """
    seq, hidden = x.shape
    heads = hidden // HEAD_DIM

    h = ref.rmsnorm_ref(x, gamma1)
    q = matmul(h, wq).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    k = matmul(h, wk).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    v = matmul(h, wv).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    attn = attention(q, k, v)  # [heads, seq, HEAD_DIM]
    attn = attn.transpose(1, 0, 2).reshape(seq, hidden)
    x = x + matmul(attn, wo)

    h2 = ref.rmsnorm_ref(x, gamma2)
    x = x + mlp(h2, w_gate, w_up, w_down)
    return (x,)


def llama3_block_ref(x, gamma1, wq, wk, wv, wo, gamma2, w_gate, w_up, w_down):
    """Pure-jnp oracle of `llama3_block` (kernels replaced with refs)."""
    seq, hidden = x.shape
    heads = hidden // HEAD_DIM
    h = ref.rmsnorm_ref(x, gamma1)
    q = (h @ wq).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    k = (h @ wk).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    v = (h @ wv).reshape(seq, heads, HEAD_DIM).transpose(1, 0, 2)
    attn = ref.attention_ref(q, k, v).transpose(1, 0, 2).reshape(seq, hidden)
    x = x + attn @ wo
    h2 = ref.rmsnorm_ref(x, gamma2)
    return x + ref.mlp_ref(h2, w_gate, w_up, w_down)


# --------------------------------------------------------------------------
# AOT artifact registry: name -> (function, example argument specs).
# --------------------------------------------------------------------------

def _spec(*shapes):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


# Scaled serving shapes.
ATTN_SHAPE = (4, 128, 64)
MOE_TOKENS, MOE_EXPERTS, MOE_DIN, MOE_DOUT = 16, 4, 512, 256
CONV_CIN, CONV_COUT, CONV_H, CONV_K = 32, 32, 34, 3
MLP_TOKENS, MLP_DIN, MLP_FFN, MLP_DOUT = 16, 256, 688, 256
E2E_SEQ, E2E_HIDDEN, E2E_FFN = 64, 128, 352

ARTIFACTS = {
    "llama3_attention": (
        llama3_attention_layer,
        _spec(ATTN_SHAPE, ATTN_SHAPE, ATTN_SHAPE),
    ),
    "llama3_causal_attention": (
        llama3_causal_attention_layer,
        _spec(ATTN_SHAPE, ATTN_SHAPE, ATTN_SHAPE),
    ),
    "deepseek_moe": (
        deepseek_moe_layer,
        _spec(
            (MOE_TOKENS, MOE_DIN),
            (MOE_EXPERTS, MOE_DIN, MOE_DOUT),
            (MOE_TOKENS, MOE_EXPERTS),
        ),
    ),
    "flux_attention": (
        flux_attention_layer,
        _spec((8, 64, 64), (8, 64, 64), (8, 64, 64)),
    ),
    "flux_conv": (
        flux_conv_layer,
        _spec((CONV_CIN, CONV_H, CONV_H), (CONV_COUT, CONV_CIN, CONV_K, CONV_K)),
    ),
    "llama4_mlp": (
        llama4_mlp_layer,
        _spec(
            (MLP_TOKENS, MLP_DIN),
            (MLP_DIN, MLP_FFN),
            (MLP_DIN, MLP_FFN),
            (MLP_FFN, MLP_DOUT),
        ),
    ),
    "llama3_block": (
        llama3_block,
        _spec(
            (E2E_SEQ, E2E_HIDDEN),
            (E2E_HIDDEN,),
            (E2E_HIDDEN, E2E_HIDDEN),
            (E2E_HIDDEN, E2E_HIDDEN),
            (E2E_HIDDEN, E2E_HIDDEN),
            (E2E_HIDDEN, E2E_HIDDEN),
            (E2E_HIDDEN,),
            (E2E_HIDDEN, E2E_FFN),
            (E2E_HIDDEN, E2E_FFN),
            (E2E_FFN, E2E_HIDDEN),
        ),
    ),
}
