"""L1 Pallas kernel: fused gated (SwiGLU) MLP.

The Llama-4-Scout MLP layer: out = (silu(x @ Wg) * (x @ Wu)) @ Wd.
Fusing gate/up/activation into one kernel avoids materializing the two
[tokens, ffn] intermediates in HBM; the ffn dimension streams through the
grid while the token block stays VMEM-resident.

Grid: (ffn_blocks,) — each step computes a [tokens, bf] slice of the gated
activation and immediately contracts it with the matching Wd rows,
accumulating the [tokens, d_out] result in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One ffn-block step of the fused gated MLP."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]        # [t, d_in]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)  # [t, bf]
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)  # [t, bf]
    act = g * (1.0 / (1.0 + jnp.exp(-g))) * u                         # silu(g)*u
    o_ref[...] += jnp.dot(act, wd_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bf",))
def mlp(x, w_gate, w_up, w_down, bf=256):
    """Fused SwiGLU MLP (f32).

    x: [tokens, d_in]; w_gate/w_up: [d_in, ffn]; w_down: [ffn, d_out].
    VMEM per step = t*d_in + 2*d_in*bf + bf*d_out + t*d_out floats.
    """
    from .matmul import pick_tile

    t, d_in = x.shape
    d_in2, ffn = w_gate.shape
    ffn2, d_out = w_down.shape
    assert d_in == d_in2 and ffn == ffn2 and w_up.shape == w_gate.shape
    bf = pick_tile(ffn, bf)
    grid = (ffn // bf,)

    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d_in), lambda f: (0, 0)),
            pl.BlockSpec((d_in, bf), lambda f: (0, f)),
            pl.BlockSpec((d_in, bf), lambda f: (0, f)),
            pl.BlockSpec((bf, d_out), lambda f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((t, d_out), lambda f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, w_gate, w_up, w_down)
