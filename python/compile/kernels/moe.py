"""L1 Pallas kernel: top-1 routed mixture-of-experts FFN.

The DeepSeek-R1 MoE layer. Routing (argmax over router logits) is cheap
and stays in plain jnp; the expensive part — every token through its
expert's weight matrix — runs as a Pallas kernel that streams expert
blocks through VMEM and masks tokens by their route, so the dense compute
is MXU matmuls with a per-expert one-hot mask (the standard dense-MoE
formulation for small expert counts).

Grid: (experts,) — each step computes X @ W[e] for the full token block
and accumulates the masked contribution.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_kernel(x_ref, w_ref, mask_ref, o_ref):
    """One expert step: o += mask[:, e] * (x @ W[e])."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]          # [t, d_in]
    w = w_ref[0]            # [d_in, d_out]
    mask = mask_ref[...]    # [t, 1]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)  # [t, d_out]
    o_ref[...] += y * mask


@jax.jit
def moe(x, w_experts, router_logits):
    """Top-1 routed MoE (f32).

    x: [tokens, d_in]; w_experts: [E, d_in, d_out];
    router_logits: [tokens, E] -> [tokens, d_out].
    VMEM per step = t*d_in + d_in*d_out + t + t*d_out floats; expert
    matrices stream one at a time.
    """
    t, d_in = x.shape
    n_exp, d_in2, d_out = w_experts.shape
    assert d_in == d_in2
    route = jnp.argmax(router_logits, axis=-1)                    # [t]
    onehot = jax.nn.one_hot(route, n_exp, dtype=x.dtype)          # [t, E]

    return pl.pallas_call(
        _moe_kernel,
        grid=(n_exp,),
        in_specs=[
            pl.BlockSpec((t, d_in), lambda e: (0, 0)),
            pl.BlockSpec((1, d_in, d_out), lambda e: (e, 0, 0)),
            pl.BlockSpec((t, 1), lambda e: (0, e)),
        ],
        out_specs=pl.BlockSpec((t, d_out), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, w_experts, onehot)
