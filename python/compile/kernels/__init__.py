"""L1 Pallas kernels (build-time only; never imported at runtime).

Each kernel pairs with a pure-jnp oracle in `ref.py`; pytest enforces the
match. All kernels run `interpret=True` so their HLO executes on any PJRT
backend, including the rust CPU client.
"""

from .attention import attention
from .conv import conv2d
from .matmul import matmul
from .mlp import mlp
from .moe import moe
from . import ref

__all__ = ["attention", "conv2d", "matmul", "mlp", "moe", "ref"]
