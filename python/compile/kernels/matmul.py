"""L1 Pallas kernel: MXU-tiled matmul.

The workhorse of the MoE expert layer, the dense projections and the MLP.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tunes CPU
cache tiling; on TPU the same insight maps to HBM->VMEM blocking expressed
with BlockSpecs. Tiles default to the 128x128 MXU shape, with the K
reduction streamed through the grid's innermost dimension so each (i, j)
output tile accumulates in VMEM.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
path serializes and the rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i, j] += A[i, k] @ B[k, j].

    The output BlockSpec maps every k step to the same (i, j) tile, so the
    tile stays resident in VMEM and accumulates across the K stream.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def pick_tile(dim, target):
    """Largest divisor of `dim` <= target, preferring MXU-aligned sizes."""
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=128, bn=128, bk=128):
    """Tiled matmul: a [M, K] @ b [K, N] -> [M, N] (f32).

    Block sizes clamp to divisors of the problem shape; defaults target the
    MXU. VMEM per grid step = (bm*bk + bk*bn + bm*bn) * 4 bytes
    (192 KiB at the 128 defaults — comfortably inside a TPU core's VMEM).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    bm = pick_tile(m, bm)
    bn = pick_tile(n, bn)
    bk = pick_tile(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
