"""L1 Pallas kernel: flash-attention-style fused attention.

One Q block is held in VMEM while K/V blocks stream through the grid's
innermost dimension; softmax is computed *online* (running max + running
sum), so the [seq, seq] score matrix is never materialized in HBM — the
TPU restatement of the paper's cache-blocking insight for attention
(DESIGN.md §Hardware-Adaptation).

Grid: (heads, q_blocks, kv_blocks); kv is the reduction stream. Running
statistics (m, l) and the output accumulator live in the output refs,
which map to the same block for every kv step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, kv_steps, causal, bq, bk
):
    """One (h, qi, kj) step of online-softmax attention."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [bq, d]
    k = k_ref[0]  # [bk, d]
    v = v_ref[0]  # [bk, d]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if causal:
        # Global row/col positions of this tile; mask future keys.
        row = pl.program_id(1) * bq + jnp.arange(bq)[:, None]
        col = pl.program_id(2) * bk + jnp.arange(bk)[None, :]
        s = jnp.where(col > row, NEG_INF, s)

    m_prev = m_ref[0]                                   # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))         # [bq]
    correction = jnp.exp(m_prev - m_cur)                # [bq]
    p = jnp.exp(s - m_cur[:, None])                     # [bq, bk]

    l_ref[0] = l_ref[0] * correction + p.sum(axis=-1)
    o_ref[0] = o_ref[0] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[0] = m_cur

    # Final kv step: normalize by the accumulated softmax denominator.
    @pl.when(pl.program_id(2) == kv_steps - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / l_ref[0][:, None]


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal"))
def attention(q, k, v, bq=128, bk=128, causal=False):
    """Fused multi-head attention, f32.

    q, k, v: [heads, seq, dim] -> [heads, seq, dim]. `causal=True` applies
    the decoder mask inside the kernel (the serving decode path), still
    without materializing the [seq, seq] score matrix.
    VMEM per step = (bq + 2*bk) * dim + bq*dim + 2*bq floats — e.g.
    ~260 KiB at bq=bk=128, dim=128.
    """
    from .matmul import pick_tile

    h, sq, d = q.shape
    _, sk, _ = k.shape
    bq = pick_tile(sq, bq)
    bk = pick_tile(sk, bk)
    kv_steps = sk // bk
    scale = 1.0 / (d ** 0.5)
    grid = (h, sq // bq, kv_steps)

    out, _m, _l = pl.pallas_call(
        functools.partial(
            _attention_kernel,
            scale=scale, kv_steps=kv_steps, causal=causal, bq=bq, bk=bk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
            pl.BlockSpec((1, bq), lambda hh, qi, kj: (hh, qi)),
            pl.BlockSpec((1, bq), lambda hh, qi, kj: (hh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((h, sq), jnp.float32),   # running max
            jax.ShapeDtypeStruct((h, sq), jnp.float32),   # running sum
        ],
        interpret=True,
    )(q, k, v)
    return out
