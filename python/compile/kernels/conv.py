"""L1 Pallas kernel: 2-D convolution as implicit GEMM.

The FLUX convolution layer. Rather than porting a CPU register-blocked
direct convolution, the TPU idiom is implicit GEMM: each (kh, kw) tap is a
[c_in, oh*ow] x [c_out, c_in] matmul on a shifted view of the input, which
keeps the MXU busy and lets BlockSpecs stream channel blocks through VMEM
(DESIGN.md §Hardware-Adaptation).

Grid: (kh, kw, c_in_blocks) — all reduction dimensions; the full output
accumulates in VMEM across the grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, oh, ow, ksize):
    """One (kh, kw, ci-block) step: o += W[:, ci, kh, kw] @ X[ci, sh:, sw:]."""
    kh = pl.program_id(0)
    kw = pl.program_id(1)

    @pl.when((kh == 0) & (kw == 0) & (pl.program_id(2) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bc, h, w]
    w = w_ref[...]  # [c_out, bc, ksize, ksize]
    # Shifted valid window for this tap.
    patch = jax.lax.dynamic_slice(
        x, (0, kh, kw), (x.shape[0], oh, ow)
    )  # [bc, oh, ow]
    tap = jax.lax.dynamic_slice(
        w, (0, 0, kh, kw), (w.shape[0], w.shape[1], 1, 1)
    )[:, :, 0, 0]  # [c_out, bc]
    contrib = jnp.dot(
        tap, patch.reshape(x.shape[0], oh * ow),
        preferred_element_type=jnp.float32,
    )  # [c_out, oh*ow]
    o_ref[...] += contrib.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("bc",))
def conv2d(x, w, bc=32):
    """2-D convolution, stride 1, valid padding (f32).

    x: [c_in, h, w]; w: [c_out, c_in, kh, kw] -> [c_out, oh, ow].
    VMEM per step = bc*h*w + c_out*bc*k*k + c_out*oh*ow floats.
    """
    from .matmul import pick_tile

    c_in, h, wdt = x.shape
    c_out, c_in2, ksize, ksize2 = w.shape
    assert c_in == c_in2 and ksize == ksize2
    oh = h - ksize + 1
    ow = wdt - ksize + 1
    bc = pick_tile(c_in, bc)
    grid = (ksize, ksize, c_in // bc)

    return pl.pallas_call(
        functools.partial(_conv_kernel, oh=oh, ow=ow, ksize=ksize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, h, wdt), lambda kh, kw, ci: (ci, 0, 0)),
            pl.BlockSpec(
                (c_out, bc, ksize, ksize), lambda kh, kw, ci: (0, ci, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((c_out, oh, ow), lambda kh, kw, ci: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out, oh, ow), jnp.float32),
        interpret=True,
    )(x, w)
