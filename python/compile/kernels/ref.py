"""Pure-jnp oracles for the Pallas kernels.

Every L1 kernel in this package has a reference implementation here built
only from `jnp`/`lax` primitives. pytest asserts `kernel(x) ~= ref(x)` —
the core correctness signal of the build path (the AOT artifacts embed the
kernels, so kernel==ref implies artifact==ref).
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Multi-head attention: softmax(q @ k^T * scale) @ v.

    Shapes: q, k, v: [heads, seq, dim] -> out [heads, seq, dim].
    """
    if scale is None:
        scale = (1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype)))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def causal_attention_ref(q, k, v, scale=None):
    """Causal (decoder) attention: position i attends to keys j <= i."""
    if scale is None:
        scale = (1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype)))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    sq, sk = scores.shape[-2], scores.shape[-1]
    mask = jnp.arange(sk)[None, :] > jnp.arange(sq)[:, None]
    scores = jnp.where(mask[None], -1e30, scores)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def matmul_ref(a, b):
    """Plain f32 matmul oracle for the expert/dense kernels."""
    return a @ b


def moe_ref(x, w_experts, router_logits):
    """Top-1 routed mixture-of-experts layer (dense one-hot oracle).

    x: [tokens, d_in]; w_experts: [n_experts, d_in, d_out];
    router_logits: [tokens, n_experts].
    """
    route = jnp.argmax(router_logits, axis=-1)                  # [tokens]
    onehot = jnp.eye(w_experts.shape[0], dtype=x.dtype)[route]  # [tokens, E]
    per_expert = jnp.einsum("td,edf->tef", x, w_experts)        # [tokens, E, f]
    return jnp.einsum("tef,te->tf", per_expert, onehot)


def conv2d_ref(x, w):
    """Direct 2-D convolution, stride 1, valid padding.

    x: [c_in, h, w]; w: [c_out, c_in, kh, kw] -> [c_out, oh, ow].
    """
    out = jax.lax.conv_general_dilated(
        x[None], w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def mlp_ref(x, w_gate, w_up, w_down):
    """Gated SwiGLU MLP: (silu(x@Wg) * (x@Wu)) @ Wd."""
    g = x @ w_gate
    u = x @ w_up
    silu = g * (1.0 / (1.0 + jnp.exp(-g)))
    return (silu * u) @ w_down


def rmsnorm_ref(x, gamma, eps=1e-6):
    """RMSNorm over the last axis."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * gamma / jnp.sqrt(ms + eps)
