//! Markdown table rendering for the experiment reports.

/// Simple markdown table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a speedup like the paper: `5.0x`.
pub fn x(v: f64) -> String {
    format!("{v:.1}x")
}

/// Format a speedup with 2 decimals (Table 3/4/5/6 style).
pub fn x2(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a sample count.
pub fn n(v: f64) -> String {
    format!("{}", v.round() as i64)
}

/// Format USD.
pub fn usd(v: f64) -> String {
    format!("${v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Table 1", &["workload", "speedup"]);
        t.row(vec!["moe".into(), x(5.02)]);
        let md = t.to_markdown();
        assert!(md.contains("### Table 1"));
        assert!(md.contains("| workload | speedup |"));
        assert!(md.contains("| moe | 5.0x |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn formatters() {
        assert_eq!(x(5.04), "5.0x");
        assert_eq!(x2(7.081), "7.08");
        assert_eq!(n(599.7), "600");
        assert_eq!(usd(0.894), "$0.89");
    }
}
