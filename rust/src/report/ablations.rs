//! Ablation regenerators:
//! - Table 4 / Fig. 4(a): LLM choice,
//! - Table 5 / Fig. 4(b): historical trace depth,
//! - Table 6: MCTS branching factor.
//!
//! All on the Intel Core i9 ablation environment, reporting best speedup at
//! the paper's sample checkpoints.

use crate::coordinator::{run_session, Strategy, TuneConfig};
use crate::reasoning::ModelProfile;
use crate::tir::workload::WorkloadId;
use crate::util::json::{arr, num, s, Json};

use super::scale::Scale;
use super::table::{x2, Table};

pub struct Ablation {
    pub markdown: String,
    pub json: Json,
}

/// The four benchmarks the appendix ablations cover.
const ABLATION_WORKLOADS: [WorkloadId; 4] = [
    WorkloadId::Llama3Attention,
    WorkloadId::DeepSeekMoe,
    WorkloadId::FluxAttention,
    WorkloadId::FluxConv,
];

fn curve(cfg: &TuneConfig, checkpoints: &[usize]) -> Vec<f64> {
    let session = run_session(cfg).expect("tuning session");
    checkpoints
        .iter()
        .map(|&c| session.mean_speedup_at(c))
        .collect()
}

fn header(checkpoints: &[usize], label: &str) -> Vec<String> {
    std::iter::once(label.to_string())
        .chain(checkpoints.iter().map(|c| c.to_string()))
        .collect()
}

/// Table 4: each LLM profile as the proposal engine.
pub fn table4(scale: Scale, seed: u64) -> Ablation {
    let checkpoints = scale.checkpoints();
    let budget = *checkpoints.last().unwrap();
    let mut md = String::from("## Table 4 / Figure 4(a) — LLM choice ablation (Intel Core i9)\n\n");
    let mut json = Json::obj();
    for w in ABLATION_WORKLOADS {
        let hdr = header(&checkpoints, "model");
        let mut t = Table::new(
            w.display(),
            &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let mut wjson = Json::obj();
        for model in ModelProfile::all() {
            let cfg = TuneConfig {
                strategy: Strategy::LlmMcts,
                workload: w.name().to_string(),
                platform: "core_i9".to_string(),
                budget,
                repeats: scale.repeats(),
                seed,
                model: model.name.to_string(),
                ..Default::default()
            };
            let speeds = curve(&cfg, &checkpoints);
            let mut row = vec![model.display.to_string()];
            row.extend(speeds.iter().map(|&v| x2(v)));
            t.row(row);
            wjson.set(model.name, arr(speeds.into_iter().map(num).collect()));
        }
        md.push_str(&t.to_markdown());
        md.push('\n');
        json.set(w.name(), wjson);
    }
    wrap("table4", md, json, &checkpoints)
}

/// Table 5: historical trace depth (parent+gp vs parent+gp+ggp).
pub fn table5(scale: Scale, seed: u64) -> Ablation {
    let checkpoints = scale.checkpoints();
    let budget = *checkpoints.last().unwrap();
    let mut md =
        String::from("## Table 5 / Figure 4(b) — historical trace depth ablation (Intel Core i9)\n\n");
    let mut json = Json::obj();
    for w in ABLATION_WORKLOADS {
        let hdr = header(&checkpoints, "context");
        let mut t = Table::new(
            w.display(),
            &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let mut wjson = Json::obj();
        for (label, depth) in [
            ("Parent + Grandparent", 2usize),
            ("Parent + Grandparent + Great-Grandparent", 3usize),
        ] {
            let cfg = TuneConfig {
                strategy: Strategy::LlmMcts,
                workload: w.name().to_string(),
                platform: "core_i9".to_string(),
                budget,
                repeats: scale.repeats(),
                seed,
                history_depth: depth,
                ..Default::default()
            };
            let speeds = curve(&cfg, &checkpoints);
            let mut row = vec![label.to_string()];
            row.extend(speeds.iter().map(|&v| x2(v)));
            t.row(row);
            wjson.set(
                &format!("depth{depth}"),
                arr(speeds.into_iter().map(num).collect()),
            );
        }
        md.push_str(&t.to_markdown());
        md.push('\n');
        json.set(w.name(), wjson);
    }
    wrap("table5", md, json, &checkpoints)
}

/// Table 6: MCTS branching factor B = 2 vs B = 4.
pub fn table6(scale: Scale, seed: u64) -> Ablation {
    let checkpoints = scale.checkpoints();
    let budget = *checkpoints.last().unwrap();
    let mut md = String::from("## Table 6 — MCTS branching factor ablation (Intel Core i9)\n\n");
    let mut json = Json::obj();
    for w in ABLATION_WORKLOADS {
        let hdr = header(&checkpoints, "branching");
        let mut t = Table::new(
            w.display(),
            &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let mut wjson = Json::obj();
        for b in [2usize, 4usize] {
            let cfg = TuneConfig {
                strategy: Strategy::LlmMcts,
                workload: w.name().to_string(),
                platform: "core_i9".to_string(),
                budget,
                repeats: scale.repeats(),
                seed,
                branching: b,
                ..Default::default()
            };
            let speeds = curve(&cfg, &checkpoints);
            let mut row = vec![format!("B = {b}")];
            row.extend(speeds.iter().map(|&v| x2(v)));
            t.row(row);
            wjson.set(&format!("b{b}"), arr(speeds.into_iter().map(num).collect()));
        }
        md.push_str(&t.to_markdown());
        md.push('\n');
        json.set(w.name(), wjson);
    }
    wrap("table6", md, json, &checkpoints)
}

fn wrap(name: &str, md: String, series: Json, checkpoints: &[usize]) -> Ablation {
    let mut root = Json::obj();
    root.set("experiment", s(name));
    root.set(
        "checkpoints",
        arr(checkpoints.iter().map(|&c| num(c as f64)).collect()),
    );
    root.set("series", series);
    Ablation { markdown: md, json: root }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_smoke_runs_both_depths() {
        let a = table5(Scale::Smoke, 2);
        assert!(a.markdown.contains("Great-Grandparent"));
        let moe = a.json.get("series").unwrap().get("deepseek_moe").unwrap();
        assert!(moe.get("depth2").is_some());
        assert!(moe.get("depth3").is_some());
    }

    #[test]
    fn table6_smoke_runs_both_branchings() {
        let a = table6(Scale::Smoke, 2);
        assert!(a.markdown.contains("B = 2"));
        assert!(a.markdown.contains("B = 4"));
    }
}
