//! Figure 3 / Table 3: speedup-vs-samples convergence for the three
//! methods on the five kernels (Intel Core i9 ablation environment).

use crate::coordinator::{run_session, Strategy, TuneConfig};
use crate::tir::workload::WorkloadId;
use crate::util::json::{arr, num, s, Json};

use super::scale::Scale;
use super::table::{x2, Table};

pub struct Figure3 {
    pub markdown: String,
    pub json: Json,
}

/// Regenerate Figure 3 / Table 3.
pub fn run(scale: Scale, seed: u64) -> Figure3 {
    let checkpoints = scale.checkpoints();
    let strategies = [Strategy::Evolutionary, Strategy::Mcts, Strategy::LlmMcts];
    let mut md = String::from(
        "## Figure 3 / Table 3 — speedup over pre-optimized code vs evaluated proposals (Intel Core i9)\n\n",
    );
    let mut json = Json::obj();

    for w in WorkloadId::ALL {
        let mut t = Table::new(
            w.display(),
            &std::iter::once("method".to_string())
                .chain(checkpoints.iter().map(|c| c.to_string()))
                .collect::<Vec<_>>()
                .iter()
                .map(|s| s.as_str())
                .collect::<Vec<_>>(),
        );
        let mut wjson = Json::obj();
        for strat in strategies {
            let cfg = TuneConfig {
                strategy: strat,
                workload: w.name().to_string(),
                platform: "core_i9".to_string(),
                budget: if strat == Strategy::Evolutionary {
                    scale.es_budget()
                } else {
                    scale.rc_budget().max(*checkpoints.last().unwrap())
                },
                repeats: scale.repeats(),
                seed,
                ..Default::default()
            };
            let session = run_session(&cfg).expect("tuning session");
            let speeds: Vec<f64> = checkpoints
                .iter()
                .map(|&c| session.mean_speedup_at(c))
                .collect();
            let mut row = vec![strat.display().to_string()];
            row.extend(speeds.iter().map(|&v| x2(v)));
            t.row(row);
            wjson.set(
                strat.name(),
                arr(speeds.into_iter().map(num).collect()),
            );
        }
        md.push_str(&t.to_markdown());
        md.push('\n');
        json.set(w.name(), wjson);
    }
    let mut root = Json::obj();
    root.set("experiment", s("figure3"));
    root.set(
        "checkpoints",
        arr(checkpoints.iter().map(|&c| num(c as f64)).collect()),
    );
    root.set("series", json);
    Figure3 { markdown: md, json: root }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scale_produces_all_series() {
        let f = run(Scale::Smoke, 1);
        assert!(f.markdown.contains("DeepSeek-R1 MoE Layer"));
        assert!(f.markdown.contains("REASONING COMPILER"));
        assert!(f.markdown.contains("Evolutionary Search"));
        let series = f.json.get("series").unwrap();
        for w in WorkloadId::ALL {
            let wj = series.get(w.name()).unwrap();
            assert_eq!(wj.get("llm_mcts").unwrap().as_arr().unwrap().len(), 3);
        }
    }
}
