//! Experiment scale presets.
//!
//! Every regenerator runs at one of three scales: `Smoke` (CI-fast),
//! `Default` (minutes — the `cargo bench` setting), `Full` (the paper's
//! budgets and 20 repeats — what EXPERIMENTS.md records).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Full,
}

impl Scale {
    pub fn from_name(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "full" | "paper" => Some(Scale::Full),
            _ => None,
        }
    }

    /// Statistical repeats (paper: 20).
    pub fn repeats(&self) -> usize {
        match self {
            Scale::Smoke => 2,
            Scale::Default => 5,
            Scale::Full => 20,
        }
    }

    /// Hardware-sample budget for the Evolutionary Search baseline.
    pub fn es_budget(&self) -> usize {
        match self {
            Scale::Smoke => 60,
            Scale::Default => 600,
            Scale::Full => 3000,
        }
    }

    /// Budget for the REASONING COMPILER / MCTS variants.
    pub fn rc_budget(&self) -> usize {
        match self {
            Scale::Smoke => 30,
            Scale::Default => 200,
            Scale::Full => 600,
        }
    }

    /// Sample checkpoints for convergence tables (paper Table 3 header).
    pub fn checkpoints(&self) -> Vec<usize> {
        match self {
            Scale::Smoke => vec![18, 36, 60],
            Scale::Default => vec![18, 36, 72, 150, 200, 600],
            Scale::Full => vec![18, 36, 72, 150, 200, 600, 900, 1632, 3000],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_ordered() {
        assert!(Scale::Smoke.es_budget() < Scale::Default.es_budget());
        assert!(Scale::Default.es_budget() < Scale::Full.es_budget());
        assert_eq!(Scale::Full.repeats(), 20);
        assert_eq!(Scale::from_name("paper"), Some(Scale::Full));
        assert_eq!(Scale::from_name("x"), None);
    }
}
