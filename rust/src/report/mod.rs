//! Experiment regenerators: one module per paper table/figure, each
//! producing markdown (for EXPERIMENTS.md) and JSON (for tooling). The
//! benches in `rust/benches/` and the `rcc` CLI both dispatch here; see
//! DESIGN.md's per-experiment index.

pub mod ablations;
pub mod costs;
pub mod explain;
pub mod figure3;
pub mod platforms;
pub mod scale;
pub mod table;

pub use scale::Scale;
