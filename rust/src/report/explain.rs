//! `rcc explain`: reconstruct *why* a tuning session picked its schedule
//! from the decision-provenance audit log alone (`obs::audit`).
//!
//! The explanation is computed purely from the log's records — no replay,
//! no re-measurement — so it works on logs shipped from another machine:
//!
//! - **Winning path**: the chain of tree edges from the root to the node
//!   whose measured latency is the run's best, each edge carrying the
//!   transforms the proposal added, its visit count / Q value after
//!   backprop replay, and its *marginal reward attribution* — the share
//!   of the total latency improvement first realized at that edge
//!   ([`attribute`]; the shares sum to `baseline - best` exactly).
//! - **Abandoned branches**: the most-visited off-path nodes and why they
//!   lost (quarantined measurement, never revisited, lower Q).
//! - **LLM attribution**: proposal acceptance over every `llm` record —
//!   offered vs expanded, rejected-illegal counts, retries, degraded calls.
//! - **Calibration**: surrogate-vs-measured residuals aggregated from
//!   `measure` records, keyed by the session's (shape class, platform).
//! - **Sample efficiency**: each run's convergence curve from its
//!   `result` record.
//!
//! A log may hold several sessions (arming appends); explanation always
//! reads the slice after the **last** `session` record, matching "explain
//! the run I just did".

use crate::cost::CalibrationStats;
use crate::obs::audit::get_u64_str;
use crate::util::json::{arr, num, s, Json};

/// Session parameters from the `session` header record (empty strings
/// when the log predates the header or was truncated before it).
#[derive(Debug, Clone, Default)]
pub struct SessionHeader {
    pub workload: String,
    pub platform: String,
    pub strategy: String,
    pub budget: usize,
    pub repeats: usize,
    /// 16-hex shape class — the calibration table's grouping key.
    pub shape_class: String,
    pub seed: u64,
}

/// One run's outcome (`result` record).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub seed: u64,
    pub baseline: f64,
    pub best_latency: f64,
    pub samples: usize,
    pub failed: usize,
    /// Sample-efficiency curve: `(sample, latency)` per improvement.
    pub curve: Vec<(usize, f64)>,
}

/// One edge of the winning path, root side first.
#[derive(Debug, Clone)]
pub struct PathStep {
    pub node: usize,
    pub source: String,
    pub transforms: Vec<String>,
    /// Measured latency at this node (`None` for a quarantined edge).
    pub latency: Option<f64>,
    pub visits: f64,
    pub q: f64,
    /// Marginal best-latency improvement first realized at this edge.
    pub improvement: f64,
}

/// An explored subtree that lost to the winning path.
#[derive(Debug, Clone)]
pub struct Abandoned {
    pub node: usize,
    pub visits: f64,
    pub q: f64,
    pub reason: String,
    pub transforms: Vec<String>,
}

/// Aggregated LLM proposal attribution over every `llm` record.
#[derive(Debug, Clone, Default)]
pub struct LlmStats {
    pub calls: u64,
    pub offered: u64,
    pub valid: u64,
    pub bare: u64,
    pub invalid: u64,
    pub expanded: u64,
    pub fallbacks: u64,
    pub retries: u64,
    pub degraded: u64,
}

impl LlmStats {
    /// Proposals that survived legality filtering and entered the tree,
    /// over proposals offered (0 when nothing was offered).
    pub fn acceptance_rate(&self) -> f64 {
        if self.offered == 0 { 0.0 } else { self.expanded as f64 / self.offered as f64 }
    }
}

/// One ES generation (`gen` record) of the winning run.
#[derive(Debug, Clone)]
pub struct GenRow {
    pub gen: usize,
    pub measured: usize,
    pub population: usize,
    pub best_fitness: f64,
    pub best_latency: f64,
    pub failed: usize,
}

/// The full reconstruction. Build with [`Explanation::from_records`].
#[derive(Debug, Clone, Default)]
pub struct Explanation {
    pub header: SessionHeader,
    pub runs: Vec<RunSummary>,
    /// Seed of the winning run (minimum best latency across repeats).
    pub winning_seed: u64,
    /// Winning path, root edge first (empty when the baseline won or the
    /// run was ES — ES logs explain through `generations` instead).
    pub path: Vec<PathStep>,
    pub abandoned: Vec<Abandoned>,
    pub llm: LlmStats,
    /// `(shape class, platform, residual summary)` rows.
    pub calibration: Vec<(String, String, CalibrationStats)>,
    pub generations: Vec<GenRow>,
}

/// Marginal reward attribution: walking `lats` in path order with a
/// running best that starts at `baseline`, each step's improvement is the
/// best-latency drop it *first* achieves (0 for regressions). The
/// improvements sum exactly to `baseline - min(best over the path)`, so
/// every microsecond of the final speedup is attributed to exactly one
/// edge. Quarantined edges are passed as `f64::INFINITY` and get 0.
pub fn attribute(baseline: f64, lats: &[f64]) -> Vec<f64> {
    let mut best = baseline;
    lats.iter()
        .map(|&l| {
            if l.is_finite() && l < best {
                let gain = best - l;
                best = l;
                gain
            } else {
                0.0
            }
        })
        .collect()
}

fn f(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(Json::as_f64).unwrap_or(0.0)
}

fn text(j: &Json, k: &str) -> String {
    j.get(k).and_then(Json::as_str).unwrap_or("").to_string()
}

fn kind_of(j: &Json) -> &str {
    j.get("kind").and_then(Json::as_str).unwrap_or("")
}

fn transforms_of(j: &Json) -> Vec<String> {
    j.get("transforms")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|t| t.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

/// Replayed per-node tree state for one run's `node`/`backprop` records.
struct TreeNode {
    parent: Option<usize>,
    source: String,
    transforms: Vec<String>,
    latency: Option<f64>,
    visits: f64,
    w: f64,
}

impl Explanation {
    /// Reconstruct from a loaded audit log (`obs::audit::load`). Reads
    /// the slice after the last `session` record; a headerless log is
    /// explained whole with a default header.
    pub fn from_records(records: &[Json]) -> Explanation {
        let start = records
            .iter()
            .rposition(|r| kind_of(r) == "session")
            .unwrap_or(0);
        let slice = &records[start..];

        let mut ex = Explanation::default();
        if let Some(h) = slice.iter().find(|r| kind_of(r) == "session") {
            ex.header = SessionHeader {
                workload: text(h, "workload"),
                platform: text(h, "platform"),
                strategy: text(h, "strategy"),
                budget: f(h, "budget") as usize,
                repeats: f(h, "repeats") as usize,
                shape_class: text(h, "shape_class"),
                seed: get_u64_str(h, "seed").unwrap_or(0),
            };
        }

        // ---- per-run outcomes + the winning run ---------------------------
        for r in slice.iter().filter(|r| kind_of(r) == "result") {
            let curve = r
                .get("curve")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|p| (f(p, "sample") as usize, f(p, "latency")))
                        .collect()
                })
                .unwrap_or_default();
            ex.runs.push(RunSummary {
                seed: get_u64_str(r, "seed").unwrap_or(0),
                baseline: f(r, "baseline"),
                best_latency: f(r, "best_latency"),
                samples: f(r, "samples") as usize,
                failed: f(r, "failed") as usize,
                curve,
            });
        }
        let winner = ex
            .runs
            .iter()
            .min_by(|a, b| a.best_latency.partial_cmp(&b.best_latency).unwrap())
            .cloned();
        ex.winning_seed = winner.as_ref().map(|w| w.seed).unwrap_or(0);
        let win_seed_s = ex.winning_seed.to_string();
        let of_winner =
            |r: &Json| r.get("seed").and_then(Json::as_str) == Some(win_seed_s.as_str());

        // ---- tree replay for the winning run ------------------------------
        let mut tree: Vec<Option<TreeNode>> = Vec::new();
        for r in slice.iter().filter(|r| kind_of(r) == "node").filter(|r| of_winner(r)) {
            let id = f(r, "id") as usize;
            if tree.len() <= id {
                tree.resize_with(id + 1, || None);
            }
            let root = r.get("parent").is_none();
            tree[id] = Some(TreeNode {
                parent: (!root).then(|| f(r, "parent") as usize),
                source: text(r, "source"),
                transforms: transforms_of(r),
                latency: r.get("latency").and_then(Json::as_f64),
                // Creation state: non-root nodes start at one visit with
                // their creation reward; the root accumulates from warm
                // children and backprop replay below.
                visits: if root { 0.0 } else { 1.0 },
                w: f(r, "reward"),
            });
            // Warm seeding bumps the root without a backprop record.
            if tree[id].as_ref().map(|n| n.source == "warm").unwrap_or(false) {
                let reward = f(r, "reward");
                if let Some(Some(root)) = tree.get_mut(0) {
                    root.visits += 1.0;
                    root.w += reward;
                }
            }
        }
        for r in slice.iter().filter(|r| kind_of(r) == "backprop").filter(|r| of_winner(r)) {
            let reward = f(r, "reward");
            let visit_only = matches!(r.get("visit_only"), Some(Json::Bool(true)));
            if let Some(path) = r.get("path").and_then(Json::as_arr) {
                for id in path.iter().filter_map(Json::as_f64) {
                    if let Some(Some(n)) = tree.get_mut(id as usize) {
                        n.visits += 1.0;
                        if !visit_only {
                            n.w += reward;
                        }
                    }
                }
            }
        }

        // ---- winning path + attribution -----------------------------------
        let mut on_path: Vec<usize> = Vec::new();
        if let Some(w) = &winner {
            // The winning node measured the run's best latency; JSON
            // round-trips f64 shortest-exact, so bit equality holds.
            let win_node = tree
                .iter()
                .enumerate()
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .find(|(_, n)| n.latency.map(|l| l == w.best_latency).unwrap_or(false))
                .map(|(i, _)| i);
            if let Some(mut cur) = win_node {
                loop {
                    on_path.push(cur);
                    match tree[cur].as_ref().and_then(|n| n.parent) {
                        Some(p) => cur = p,
                        None => break,
                    }
                }
                on_path.reverse(); // root first
                let lats: Vec<f64> = on_path
                    .iter()
                    .skip(1) // the root is the baseline, not an edge
                    .map(|&i| {
                        tree[i]
                            .as_ref()
                            .and_then(|n| n.latency)
                            .unwrap_or(f64::INFINITY)
                    })
                    .collect();
                let gains = attribute(w.baseline, &lats);
                for (&id, gain) in on_path.iter().skip(1).zip(gains) {
                    let n = tree[id].as_ref().unwrap();
                    ex.path.push(PathStep {
                        node: id,
                        source: n.source.clone(),
                        transforms: n.transforms.clone(),
                        latency: n.latency,
                        visits: n.visits,
                        q: n.w / n.visits.max(1e-9),
                        improvement: gain,
                    });
                }
            }
        }

        // ---- abandoned branches -------------------------------------------
        let mut off: Vec<(usize, &TreeNode)> = tree
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
            .filter(|(i, _)| *i != 0 && !on_path.contains(i))
            .collect();
        off.sort_by(|a, b| {
            b.1.visits.partial_cmp(&a.1.visits).unwrap().then(a.0.cmp(&b.0))
        });
        for (id, n) in off.into_iter().take(3) {
            let reason = if n.latency.is_none() {
                "quarantined measurement".to_string()
            } else if n.visits <= 1.0 {
                "never revisited (budget went elsewhere)".to_string()
            } else {
                "lower Q than the winning path".to_string()
            };
            ex.abandoned.push(Abandoned {
                node: id,
                visits: n.visits,
                q: n.w / n.visits.max(1e-9),
                reason,
                transforms: n.transforms.clone(),
            });
        }

        // ---- LLM attribution (all repeats) --------------------------------
        for r in slice.iter().filter(|r| kind_of(r) == "llm") {
            ex.llm.calls += 1;
            ex.llm.offered += f(r, "offered") as u64;
            ex.llm.valid += f(r, "valid") as u64;
            ex.llm.bare += f(r, "bare") as u64;
            ex.llm.invalid += f(r, "invalid") as u64;
            ex.llm.expanded += f(r, "expanded") as u64;
            ex.llm.retries += f(r, "retries") as u64;
            if matches!(r.get("fallback"), Some(Json::Bool(true))) {
                ex.llm.fallbacks += 1;
            }
            if matches!(r.get("degraded"), Some(Json::Bool(true))) {
                ex.llm.degraded += 1;
            }
        }

        // ---- calibration table --------------------------------------------
        // `measure` records with a prediction pair the surrogate against
        // the hardware; the standalone-batch records carry no prediction
        // and are skipped. One session = one (shape class, platform) row.
        let mut cal = CalibrationStats::default();
        for r in slice.iter().filter(|r| kind_of(r) == "measure") {
            if let (Some(p), Some(l)) = (
                r.get("predicted").and_then(Json::as_f64),
                r.get("latency").and_then(Json::as_f64),
            ) {
                cal.record(p, l);
            }
        }
        if !cal.is_empty() {
            ex.calibration.push((
                ex.header.shape_class.clone(),
                ex.header.platform.clone(),
                cal,
            ));
        }

        // ---- ES generations of the winning run ----------------------------
        for r in slice.iter().filter(|r| kind_of(r) == "gen").filter(|r| of_winner(r)) {
            ex.generations.push(GenRow {
                gen: f(r, "gen") as usize,
                measured: f(r, "measured") as usize,
                population: f(r, "population") as usize,
                best_fitness: f(r, "best_fitness"),
                best_latency: f(r, "best_latency"),
                failed: f(r, "failed") as usize,
            });
        }

        ex
    }

    /// Human report: every section `rcc explain` prints.
    pub fn render(&self) -> String {
        let h = &self.header;
        let mut out = format!(
            "session: {} on {} — {}, budget {} x {} repeat(s)\n",
            h.workload, h.platform, h.strategy, h.budget, h.repeats
        );
        out.push_str("runs:\n");
        for r in &self.runs {
            out.push_str(&format!(
                "  seed {}: baseline {:.6} -> best {:.6} ({:.2}x), {} sample(s), {} failed\n",
                r.seed,
                r.baseline,
                r.best_latency,
                if r.best_latency > 0.0 { r.baseline / r.best_latency } else { 0.0 },
                r.samples,
                r.failed
            ));
        }
        out.push_str(&format!("winning path (run seed {}):\n", self.winning_seed));
        if self.path.is_empty() {
            out.push_str("  (no tree edges — baseline won, or an ES run; see generations)\n");
        }
        for (i, p) in self.path.iter().enumerate() {
            let lat = p
                .latency
                .map(|l| format!("{l:.6}"))
                .unwrap_or_else(|| "failed".to_string());
            out.push_str(&format!(
                "  {}. node {} [{}] latency {} improvement {:.6} visits {:.0} Q {:.3} via {}\n",
                i + 1,
                p.node,
                p.transforms.join("; "),
                lat,
                p.improvement,
                p.visits,
                p.q,
                p.source
            ));
        }
        if !self.abandoned.is_empty() {
            out.push_str("abandoned branches:\n");
            for a in &self.abandoned {
                out.push_str(&format!(
                    "  node {}: visits {:.0}, Q {:.3} — {} [{}]\n",
                    a.node,
                    a.visits,
                    a.q,
                    a.reason,
                    a.transforms.join("; ")
                ));
            }
        }
        if self.llm.calls > 0 {
            out.push_str(&format!(
                "llm proposals: {} call(s), {} offered, {} accepted ({:.0}%), {} rejected illegal, {} fallback(s), {} retry(ies), {} degraded\n",
                self.llm.calls,
                self.llm.offered,
                self.llm.expanded,
                self.llm.acceptance_rate() * 100.0,
                self.llm.invalid,
                self.llm.fallbacks,
                self.llm.retries,
                self.llm.degraded
            ));
        }
        for (class, plat, stats) in &self.calibration {
            out.push_str(&format!(
                "calibration [{class} @ {plat}]: {}\n",
                stats.render_line()
            ));
        }
        if !self.runs.is_empty() {
            out.push_str("sample efficiency:\n");
            for r in &self.runs {
                let best_at = r
                    .curve
                    .iter()
                    .filter(|(_, l)| *l == r.best_latency)
                    .map(|(s, _)| *s)
                    .next()
                    .unwrap_or(0);
                out.push_str(&format!(
                    "  seed {}: best found at sample {} of {}\n",
                    r.seed, best_at, r.samples
                ));
            }
        }
        if !self.generations.is_empty() {
            out.push_str("es generations:\n");
            for g in &self.generations {
                out.push_str(&format!(
                    "  gen {}: measured {}, population {}, best fitness {:.3}, best latency {:.6}, failed {}\n",
                    g.gen, g.measured, g.population, g.best_fitness, g.best_latency, g.failed
                ));
            }
        }
        out
    }

    /// Machine form (`rcc explain --json`).
    pub fn to_json(&self) -> Json {
        let h = &self.header;
        let mut header = Json::obj();
        header
            .set("workload", s(&h.workload))
            .set("platform", s(&h.platform))
            .set("strategy", s(&h.strategy))
            .set("budget", num(h.budget as f64))
            .set("repeats", num(h.repeats as f64))
            .set("shape_class", s(&h.shape_class))
            .set("seed", s(&h.seed.to_string()));
        let runs = arr(self
            .runs
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("seed", s(&r.seed.to_string()))
                    .set("baseline", num(r.baseline))
                    .set("best_latency", num(r.best_latency))
                    .set("samples", num(r.samples as f64))
                    .set("failed", num(r.failed as f64))
                    .set(
                        "curve",
                        arr(r
                            .curve
                            .iter()
                            .map(|(smp, lat)| {
                                let mut p = Json::obj();
                                p.set("sample", num(*smp as f64)).set("latency", num(*lat));
                                p
                            })
                            .collect()),
                    );
                o
            })
            .collect());
        let path = arr(self
            .path
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("node", num(p.node as f64))
                    .set("source", s(&p.source))
                    .set("transforms", arr(p.transforms.iter().map(|t| s(t)).collect()))
                    .set("improvement", num(p.improvement))
                    .set("visits", num(p.visits))
                    .set("q", num(p.q));
                match p.latency {
                    Some(l) => o.set("latency", num(l)),
                    None => o.set("failed", Json::Bool(true)),
                };
                o
            })
            .collect());
        let abandoned = arr(self
            .abandoned
            .iter()
            .map(|a| {
                let mut o = Json::obj();
                o.set("node", num(a.node as f64))
                    .set("visits", num(a.visits))
                    .set("q", num(a.q))
                    .set("reason", s(&a.reason))
                    .set("transforms", arr(a.transforms.iter().map(|t| s(t)).collect()));
                o
            })
            .collect());
        let mut llm = Json::obj();
        llm.set("calls", num(self.llm.calls as f64))
            .set("offered", num(self.llm.offered as f64))
            .set("valid", num(self.llm.valid as f64))
            .set("bare", num(self.llm.bare as f64))
            .set("invalid", num(self.llm.invalid as f64))
            .set("expanded", num(self.llm.expanded as f64))
            .set("acceptance_rate", num(self.llm.acceptance_rate()))
            .set("fallbacks", num(self.llm.fallbacks as f64))
            .set("retries", num(self.llm.retries as f64))
            .set("degraded", num(self.llm.degraded as f64));
        let calibration = arr(self
            .calibration
            .iter()
            .map(|(class, plat, stats)| {
                let mut o = Json::obj();
                o.set("shape_class", s(class))
                    .set("platform", s(plat))
                    .set("stats", stats.to_json());
                o
            })
            .collect());
        let generations = arr(self
            .generations
            .iter()
            .map(|g| {
                let mut o = Json::obj();
                o.set("gen", num(g.gen as f64))
                    .set("measured", num(g.measured as f64))
                    .set("population", num(g.population as f64))
                    .set("best_fitness", num(g.best_fitness))
                    .set("best_latency", num(g.best_latency))
                    .set("failed", num(g.failed as f64));
                o
            })
            .collect());
        let mut doc = Json::obj();
        doc.set("header", header)
            .set("winning_seed", s(&self.winning_seed.to_string()))
            .set("runs", runs)
            .set("winning_path", path)
            .set("abandoned", abandoned)
            .set("llm", llm)
            .set("calibration", calibration)
            .set("generations", generations);
        doc
    }
}

/// Explain a *registry* run record (`results/runs/<id>.json`): the
/// persisted summary has no tree, but it carries the best trace, the
/// sample-efficiency curve and the session calibration block.
pub fn render_run_record(doc: &Json) -> String {
    let gs = |k: &str| doc.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let gn = |k: &str| doc.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let mut out = format!(
        "run {}: {} on {} — {}, mean {:.2}x, best {:.2}x in {} sample(s)\n",
        gs("id"),
        gs("workload"),
        gs("platform"),
        gs("strategy"),
        gn("mean_speedup"),
        gn("best_speedup"),
        gn("samples")
    );
    if let Some(trace) = doc.get("best_trace").and_then(Json::as_arr) {
        out.push_str("best trace:\n");
        for t in trace {
            if let Some(t) = t.as_str() {
                out.push_str(&format!("  {t}\n"));
            }
        }
    }
    if let Some(curve) = doc.get("curve").and_then(Json::as_arr) {
        out.push_str("sample efficiency:\n");
        for p in curve {
            out.push_str(&format!(
                "  sample {:>4}: {:.2}x\n",
                p.get("sample").and_then(Json::as_f64).unwrap_or(0.0),
                p.get("best_speedup").and_then(Json::as_f64).unwrap_or(0.0)
            ));
        }
    }
    if let Some(cal) = doc.get("telemetry").and_then(|t| t.get("calibration")) {
        let stats = CalibrationStats::from_json(cal);
        if !stats.is_empty() {
            out.push_str(&format!("calibration: {}\n", stats.render_line()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::audit::record;

    #[test]
    fn attribute_sums_to_total_improvement_and_skips_regressions() {
        let gains = attribute(10.0, &[8.0, 9.0, f64::INFINITY, 6.0, 6.0]);
        assert_eq!(gains, vec![2.0, 0.0, 0.0, 2.0, 0.0]);
        let total: f64 = gains.iter().sum();
        assert!((total - (10.0 - 6.0)).abs() < 1e-12, "sum == baseline - best");
        assert!(attribute(5.0, &[]).is_empty());
        // A path that never beats the baseline attributes nothing.
        assert_eq!(attribute(1.0, &[2.0, 3.0]), vec![0.0, 0.0]);
    }

    /// Synthetic log: root -> node 1 (best) -> abandoned node 2.
    fn synthetic_log() -> Vec<Json> {
        let mut records = Vec::new();
        let mut h = record("session", 42);
        h.set("workload", s("w")).set("platform", s("p")).set("strategy", s("mcts"))
            .set("budget", num(10.0)).set("repeats", num(1.0))
            .set("shape_class", s("00000000000000aa"));
        records.push(h);
        let mut root = record("node", 42);
        root.set("id", num(0.0)).set("source", s("root")).set("latency", num(10.0))
            .set("step", num(0.0));
        records.push(root);
        let mut n1 = record("node", 42);
        n1.set("id", num(1.0)).set("parent", num(0.0)).set("source", s("policy"))
            .set("step", num(0.0)).set("score", num(1.5)).set("reward", num(1.0))
            .set("latency", num(6.0))
            .set("transforms", arr(vec![s("tile(stage=0, loop=1, factor=8)")]));
        records.push(n1);
        let mut n2 = record("node", 42);
        n2.set("id", num(2.0)).set("parent", num(0.0)).set("source", s("policy"))
            .set("step", num(1.0)).set("score", num(1.1)).set("reward", num(0.4))
            .set("latency", num(9.0))
            .set("transforms", arr(vec![s("cache_write(stage=0)")]));
        records.push(n2);
        let mut b = record("backprop", 42);
        b.set("leaf", num(1.0)).set("reward", num(1.0))
            .set("visit_only", Json::Bool(false)).set("path", arr(vec![num(0.0)]));
        records.push(b);
        for (pred, lat) in [(6.5, 6.0), (9.5, 9.0)] {
            let mut m = record("measure", 42);
            m.set("sample", num(1.0)).set("predicted", num(pred)).set("latency", num(lat));
            records.push(m);
        }
        let mut l = record("llm", 42);
        l.set("call", num(0.0)).set("ctx", s("abcd")).set("step", num(0.0))
            .set("offered", num(3.0)).set("valid", num(2.0)).set("bare", num(0.0))
            .set("invalid", num(1.0)).set("expanded", num(2.0))
            .set("fallback", Json::Bool(false)).set("retries", num(1.0))
            .set("degraded", Json::Bool(false));
        records.push(l);
        let mut r = record("result", 42);
        r.set("baseline", num(10.0)).set("best_latency", num(6.0))
            .set("samples", num(2.0)).set("failed", num(0.0))
            .set("curve", arr(vec![{
                let mut p = Json::obj();
                p.set("sample", num(2.0)).set("latency", num(6.0));
                p
            }]));
        records.push(r);
        records
    }

    #[test]
    fn reconstructs_winning_path_attribution_and_stats() {
        let ex = Explanation::from_records(&synthetic_log());
        assert_eq!(ex.header.workload, "w");
        assert_eq!(ex.winning_seed, 42);
        assert_eq!(ex.runs.len(), 1);
        assert_eq!(ex.path.len(), 1, "root -> node 1");
        assert_eq!(ex.path[0].node, 1);
        assert_eq!(ex.path[0].transforms, vec!["tile(stage=0, loop=1, factor=8)"]);
        assert!((ex.path[0].improvement - 4.0).abs() < 1e-12);
        // Node 1: created with 1 visit, no further backprop onto itself.
        assert!((ex.path[0].visits - 1.0).abs() < 1e-12);
        assert_eq!(ex.abandoned.len(), 1);
        assert_eq!(ex.abandoned[0].node, 2);
        assert_eq!(ex.llm.calls, 1);
        assert!((ex.llm.acceptance_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(ex.calibration.len(), 1);
        assert_eq!(ex.calibration[0].2.n, 2);
        let text = ex.render();
        assert!(text.contains("winning path"), "{text}");
        assert!(text.contains("llm proposals"), "{text}");
        assert!(text.contains("calibration ["), "{text}");
        let json = ex.to_json().to_string();
        assert!(json.contains("winning_path"), "{json}");
    }

    #[test]
    fn explains_the_last_session_slice_only() {
        let mut records = Vec::new();
        // A stale first session with a different workload.
        let mut old = record("session", 1);
        old.set("workload", s("stale"));
        records.push(old);
        records.extend(synthetic_log());
        let ex = Explanation::from_records(&records);
        assert_eq!(ex.header.workload, "w", "only the last session explains");
    }
}
