//! Appendix F / Table 7 (API cost per experiment) and Appendix G /
//! Table 8 (proposal validity and fallback rates).

use crate::coordinator::{run_session, Strategy, TuneConfig};
use crate::reasoning::ModelProfile;
use crate::tir::workload::WorkloadId;
use crate::util::json::{num, s, Json};

use super::scale::Scale;
use super::table::{usd, Table};

pub struct CostReport {
    pub markdown: String,
    pub json: Json,
}

/// Table 7: USD cost of a full experiment per (benchmark, model).
pub fn table7(scale: Scale, seed: u64) -> CostReport {
    let models = ModelProfile::all();
    let mut hdr = vec!["Layer / Task".to_string()];
    hdr.extend(models.iter().map(|m| m.display.to_string()));
    let mut t = Table::new(
        "Table 7 — LLM API cost per experiment (USD)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut json_rows = Vec::new();
    for w in WorkloadId::ALL {
        let mut row = vec![w.display().to_string()];
        let mut jrow = Json::obj();
        jrow.set("workload", s(w.name()));
        for model in &models {
            let cfg = TuneConfig {
                strategy: Strategy::LlmMcts,
                workload: w.name().to_string(),
                platform: "core_i9".to_string(),
                budget: scale.rc_budget(),
                repeats: scale.repeats().min(3), // cost scales linearly anyway
                seed,
                model: model.name.to_string(),
                ..Default::default()
            };
            let session = run_session(&cfg).expect("tuning session");
            // Cost of ONE full experiment = total cost / repeats.
            let cost = session.llm_costs.usd(model) / cfg.repeats as f64;
            row.push(usd(cost));
            jrow.set(model.name, num(cost));
        }
        t.row(row);
        json_rows.push(jrow);
    }
    let mut json = Json::obj();
    json.set("experiment", s("table7"))
        .set("rows", Json::Arr(json_rows));
    CostReport {
        markdown: format!("## Table 7\n\n{}", t.to_markdown()),
        json,
    }
}

/// Table 8: fallback rate by proposal model.
pub fn table8(scale: Scale, seed: u64) -> CostReport {
    let mut t = Table::new(
        "Table 8 — fallback rate by proposal model",
        &["Model", "Fallback Rate", "Expected (profile)"],
    );
    let mut json_rows = Vec::new();
    for model in ModelProfile::all() {
        let cfg = TuneConfig {
            strategy: Strategy::LlmMcts,
            workload: "deepseek_moe".to_string(),
            platform: "core_i9".to_string(),
            budget: scale.rc_budget() * 2, // more expansions => tighter estimate
            repeats: scale.repeats(),
            seed,
            model: model.name.to_string(),
            ..Default::default()
        };
        let session = run_session(&cfg).expect("tuning session");
        let rate = session.llm_fallback_rate;
        t.row(vec![
            model.display.to_string(),
            format!("{:.2}%", rate * 100.0),
            format!("{:.2}%", model.expected_fallback_rate() * 100.0),
        ]);
        let mut jrow = Json::obj();
        jrow.set("model", s(model.name))
            .set("fallback_rate", num(rate))
            .set("expected", num(model.expected_fallback_rate()));
        json_rows.push(jrow);
    }
    let mut json = Json::obj();
    json.set("experiment", s("table8"))
        .set("rows", Json::Arr(json_rows));
    CostReport {
        markdown: format!("## Table 8\n\n{}", t.to_markdown()),
        json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_smoke_orders_models() {
        let r = table8(Scale::Smoke, 5);
        let rows = r.json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 6);
        // Strong commercial models: 0 fallback. Small OSS: > 0.
        let rate = |name: &str| {
            rows.iter()
                .find(|r| r.get("model").unwrap().as_str() == Some(name))
                .unwrap()
                .get("fallback_rate")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(rate("gpt4o_mini"), 0.0);
        assert_eq!(rate("o1_mini"), 0.0);
        assert!(rate("ds_distill_7b") > rate("llama33_70b"));
    }
}
