//! Table 1 (layer-wise) and Table 2 (end-to-end Llama-3-8B): sample
//! efficiency of the REASONING COMPILER vs TVM Evolutionary Search across
//! the five hardware platforms.
//!
//! Protocol (matching the paper's metrics):
//! - run ES with the large baseline budget, RC with the small budget;
//! - "# Samples" = samples to reach 98% of that run's own final best
//!   (the convergence point);
//! - Speedup = final best over the unoptimized baseline;
//! - Sample Reduction = ES samples / RC samples;
//! - Sample Efficiency Gain = (RC speedup / RC samples) /
//!   (ES speedup / ES samples).

use crate::coordinator::{run_e2e, run_session, Strategy, TuneConfig};
use crate::cost::Platform;
use crate::tir::workload::{self, WorkloadId};
use crate::util::json::{num, s, Json};
use crate::util::stats;

use super::scale::Scale;
use super::table::{n, x, Table};

pub struct PlatformReport {
    pub markdown: String,
    pub json: Json,
}

/// Samples to convergence: first sample reaching 98% of the session's mean
/// final speedup.
fn convergence_samples(session: &crate::coordinator::SessionResult) -> f64 {
    let target = session.mean_speedup() * 0.98;
    session.mean_samples_to(target)
}

struct PairOutcome {
    es_samples: f64,
    es_speedup: f64,
    rc_samples: f64,
    rc_speedup: f64,
}

impl PairOutcome {
    fn reduction(&self) -> f64 {
        self.es_samples / self.rc_samples.max(1.0)
    }
    fn efficiency_gain(&self) -> f64 {
        (self.rc_speedup / self.rc_samples.max(1.0)) / (self.es_speedup / self.es_samples.max(1.0))
    }
}

fn run_pair(workload: &str, platform: &str, scale: Scale, seed: u64) -> PairOutcome {
    let base = TuneConfig {
        workload: workload.to_string(),
        platform: platform.to_string(),
        repeats: scale.repeats(),
        seed,
        ..Default::default()
    };
    let es = run_session(&TuneConfig {
        strategy: Strategy::Evolutionary,
        budget: scale.es_budget(),
        ..base.clone()
    })
    .expect("tuning session");
    let rc = run_session(&TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: scale.rc_budget(),
        ..base
    })
    .expect("tuning session");
    PairOutcome {
        es_samples: convergence_samples(&es),
        es_speedup: es.mean_speedup(),
        rc_samples: convergence_samples(&rc),
        rc_speedup: rc.mean_speedup(),
    }
}

/// Regenerate Table 1.
pub fn table1(scale: Scale, seed: u64) -> PlatformReport {
    let mut t = Table::new(
        "Table 1 — layer-wise sample efficiency across hardware platforms",
        &[
            "Platform",
            "Benchmark",
            "TVM # Samples",
            "TVM Speedup",
            "RC # Samples",
            "RC Speedup",
            "Sample Reduction",
            "Sample Efficiency Gain",
        ],
    );
    let mut json_rows = Vec::new();
    let mut es_speeds = Vec::new();
    let mut rc_speeds = Vec::new();
    let mut reductions = Vec::new();
    let mut gains = Vec::new();

    for platform in Platform::all() {
        for w in WorkloadId::ALL {
            let o = run_pair(w.name(), platform.name, scale, seed);
            t.row(vec![
                platform.display.to_string(),
                w.display().to_string(),
                n(o.es_samples),
                x(o.es_speedup),
                n(o.rc_samples),
                x(o.rc_speedup),
                x(o.reduction()),
                x(o.efficiency_gain()),
            ]);
            let mut row = Json::obj();
            row.set("platform", s(platform.name))
                .set("workload", s(w.name()))
                .set("es_samples", num(o.es_samples))
                .set("es_speedup", num(o.es_speedup))
                .set("rc_samples", num(o.rc_samples))
                .set("rc_speedup", num(o.rc_speedup))
                .set("sample_reduction", num(o.reduction()))
                .set("efficiency_gain", num(o.efficiency_gain()));
            json_rows.push(row);
            es_speeds.push(o.es_speedup);
            rc_speeds.push(o.rc_speedup);
            reductions.push(o.reduction());
            gains.push(o.efficiency_gain());
        }
    }
    let geo = |v: &[f64]| stats::geomean(v);
    t.row(vec![
        "Geomean".into(),
        "-".into(),
        "-".into(),
        x(geo(&es_speeds)),
        "-".into(),
        x(geo(&rc_speeds)),
        x(geo(&reductions)),
        x(geo(&gains)),
    ]);

    let mut json = Json::obj();
    json.set("experiment", s("table1"))
        .set("rows", Json::Arr(json_rows))
        .set("geomean_es_speedup", num(geo(&es_speeds)))
        .set("geomean_rc_speedup", num(geo(&rc_speeds)))
        .set("geomean_sample_reduction", num(geo(&reductions)))
        .set("geomean_efficiency_gain", num(geo(&gains)));
    PlatformReport {
        markdown: format!("## Table 1\n\n{}", t.to_markdown()),
        json,
    }
}

/// Regenerate Table 2 (end-to-end Llama-3-8B).
pub fn table2(scale: Scale, seed: u64) -> PlatformReport {
    let mut t = Table::new(
        "Table 2 — end-to-end Llama-3-8B sample efficiency",
        &[
            "Platform",
            "TVM # Samples",
            "TVM Speedup",
            "RC # Samples",
            "RC Speedup",
            "Sample Reduction",
            "Sample Efficiency Gain",
        ],
    );
    // Scaled-down task set at smoke scale; serving-sized otherwise.
    let tasks = match scale {
        Scale::Smoke => workload::llama3_e2e_test(),
        _ => workload::llama3_e2e(64),
    };
    let mut json_rows = Vec::new();
    let mut es_speeds = Vec::new();
    let mut rc_speeds = Vec::new();
    let mut reductions = Vec::new();
    let mut gains = Vec::new();

    for platform in Platform::all() {
        let mk = |strategy: Strategy, budget: usize| TuneConfig {
            strategy,
            platform: platform.name.to_string(),
            budget,
            repeats: (scale.repeats() / 2).max(1), // e2e repeats are heavier
            seed,
            ..Default::default()
        };
        // Whole-model budgets: tasks share the budget inside run_e2e.
        let es = run_e2e(&tasks, &mk(Strategy::Evolutionary, scale.es_budget() * 2))
            .expect("e2e tuning");
        let rc = run_e2e(&tasks, &mk(Strategy::LlmMcts, scale.rc_budget() * 2))
            .expect("e2e tuning");
        let (es_n, rc_n) = (es.total_samples as f64, rc.total_samples as f64);
        let reduction = es_n / rc_n.max(1.0);
        let gain = (rc.weighted_speedup / rc_n.max(1.0)) / (es.weighted_speedup / es_n.max(1.0));
        t.row(vec![
            platform.display.to_string(),
            n(es_n),
            x(es.weighted_speedup),
            n(rc_n),
            x(rc.weighted_speedup),
            x(reduction),
            x(gain),
        ]);
        let mut row = Json::obj();
        row.set("platform", s(platform.name))
            .set("es_samples", num(es_n))
            .set("es_speedup", num(es.weighted_speedup))
            .set("rc_samples", num(rc_n))
            .set("rc_speedup", num(rc.weighted_speedup))
            .set("sample_reduction", num(reduction))
            .set("efficiency_gain", num(gain));
        json_rows.push(row);
        es_speeds.push(es.weighted_speedup);
        rc_speeds.push(rc.weighted_speedup);
        reductions.push(reduction);
        gains.push(gain);
    }
    t.row(vec![
        "Geomean".into(),
        "-".into(),
        x(stats::geomean(&es_speeds)),
        "-".into(),
        x(stats::geomean(&rc_speeds)),
        x(stats::geomean(&reductions)),
        x(stats::geomean(&gains)),
    ]);

    let mut json = Json::obj();
    json.set("experiment", s("table2"))
        .set("rows", Json::Arr(json_rows))
        .set("geomean_rc_speedup", num(stats::geomean(&rc_speeds)))
        .set("geomean_sample_reduction", num(stats::geomean(&reductions)))
        .set("geomean_efficiency_gain", num(stats::geomean(&gains)));
    PlatformReport {
        markdown: format!("## Table 2\n\n{}", t.to_markdown()),
        json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_has_25_pairs_plus_geomean() {
        let r = table1(Scale::Smoke, 3);
        let rows = r.json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 25);
        assert!(r.markdown.contains("Geomean"));
        // Headline shape: RC gains efficiency on geomean.
        let gain = r.json.get("geomean_efficiency_gain").unwrap().as_f64().unwrap();
        assert!(gain > 1.0, "geomean efficiency gain {gain}");
    }
}
