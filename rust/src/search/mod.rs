//! Search engines over the transformation space: MCTS with UCT (vanilla and
//! LLM-guided via a pluggable [`ProposalPolicy`]) and the TVM-style
//! Evolutionary Search baseline. All strategies meter hardware measurements
//! through [`common::Evaluator`], producing the speedup-vs-samples curves
//! the paper's figures and tables are built from.
//!
//! Both engines have `*_warm` variants that accept a [`WarmStart`] (known
//! traces from the tuning database, seeded into the MCTS root frontier /
//! the evolutionary population) and a `db::MeasureCache` (re-measurements
//! of known programs cost zero samples); [`SearchResult`] reports the
//! cache hit/miss counts.

pub mod common;
pub mod evolutionary;
pub mod mcts;

pub use common::{
    Evaluator, Measurement, ProposalContext, ProposalPolicy, RandomPolicy, SearchResult, WarmStart,
};
pub use evolutionary::{evolutionary_search, evolutionary_search_warm, EvoConfig};
pub use mcts::{mcts_search, mcts_search_warm, MctsConfig};
