//! Search engines over the transformation space: MCTS with UCT (vanilla and
//! LLM-guided via a pluggable [`ProposalPolicy`]) and the TVM-style
//! Evolutionary Search baseline. All strategies meter hardware measurements
//! through [`common::Evaluator`], producing the speedup-vs-samples curves
//! the paper's figures and tables are built from.

pub mod common;
pub mod evolutionary;
pub mod mcts;

pub use common::{Measurement, ProposalContext, ProposalPolicy, RandomPolicy, SearchResult};
pub use evolutionary::{evolutionary_search, EvoConfig};
pub use mcts::{mcts_search, MctsConfig};
