//! Search engines over the transformation space: MCTS with UCT (vanilla and
//! LLM-guided via a pluggable [`ProposalPolicy`]) and the TVM-style
//! Evolutionary Search baseline, unified behind the [`SearchStrategy`]
//! trait over a [`SearchContext`]. All strategies meter hardware
//! measurements through [`common::Evaluator`] — planned and streamed onto
//! the persistent `util::executor::Executor` by [`common::BatchEvaluator`]
//! (its crate-internal `PlannedBatch`) — producing the speedup-vs-samples
//! curves the paper's figures and tables are built from.
//!
//! Warm starts ([`WarmStart`] traces from the tuning database) seed the
//! MCTS root frontier / the evolutionary population through one shared
//! replay helper ([`common::replay_warm_entries`]), and an attached
//! `db::MeasureCache` makes re-measurements of known programs cost zero
//! samples; [`SearchResult`] reports the cache hit/miss counts.
//!
//! Determinism: the executor width never changes results (measurement
//! seeds are fixed at plan time and results fold by plan index);
//! `eval_batch > 1` switches MCTS to leaf-parallel expansion, which
//! changes the trajectory but stays bit-reproducible per seed. The legacy
//! free functions (`mcts_search*`, `evolutionary_search*`) wrap the
//! strategies with a serial context.

pub mod common;
pub mod evolutionary;
pub mod mcts;

pub use common::{
    replay_warm_entries, BatchEvaluator, Evaluator, Measurement, ProposalContext,
    ProposalPolicy, RandomPolicy, SearchContext, SearchResult, SearchStrategy, WarmReplay,
    WarmStart,
};
pub use evolutionary::{
    evolutionary_search, evolutionary_search_warm, EvoConfig, EvolutionaryStrategy,
};
pub use mcts::{mcts_search, mcts_search_warm, MctsConfig, MctsStrategy};
