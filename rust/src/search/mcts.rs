//! Monte Carlo tree search over transformation sequences (§3.2).
//!
//! - **Selection**: UCT descent from the root (`c = sqrt(2)` by default).
//! - **Expansion**: the proposal policy (random for vanilla MCTS, the LLM
//!   reasoning engine for the REASONING COMPILER) suggests a transformation
//!   sequence, which is applied to create one new child node. Duplicate
//!   program states (by structural fingerprint) are not re-added, keeping
//!   the tree acyclic.
//! - **Rollout**: a short random continuation is scored with the surrogate
//!   f̂ — never the hardware model, matching the paper's cost-model-driven
//!   simulation.
//! - **Backpropagation**: normalized rewards and visit counts flow to the
//!   root.
//!
//! Each expanded child is additionally measured once on the hardware model,
//! consuming one sample of the budget (this is the paper's "evaluated
//! transformation proposals" axis).

use std::collections::HashSet;

use crate::cost::CostModel;
use crate::db::{program_fingerprint, MeasureCache};
use crate::schedule::{sampler, Schedule};
use crate::tir::Program;
use crate::util::rng::Pcg;

use super::common::{Evaluator, ProposalContext, ProposalPolicy, SearchResult, WarmStart};

/// MCTS hyperparameters (paper §4.1: c = sqrt(2), B = 2).
#[derive(Debug, Clone)]
pub struct MctsConfig {
    /// UCT exploration constant.
    pub exploration_c: f64,
    /// Branching factor: max children per node.
    pub branching: usize,
    /// Rollout depth (random continuation length).
    pub rollout_len: usize,
    /// History depth handed to the proposal policy (2 = parent+grandparent,
    /// 3 adds the great-grandparent; Figure 4b / Table 5 ablate this).
    pub history_depth: usize,
    /// Maximum transformation-sequence length (the horizon T of §2).
    pub max_trace_len: usize,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig {
            exploration_c: std::f64::consts::SQRT_2,
            branching: 2,
            rollout_len: 4,
            history_depth: 2,
            max_trace_len: 24,
        }
    }
}

struct Node {
    schedule: Schedule,
    parent: Option<usize>,
    children: Vec<usize>,
    /// Cumulative normalized reward.
    w: f64,
    /// Visit count.
    n: f64,
    /// Surrogate score (baseline_latency / f̂), cached for prompts.
    score: f64,
}

/// Run MCTS with the given proposal policy. `surrogate` scores rollouts;
/// `hardware` (inside `Evaluator`) measures expanded candidates and meters
/// the sample budget.
#[allow(clippy::too_many_arguments)]
pub fn mcts_search(
    base: &Program,
    policy: &mut dyn ProposalPolicy,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &MctsConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
) -> SearchResult {
    mcts_search_warm(
        base, policy, surrogate, hardware, cfg, platform, budget, seed, None, None,
    )
}

/// [`mcts_search`] with tuning-database support: `warm` traces are replayed
/// and inserted as root children before the first UCT iteration (the search
/// starts from the best-known frontier instead of an empty tree), and
/// `cache` answers re-measurements of known programs without consuming the
/// sample budget.
#[allow(clippy::too_many_arguments)]
pub fn mcts_search_warm(
    base: &Program,
    policy: &mut dyn ProposalPolicy,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &MctsConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
    warm: Option<&WarmStart>,
    cache: Option<MeasureCache>,
) -> SearchResult {
    let mut rng = Pcg::new(seed);
    let mut ev = match cache {
        Some(c) => Evaluator::with_cache(hardware, base, budget, seed, c, platform.name),
        None => Evaluator::new(hardware, base, budget, seed),
    };
    let surrogate_baseline = surrogate.latency(base, seed ^ 0xF0F0);

    let root_sched = Schedule::new(base.clone());
    let mut nodes = vec![Node {
        score: 1.0,
        schedule: root_sched,
        parent: None,
        children: Vec::new(),
        w: 0.0,
        n: 1e-9,
    }];
    // Tree dedup and the measurement cache share one structural hash
    // (`db::program_fingerprint`), computed once per candidate and handed
    // to the evaluator — hashing the program is on the per-sample hot path.
    let mut seen: HashSet<u64> = HashSet::new();
    seen.insert(program_fingerprint(&nodes[0].schedule.current));

    let mut best_rollout_reward: f64 = 1.0;

    // ---- warm start: seed root children from the tuning database -----------
    // Each known-good trace becomes a root child whose exploit weight is
    // proportional to its *measured* speedup (best warm entry = 1.0), so
    // UCT prefers the strongest recorded frontier instead of treating all
    // seeds as equally good. With a pre-populated cache these measurements
    // are free; without one they spend budget like any other candidate.
    if let Some(ws) = warm {
        let mut seeded: Vec<(usize, f64)> = Vec::new();
        for (i, (trace, _known_latency)) in ws.entries.iter().enumerate() {
            let (child_sched, applied) = nodes[0].schedule.apply_all(trace);
            if applied == 0 {
                continue;
            }
            let fp = program_fingerprint(&child_sched.current);
            if !seen.insert(fp) {
                continue;
            }
            let Some(lat) = ev.measure_with_fingerprint(&child_sched, fp) else {
                break;
            };
            let child_latency_hat =
                surrogate.latency(&child_sched.current, seed ^ 0x3A17 ^ (i as u64) << 8);
            let score = surrogate_baseline / child_latency_hat;
            let child_id = nodes.len();
            nodes.push(Node {
                schedule: child_sched,
                parent: Some(0),
                children: Vec::new(),
                w: 0.0, // assigned below, normalized over all warm children
                n: 1.0,
                score,
            });
            nodes[0].children.push(child_id);
            nodes[0].n += 1.0;
            seeded.push((child_id, ev.baseline_latency / lat));
        }
        let best_speedup = seeded.iter().map(|&(_, s)| s).fold(0.0, f64::max);
        if best_speedup > 0.0 {
            for &(id, speedup) in &seeded {
                let reward = speedup / best_speedup;
                nodes[id].w = reward;
                nodes[0].w += reward;
            }
        }
    }

    let mut step = 0usize;
    // Guard against saturation: on tiny programs every proposal can
    // duplicate an existing node; stop after too many sterile iterations.
    let mut sterile = 0usize;

    while !ev.exhausted() {
        if sterile > 200 {
            break;
        }
        step += 1;
        // ---- selection: UCT descent to an expandable node ------------------
        let mut cur = 0usize;
        loop {
            let node = &nodes[cur];
            let expandable = node.children.len() < cfg.branching
                && node.schedule.trace.len() < cfg.max_trace_len;
            if expandable || node.children.is_empty() {
                break;
            }
            let ln_n = node.n.max(1.0).ln();
            let mut best_child = node.children[0];
            let mut best_uct = f64::NEG_INFINITY;
            for &c in &node.children {
                let ch = &nodes[c];
                let uct = ch.w / ch.n.max(1e-9)
                    + cfg.exploration_c * (ln_n / ch.n.max(1e-9)).sqrt();
                if uct > best_uct {
                    best_uct = uct;
                    best_child = c;
                }
            }
            cur = best_child;
        }

        // ---- expansion: ask the policy for a transformation sequence -------
        let (ancestors, scores) = ancestor_chain(&nodes, cur, cfg.history_depth);
        let proposal = {
            let ctx = ProposalContext {
                node: &nodes[cur].schedule,
                ancestors,
                scores,
                platform,
                step,
            };
            policy.propose(&ctx)
        };
        // Apply the proposal; if nothing applies, fall back to one random
        // legal transform (Appendix G's fallback path).
        let (mut child_sched, applied) = nodes[cur].schedule.apply_all(&proposal);
        if applied == 0 {
            match sampler::random_transform(&nodes[cur].schedule.current, &mut rng) {
                Some(t) => match nodes[cur].schedule.apply(t) {
                    Ok(s) => child_sched = s,
                    Err(_) => continue,
                },
                None => break,
            }
        }

        // Dedup: if this program state already exists in the tree, do not
        // add it again (tree stays acyclic); still spend a visit.
        let fp = program_fingerprint(&child_sched.current);
        if !seen.insert(fp) {
            nodes[cur].n += 1.0;
            sterile += 1;
            continue;
        }
        sterile = 0;

        // Measure the new candidate on hardware (one sample); the dedup
        // fingerprint doubles as the measurement-cache key.
        if ev.measure_with_fingerprint(&child_sched, fp).is_none() {
            break;
        }

        // ---- rollout: random continuation scored by the surrogate ----------
        let rollout_seq =
            sampler::random_sequence(&child_sched.current, cfg.rollout_len, &mut rng);
        let (rollout_sched, _) = child_sched.apply_all(&rollout_seq);
        let rollout_latency = surrogate.latency(&rollout_sched.current, seed ^ step as u64);
        // Direct surrogate score of the child itself (used in prompts).
        let child_latency_hat = surrogate.latency(&child_sched.current, seed ^ (step as u64) << 1);
        let child_score = surrogate_baseline / child_latency_hat;

        // Reward: speedup of the rollout terminal vs baseline, normalized by
        // the best rollout so far to keep UCT's exploit term in [0, 1].
        let raw_reward = surrogate_baseline / rollout_latency;
        best_rollout_reward = best_rollout_reward.max(raw_reward);
        let reward = raw_reward / best_rollout_reward;

        // ---- insert + backpropagate ----------------------------------------
        let child_id = nodes.len();
        nodes.push(Node {
            schedule: child_sched,
            parent: Some(cur),
            children: Vec::new(),
            w: reward,
            n: 1.0,
            score: child_score,
        });
        nodes[cur].children.push(child_id);
        let mut up = Some(cur);
        while let Some(i) = up {
            nodes[i].w += reward;
            nodes[i].n += 1.0;
            up = nodes[i].parent;
        }
    }

    ev.into_result(&format!("mcts[{}]", policy.name()), &base.name, platform.name)
}

/// Collect up to `depth` ancestors (nearest first) and surrogate scores
/// aligned with [node, ancestors...].
fn ancestor_chain(
    nodes: &[Node],
    cur: usize,
    depth: usize,
) -> (Vec<&Schedule>, Vec<f64>) {
    let mut ancestors = Vec::new();
    let mut scores = vec![nodes[cur].score];
    let mut up = nodes[cur].parent;
    while let Some(i) = up {
        if ancestors.len() >= depth {
            break;
        }
        ancestors.push(&nodes[i].schedule);
        scores.push(nodes[i].score);
        up = nodes[i].parent;
    }
    (ancestors, scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform, SurrogateModel};
    use crate::search::common::RandomPolicy;
    use crate::tir::workload::WorkloadId;

    fn run(budget: usize, seed: u64) -> SearchResult {
        let plat = Platform::core_i9();
        let base = WorkloadId::DeepSeekMoe.build();
        let surrogate = SurrogateModel { platform: plat.clone() };
        let hardware = HardwareModel { platform: plat.clone() };
        let mut policy = RandomPolicy::new(seed);
        mcts_search(
            &base,
            &mut policy,
            &surrogate,
            &hardware,
            &MctsConfig::default(),
            &plat,
            budget,
            seed,
        )
    }

    #[test]
    fn finds_improvement_with_modest_budget() {
        let r = run(60, 3);
        assert!(r.samples_used <= 60);
        assert!(
            r.best_speedup() > 1.5,
            "MCTS should beat baseline: {}",
            r.best_speedup()
        );
        assert!(!r.best_trace.is_empty());
    }

    #[test]
    fn curve_monotone_nondecreasing() {
        let r = run(40, 4);
        let mut prev = 0.0;
        for m in &r.curve {
            assert!(m.best_speedup >= prev);
            prev = m.best_speedup;
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run(25, 9);
        let b = run(25, 9);
        assert_eq!(a.best_latency, b.best_latency);
        assert_eq!(a.curve.len(), b.curve.len());
        let c = run(25, 10);
        assert_ne!(a.best_latency, c.best_latency);
    }

    #[test]
    fn best_trace_replays_to_best_latency() {
        let plat = Platform::core_i9();
        let base = WorkloadId::Llama4Mlp.build();
        let r = run_on(&base, &plat, 40, 5);
        let sched = Schedule::new(base.clone());
        let (best, applied) = sched.apply_all(&r.best_trace);
        assert_eq!(applied, r.best_trace.len(), "best trace must replay fully");
        // Replayed program must validate and beat baseline (noise-free).
        best.current.validate().unwrap();
        let hw = HardwareModel { platform: plat };
        assert!(hw.latency(&best.current, 0) < r.baseline_latency);
    }

    fn run_on(base: &Program, plat: &Platform, budget: usize, seed: u64) -> SearchResult {
        let surrogate = SurrogateModel { platform: plat.clone() };
        let hardware = HardwareModel { platform: plat.clone() };
        let mut policy = RandomPolicy::new(seed);
        mcts_search(
            base,
            &mut policy,
            &surrogate,
            &hardware,
            &MctsConfig::default(),
            plat,
            budget,
            seed,
        )
    }

    #[test]
    fn branching_limits_children() {
        // With B=1 the tree is a chain: every node except the frontier has
        // exactly one child. Indirectly verified via search still working.
        let plat = Platform::xeon_e3();
        let base = WorkloadId::FluxConv.build();
        let surrogate = SurrogateModel { platform: plat.clone() };
        let hardware = HardwareModel { platform: plat.clone() };
        let mut policy = RandomPolicy::new(2);
        let cfg = MctsConfig { branching: 1, ..Default::default() };
        let r = mcts_search(&base, &mut policy, &surrogate, &hardware, &cfg, &plat, 20, 2);
        assert!(r.samples_used <= 20);
        assert!(r.best_speedup() >= 1.0);
    }
}
