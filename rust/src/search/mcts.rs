//! Monte Carlo tree search over transformation sequences (§3.2).
//!
//! - **Selection**: UCT descent from the root (`c = sqrt(2)` by default).
//! - **Expansion**: the proposal policy (random for vanilla MCTS, the LLM
//!   reasoning engine for the REASONING COMPILER) suggests a transformation
//!   sequence, which is applied to create one new child node. Duplicate
//!   program states (by structural fingerprint) are not re-added, keeping
//!   the tree acyclic.
//! - **Rollout**: a short random continuation is scored with the surrogate
//!   f̂ — never the hardware model, matching the paper's cost-model-driven
//!   simulation.
//! - **Backpropagation**: normalized rewards and visit counts flow to the
//!   root.
//!
//! Each expanded child is additionally measured once on the hardware model,
//! consuming one sample of the budget (this is the paper's "evaluated
//! transformation proposals" axis).
//!
//! **Leaf parallelism** (`SearchContext::eval_batch > 1`): per iteration,
//! up to `eval_batch` leaves are selected and expanded under *virtual
//! loss* — each selected path temporarily gains visits without reward, so
//! consecutive selections within one batch diverge instead of piling onto
//! the same leaf. Each leaf's hardware measurement is **streamed onto the
//! persistent executor as leaves are selected** (the crate-internal
//! `PlannedBatch`): selection of leaf k+1 overlaps the measurement of
//! leaf k, and the executor's long-lived workers stay hot across
//! iterations instead of being respawned per batch. The plan
//! (cache probes, sample numbers, seeds) is laid down serially in
//! selection order and results fold by plan index, so with
//! `eval_batch = 1` the loop is the original serial search, bit-for-bit,
//! for any executor width.

use std::collections::{HashMap, HashSet};

use crate::cost::CostModel;
use crate::db::{program_fingerprint, MeasureCache};
use crate::obs;
use crate::schedule::{sampler, Schedule};
use crate::tir::Program;
use crate::util::json::{arr, num, s, Json};
use crate::util::rng::Pcg;

use super::common::{
    is_failed_measurement, replay_warm_entries, ProposalContext, ProposalPolicy, SearchContext,
    SearchResult, SearchStrategy, WarmStart,
};

/// MCTS hyperparameters (paper §4.1: c = sqrt(2), B = 2).
#[derive(Debug, Clone)]
pub struct MctsConfig {
    /// UCT exploration constant.
    pub exploration_c: f64,
    /// Branching factor: max children per node.
    pub branching: usize,
    /// Rollout depth (random continuation length).
    pub rollout_len: usize,
    /// History depth handed to the proposal policy (2 = parent+grandparent,
    /// 3 adds the great-grandparent; Figure 4b / Table 5 ablate this).
    pub history_depth: usize,
    /// Maximum transformation-sequence length (the horizon T of §2).
    pub max_trace_len: usize,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig {
            exploration_c: std::f64::consts::SQRT_2,
            branching: 2,
            rollout_len: 4,
            history_depth: 2,
            max_trace_len: 24,
        }
    }
}

struct Node {
    schedule: Schedule,
    parent: Option<usize>,
    children: Vec<usize>,
    /// Cumulative normalized reward.
    w: f64,
    /// Visit count.
    n: f64,
    /// Surrogate score (baseline_latency / f̂), cached for prompts.
    score: f64,
}

/// Run MCTS with the given proposal policy. `surrogate` scores rollouts;
/// `hardware` (inside `Evaluator`) measures expanded candidates and meters
/// the sample budget.
#[allow(clippy::too_many_arguments)]
pub fn mcts_search(
    base: &Program,
    policy: &mut dyn ProposalPolicy,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &MctsConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
) -> SearchResult {
    mcts_search_warm(
        base, policy, surrogate, hardware, cfg, platform, budget, seed, None, None,
    )
}

/// [`mcts_search`] with tuning-database support: `warm` traces are replayed
/// and inserted as root children before the first UCT iteration (the search
/// starts from the best-known frontier instead of an empty tree), and
/// `cache` answers re-measurements of known programs without consuming the
/// sample budget.
#[allow(clippy::too_many_arguments)]
pub fn mcts_search_warm(
    base: &Program,
    policy: &mut dyn ProposalPolicy,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &MctsConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
    warm: Option<&WarmStart>,
    cache: Option<MeasureCache>,
) -> SearchResult {
    let mut ctx = SearchContext::new(base, surrogate, hardware, platform, budget, seed);
    ctx.warm = warm;
    ctx.cache = cache.as_ref();
    MctsStrategy::new(cfg.clone(), policy).search(&ctx)
}

/// Extra visits (without reward) placed on a selected path while its leaf
/// awaits batched evaluation, steering the next in-batch selection toward
/// a different subtree. Removed before real backpropagation.
const VIRTUAL_LOSS: f64 = 1.0;

/// A newly expanded child whose hardware measurement is in flight on the
/// executor (submitted at selection time; folded at iteration end).
struct PendingLeaf {
    parent: usize,
    sched: Schedule,
    /// Expansion step at selection time (seeds the rollout scoring).
    step: usize,
    /// Node path leaf→root carrying this leaf's virtual loss.
    path: Vec<usize>,
    /// Who authored the edge: the proposal policy, or the random fallback
    /// taken when nothing it proposed applied (audit provenance).
    source: &'static str,
}

/// Rendered transforms of the edge `parent → child`: the trace suffix the
/// expansion added, in the registry's round-trippable format.
fn edge_transforms(sched: &Schedule, parent_len: usize) -> Json {
    arr(sched
        .trace
        .iter()
        .skip(parent_len)
        .map(|t| s(&crate::reasoning::engine::render_transform(t)))
        .collect())
}

/// MCTS behind the [`SearchStrategy`] interface, carrying its
/// hyperparameters and proposal policy. The policy is borrowed mutably so
/// the caller can read its accounting (LLM costs, fallbacks) after the run.
pub struct MctsStrategy<'p> {
    pub cfg: MctsConfig,
    pub policy: &'p mut dyn ProposalPolicy,
}

impl<'p> MctsStrategy<'p> {
    pub fn new(cfg: MctsConfig, policy: &'p mut dyn ProposalPolicy) -> MctsStrategy<'p> {
        MctsStrategy { cfg, policy }
    }
}

impl SearchStrategy for MctsStrategy<'_> {
    fn name(&self) -> String {
        format!("mcts[{}]", self.policy.name())
    }

    fn search(&mut self, ctx: &SearchContext) -> SearchResult {
        let cfg = &self.cfg;
        let mut rng = Pcg::new(ctx.seed);
        let mut ev = ctx.batch_evaluator();
        let surrogate_baseline = ctx.surrogate.latency(ctx.base, ctx.seed ^ 0xF0F0);

        let root_sched = Schedule::new(ctx.base.clone());
        let mut nodes = vec![Node {
            score: 1.0,
            schedule: root_sched,
            parent: None,
            children: Vec::new(),
            w: 0.0,
            n: 1e-9,
        }];
        // Tree dedup and the measurement cache share one structural hash
        // (`db::program_fingerprint`), computed once per candidate and handed
        // to the evaluator — hashing the program is on the per-sample hot path.
        let mut seen: HashSet<u64> = HashSet::new();
        seen.insert(program_fingerprint(&nodes[0].schedule.current));

        // Audit: the root anchors the reconstructed tree; its latency is
        // the measured baseline every reward attribution starts from.
        if obs::audit::armed() {
            let mut r = obs::audit::record("node", ctx.seed);
            r.set("id", num(0.0))
                .set("source", s("root"))
                .set("latency", num(ev.ev.baseline_latency))
                .set("step", num(0.0));
            obs::audit::emit(r);
        }

        let mut best_rollout_reward: f64 = 1.0;

        // ---- warm start: seed root children from the tuning database -------
        // Each known-good trace becomes a root child whose exploit weight is
        // proportional to its *measured* speedup (best warm entry = 1.0), so
        // UCT prefers the strongest recorded frontier instead of treating all
        // seeds as equally good. With a pre-populated cache these measurements
        // are free; without one they spend budget like any other candidate.
        // Tree dedup against `seen` (which holds the root fingerprint)
        // happens here, not in the replay helper, mirroring the serial
        // loop exactly — including its use of the *original* entry index
        // for surrogate seeds.
        let warm_children: Vec<_> = replay_warm_entries(&nodes[0].schedule, ctx.warm, usize::MAX)
            .into_iter()
            .filter(|r| seen.insert(r.fp))
            .collect();
        if !warm_children.is_empty() {
            let lats = {
                let cands: Vec<(&Schedule, u64)> =
                    warm_children.iter().map(|r| (&r.schedule, r.fp)).collect();
                ev.measure_batch_with_fingerprints(&cands)
            };
            let mut seeded: Vec<(usize, f64)> = Vec::new();
            let mut warm_lats: Vec<f64> = Vec::new();
            for (replay, lat) in warm_children.into_iter().zip(lats) {
                let Some(lat) = lat else { break };
                let (i, child_sched) = (replay.index, replay.schedule);
                let child_latency_hat = ctx
                    .surrogate
                    .latency(&child_sched.current, ctx.seed ^ 0x3A17 ^ (i as u64) << 8);
                ev.ev.record_calibration(child_latency_hat, lat);
                let score = surrogate_baseline / child_latency_hat;
                let child_id = nodes.len();
                nodes.push(Node {
                    schedule: child_sched,
                    parent: Some(0),
                    children: Vec::new(),
                    w: 0.0, // assigned below, normalized over all warm children
                    n: 1.0,
                    score,
                });
                nodes[0].children.push(child_id);
                nodes[0].n += 1.0;
                seeded.push((child_id, ev.ev.baseline_latency / lat));
                warm_lats.push(lat);
            }
            let best_speedup = seeded.iter().map(|&(_, s)| s).fold(0.0, f64::max);
            if best_speedup > 0.0 {
                for &(id, speedup) in &seeded {
                    let reward = speedup / best_speedup;
                    nodes[id].w = reward;
                    nodes[0].w += reward;
                }
            }
            // Audit: warm children are recorded after normalization so the
            // emitted reward matches the exploit weight UCT will see.
            if obs::audit::armed() {
                for (&(id, _), &lat) in seeded.iter().zip(warm_lats.iter()) {
                    let mut r = obs::audit::record("node", ctx.seed);
                    r.set("id", num(id as f64))
                        .set("parent", num(0.0))
                        .set("source", s("warm"))
                        .set("step", num(0.0))
                        .set("score", num(nodes[id].score))
                        .set("reward", num(nodes[id].w))
                        .set("transforms", edge_transforms(&nodes[id].schedule, 0));
                    if is_failed_measurement(lat) {
                        r.set("failed", Json::Bool(true));
                    } else {
                        r.set("latency", num(lat));
                    }
                    obs::audit::emit(r);
                }
            }
        }

        let batch_size = ctx.eval_batch.max(1);
        let mut step = 0usize;
        // Guard against saturation: on tiny programs every proposal can
        // duplicate an existing node; stop after too many sterile iterations.
        let mut sterile = 0usize;
        let mut no_legal_moves = false;

        while !ev.exhausted() && !no_legal_moves {
            if sterile > 200 {
                break;
            }
            // ---- collect a batch of fresh leaves under virtual loss --------
            let mut pending: Vec<PendingLeaf> = Vec::new();
            // In-flight expansions per parent: pending children are not in
            // the tree yet, so the branching limit must count them too.
            let mut pending_children: HashMap<usize, usize> = HashMap::new();
            // Leaves stream onto the executor as they are selected: the
            // batch plan (cache probes, sample numbers → seeds) is laid
            // down serially in selection order, while measurements run on
            // the persistent workers concurrently with later selections.
            // (A lone leaf — eval_batch = 1 — runs inline at fold instead:
            // the executor's lazy first dispatch keeps the serial default
            // free of any queue traffic.)
            let mut batch = ev.begin_batch();
            while pending.len() < batch_size && sterile <= 200 {
                step += 1;
                // ---- selection: UCT descent to an expandable node ----------
                let mut cur = 0usize;
                let mut saturated_in_flight = false;
                // Audit-only descent trail: built when armed, never read by
                // the descent itself.
                let mut sel_path: Vec<Json> = Vec::new();
                let select_span = obs::span(obs::EventKind::Select, step as u64);
                loop {
                    let node = &nodes[cur];
                    let in_flight = pending_children.get(&cur).copied().unwrap_or(0);
                    let expandable = node.children.len() + in_flight < cfg.branching
                        && node.schedule.trace.len() < cfg.max_trace_len;
                    if expandable || (node.children.is_empty() && in_flight == 0) {
                        break;
                    }
                    if node.children.is_empty() {
                        // Every slot here is taken by this batch's pending
                        // leaves and there is nothing to descend into yet:
                        // flush what we have and re-select next iteration.
                        saturated_in_flight = true;
                        break;
                    }
                    let ln_n = node.n.max(1.0).ln();
                    let mut best_child = node.children[0];
                    let mut best_uct = f64::NEG_INFINITY;
                    for &c in &node.children {
                        let ch = &nodes[c];
                        let uct = ch.w / ch.n.max(1e-9)
                            + cfg.exploration_c * (ln_n / ch.n.max(1e-9)).sqrt();
                        if uct > best_uct {
                            best_uct = uct;
                            best_child = c;
                        }
                    }
                    if obs::audit::armed() {
                        let ch = &nodes[best_child];
                        let mut e = Json::obj();
                        e.set("id", num(best_child as f64))
                            .set("visits", num(ch.n))
                            .set("q", num(ch.w / ch.n.max(1e-9)))
                            .set("ucb", num(best_uct));
                        sel_path.push(e);
                    }
                    cur = best_child;
                }
                drop(select_span);
                if obs::audit::armed() && !saturated_in_flight {
                    let mut r = obs::audit::record("select", ctx.seed);
                    r.set("step", num(step as f64))
                        .set("leaf", num(cur as f64))
                        .set("virtual_loss", num(if batch_size > 1 { VIRTUAL_LOSS } else { 0.0 }))
                        .set("path", arr(sel_path));
                    obs::audit::emit(r);
                }
                if saturated_in_flight {
                    break;
                }

                // ---- expansion: ask the policy for a transformation seq ----
                let (ancestors, scores) = ancestor_chain(&nodes, cur, cfg.history_depth);
                let proposal = {
                    let pctx = ProposalContext {
                        node: &nodes[cur].schedule,
                        ancestors,
                        scores,
                        platform: ctx.platform,
                        step,
                    };
                    let _sp = obs::span(obs::EventKind::Propose, nodes[cur].n as u64);
                    self.policy.propose(&pctx)
                };
                // Apply the proposal; if nothing applies, fall back to one
                // random legal transform (Appendix G's fallback path).
                let expand_span = obs::span(obs::EventKind::Expand, pending.len() as u64);
                let (mut child_sched, applied) = nodes[cur].schedule.apply_all(&proposal);
                let mut source = "policy";
                if applied == 0 {
                    source = "random-fallback";
                    match sampler::random_transform(&nodes[cur].schedule.current, &mut rng) {
                        Some(t) => match nodes[cur].schedule.apply(t) {
                            Ok(s) => child_sched = s,
                            Err(_) => continue,
                        },
                        None => {
                            no_legal_moves = true;
                            break;
                        }
                    }
                }
                drop(expand_span);

                // Dedup: if this program state already exists in the tree, do
                // not add it again (tree stays acyclic); still spend a visit.
                let fp = program_fingerprint(&child_sched.current);
                if !seen.insert(fp) {
                    nodes[cur].n += 1.0;
                    sterile += 1;
                    continue;
                }
                sterile = 0;

                // Plan + submit the leaf's measurement right now (the
                // dedup fingerprint doubles as the measurement-cache
                // key). A plan-time budget rejection means no further
                // leaf is affordable either — stop collecting; the outer
                // loop exits once the folded batch drains the budget.
                if !batch.submit(&child_sched, Some(fp)) {
                    break;
                }

                // Virtual loss: visits without reward along the selected
                // path, so the next selection of this batch diverges. A
                // batch of one never re-selects, so it skips the loss
                // entirely — add-then-subtract would leave float-rounding
                // residue in `n` and break bit-parity with the serial loop.
                let path = if batch_size > 1 {
                    let mut path = vec![cur];
                    let mut up = nodes[cur].parent;
                    while let Some(i) = up {
                        path.push(i);
                        up = nodes[i].parent;
                    }
                    for &i in &path {
                        nodes[i].n += VIRTUAL_LOSS;
                    }
                    path
                } else {
                    Vec::new()
                };
                *pending_children.entry(cur).or_insert(0) += 1;
                pending.push(PendingLeaf { parent: cur, sched: child_sched, step, path, source });
            }

            // Real statistics flow below; lift the provisional losses first.
            for p in &pending {
                for &i in &p.path {
                    nodes[i].n -= VIRTUAL_LOSS;
                }
            }

            // ---- fold the batch: one sample per fresh leaf -----------------
            // Waits for the in-flight measurements and folds them in
            // selection order — bit-identical to the serial loop.
            let lats = {
                let cands: Vec<&Schedule> = pending.iter().map(|p| &p.sched).collect();
                batch.finish(&cands)
            };
            if pending.is_empty() {
                continue; // saturated or out of legal moves; loop guards decide
            }

            for (leaf_idx, (p, lat)) in pending.into_iter().zip(lats).enumerate() {
                let Some(lat) = lat else {
                    break; // unreachable: every pending leaf was planned
                };
                let _sp = obs::span(obs::EventKind::Backprop, leaf_idx as u64);

                // A quarantined (failed) measurement: the leaf enters the
                // tree with a pessimistic zero reward — UCT steers away
                // from it but the search keeps going instead of unwinding
                // the batch. Ancestors gain the visit, no reward.
                if is_failed_measurement(lat) {
                    let child_latency_hat =
                        ctx.surrogate.latency(&p.sched.current, ctx.seed ^ (p.step as u64) << 1);
                    let parent_len = nodes[p.parent].schedule.trace.len();
                    let child_id = nodes.len();
                    nodes.push(Node {
                        schedule: p.sched,
                        parent: Some(p.parent),
                        children: Vec::new(),
                        w: 0.0,
                        n: 1.0,
                        score: surrogate_baseline / child_latency_hat,
                    });
                    nodes[p.parent].children.push(child_id);
                    let mut bp_path: Vec<Json> = Vec::new();
                    let mut up = Some(p.parent);
                    while let Some(i) = up {
                        nodes[i].n += 1.0;
                        if obs::audit::armed() {
                            bp_path.push(num(i as f64));
                        }
                        up = nodes[i].parent;
                    }
                    if obs::audit::armed() {
                        let mut r = obs::audit::record("node", ctx.seed);
                        r.set("id", num(child_id as f64))
                            .set("parent", num(p.parent as f64))
                            .set("source", s(p.source))
                            .set("step", num(p.step as f64))
                            .set("score", num(nodes[child_id].score))
                            .set("reward", num(0.0))
                            .set("failed", Json::Bool(true))
                            .set(
                                "transforms",
                                edge_transforms(&nodes[child_id].schedule, parent_len),
                            );
                        obs::audit::emit(r);
                        let mut b = obs::audit::record("backprop", ctx.seed);
                        b.set("leaf", num(child_id as f64))
                            .set("reward", num(0.0))
                            .set("visit_only", Json::Bool(true))
                            .set("path", arr(bp_path));
                        obs::audit::emit(b);
                    }
                    continue;
                }

                // ---- rollout: random continuation scored by the surrogate --
                let rollout_seq =
                    sampler::random_sequence(&p.sched.current, cfg.rollout_len, &mut rng);
                let (rollout_sched, _) = p.sched.apply_all(&rollout_seq);
                let rollout_latency =
                    ctx.surrogate.latency(&rollout_sched.current, ctx.seed ^ p.step as u64);
                // Direct surrogate score of the child itself (used in prompts).
                let child_latency_hat =
                    ctx.surrogate.latency(&p.sched.current, ctx.seed ^ (p.step as u64) << 1);
                let child_score = surrogate_baseline / child_latency_hat;
                // Calibration: this prediction justified spending the sample.
                ev.ev.record_calibration(child_latency_hat, lat);

                // Reward: speedup of the rollout terminal vs baseline,
                // normalized by the best rollout so far to keep UCT's exploit
                // term in [0, 1].
                let raw_reward = surrogate_baseline / rollout_latency;
                best_rollout_reward = best_rollout_reward.max(raw_reward);
                let reward = raw_reward / best_rollout_reward;

                // ---- insert + backpropagate --------------------------------
                let parent_len = nodes[p.parent].schedule.trace.len();
                let child_id = nodes.len();
                nodes.push(Node {
                    schedule: p.sched,
                    parent: Some(p.parent),
                    children: Vec::new(),
                    w: reward,
                    n: 1.0,
                    score: child_score,
                });
                nodes[p.parent].children.push(child_id);
                let mut bp_path: Vec<Json> = Vec::new();
                let mut up = Some(p.parent);
                while let Some(i) = up {
                    nodes[i].w += reward;
                    nodes[i].n += 1.0;
                    if obs::audit::armed() {
                        bp_path.push(num(i as f64));
                    }
                    up = nodes[i].parent;
                }
                if obs::audit::armed() {
                    let mut r = obs::audit::record("node", ctx.seed);
                    r.set("id", num(child_id as f64))
                        .set("parent", num(p.parent as f64))
                        .set("source", s(p.source))
                        .set("step", num(p.step as f64))
                        .set("score", num(child_score))
                        .set("reward", num(reward))
                        .set("latency", num(lat))
                        .set(
                            "transforms",
                            edge_transforms(&nodes[child_id].schedule, parent_len),
                        );
                    obs::audit::emit(r);
                    let mut b = obs::audit::record("backprop", ctx.seed);
                    b.set("leaf", num(child_id as f64))
                        .set("reward", num(reward))
                        .set("visit_only", Json::Bool(false))
                        .set("path", arr(bp_path));
                    obs::audit::emit(b);
                }
            }
        }

        let name = self.name();
        ev.into_result(&name, &ctx.base.name, ctx.platform.name)
    }
}

/// Collect up to `depth` ancestors (nearest first) and surrogate scores
/// aligned with [node, ancestors...].
fn ancestor_chain(
    nodes: &[Node],
    cur: usize,
    depth: usize,
) -> (Vec<&Schedule>, Vec<f64>) {
    let mut ancestors = Vec::new();
    let mut scores = vec![nodes[cur].score];
    let mut up = nodes[cur].parent;
    while let Some(i) = up {
        if ancestors.len() >= depth {
            break;
        }
        ancestors.push(&nodes[i].schedule);
        scores.push(nodes[i].score);
        up = nodes[i].parent;
    }
    (ancestors, scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform, SurrogateModel};
    use crate::search::common::RandomPolicy;
    use crate::tir::workload::WorkloadId;

    fn run(budget: usize, seed: u64) -> SearchResult {
        let plat = Platform::core_i9();
        let base = WorkloadId::DeepSeekMoe.build();
        let surrogate = SurrogateModel::new(plat.clone());
        let hardware = HardwareModel::new(plat.clone());
        let mut policy = RandomPolicy::new(seed);
        mcts_search(
            &base,
            &mut policy,
            &surrogate,
            &hardware,
            &MctsConfig::default(),
            &plat,
            budget,
            seed,
        )
    }

    #[test]
    fn finds_improvement_with_modest_budget() {
        let r = run(60, 3);
        assert!(r.samples_used <= 60);
        assert!(
            r.best_speedup() > 1.5,
            "MCTS should beat baseline: {}",
            r.best_speedup()
        );
        assert!(!r.best_trace.is_empty());
    }

    #[test]
    fn curve_monotone_nondecreasing() {
        let r = run(40, 4);
        let mut prev = 0.0;
        for m in &r.curve {
            assert!(m.best_speedup >= prev);
            prev = m.best_speedup;
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run(25, 9);
        let b = run(25, 9);
        assert_eq!(a.best_latency, b.best_latency);
        assert_eq!(a.curve.len(), b.curve.len());
        let c = run(25, 10);
        assert_ne!(a.best_latency, c.best_latency);
    }

    #[test]
    fn best_trace_replays_to_best_latency() {
        let plat = Platform::core_i9();
        let base = WorkloadId::Llama4Mlp.build();
        let r = run_on(&base, &plat, 40, 5);
        let sched = Schedule::new(base.clone());
        let (best, applied) = sched.apply_all(&r.best_trace);
        assert_eq!(applied, r.best_trace.len(), "best trace must replay fully");
        // Replayed program must validate and beat baseline (noise-free).
        best.current.validate().unwrap();
        let hw = HardwareModel::new(plat);
        assert!(hw.latency(&best.current, 0) < r.baseline_latency);
    }

    fn run_on(base: &Program, plat: &Platform, budget: usize, seed: u64) -> SearchResult {
        let surrogate = SurrogateModel::new(plat.clone());
        let hardware = HardwareModel::new(plat.clone());
        let mut policy = RandomPolicy::new(seed);
        mcts_search(
            base,
            &mut policy,
            &surrogate,
            &hardware,
            &MctsConfig::default(),
            plat,
            budget,
            seed,
        )
    }

    #[test]
    fn branching_limits_children() {
        // With B=1 the tree is a chain: every node except the frontier has
        // exactly one child. Indirectly verified via search still working.
        let plat = Platform::xeon_e3();
        let base = WorkloadId::FluxConv.build();
        let surrogate = SurrogateModel::new(plat.clone());
        let hardware = HardwareModel::new(plat.clone());
        let mut policy = RandomPolicy::new(2);
        let cfg = MctsConfig { branching: 1, ..Default::default() };
        let r = mcts_search(&base, &mut policy, &surrogate, &hardware, &cfg, &plat, 20, 2);
        assert!(r.samples_used <= 20);
        assert!(r.best_speedup() >= 1.0);
    }
}
