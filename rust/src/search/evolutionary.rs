//! Evolutionary Search — the TVM MetaSchedule baseline.
//!
//! Mirrors MetaSchedule's evolutionary tuner: a population of transformation
//! traces evolves by tournament selection, trace mutation (append / drop /
//! re-parameterize) and prefix crossover; each generation is ranked by the
//! surrogate cost model and the top candidates are measured on hardware
//! (consuming samples). Uninformed but robust — the sample-inefficient
//! black-box baseline of the paper's comparison.

use crate::cost::CostModel;
use crate::db::MeasureCache;
use crate::obs;
use crate::schedule::{sampler, Schedule, Transform};
use crate::tir::Program;
use crate::util::json::num;
use crate::util::rng::Pcg;

use super::common::{
    is_failed_measurement, replay_warm_entries, SearchContext, SearchResult, SearchStrategy,
    WarmStart,
};

#[derive(Debug, Clone)]
pub struct EvoConfig {
    pub population: usize,
    /// Hardware measurements per generation (MetaSchedule's
    /// `num_trials_per_iter`).
    pub measure_per_gen: usize,
    /// Initial random-trace length.
    pub init_len: usize,
    pub max_trace_len: usize,
    /// Probability of mutation (vs crossover) when producing offspring.
    pub mutation_prob: f64,
    /// Tournament size for parent selection.
    pub tournament: usize,
}

impl Default for EvoConfig {
    fn default() -> Self {
        EvoConfig {
            population: 64,
            measure_per_gen: 16,
            init_len: 4,
            max_trace_len: 24,
            mutation_prob: 0.7,
            tournament: 4,
        }
    }
}

struct Member {
    schedule: Schedule,
    /// Surrogate fitness: baseline / f̂ (higher better).
    fitness: f64,
}

/// Run evolutionary search until the hardware budget is exhausted.
pub fn evolutionary_search(
    base: &Program,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &EvoConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
) -> SearchResult {
    evolutionary_search_warm(base, surrogate, hardware, cfg, platform, budget, seed, None, None)
}

/// [`evolutionary_search`] with tuning-database support: up to half the
/// initial population is seeded from `warm` traces (the rest stays random
/// for diversity), and `cache` answers re-measurements of known programs —
/// including the elites this tuner re-measures every generation — without
/// consuming the sample budget.
#[allow(clippy::too_many_arguments)]
pub fn evolutionary_search_warm(
    base: &Program,
    surrogate: &dyn CostModel,
    hardware: &dyn CostModel,
    cfg: &EvoConfig,
    platform: &crate::cost::Platform,
    budget: usize,
    seed: u64,
    warm: Option<&WarmStart>,
    cache: Option<MeasureCache>,
) -> SearchResult {
    let mut ctx = SearchContext::new(base, surrogate, hardware, platform, budget, seed);
    ctx.warm = warm;
    ctx.cache = cache.as_ref();
    EvolutionaryStrategy::new(cfg.clone()).search(&ctx)
}

/// Evolutionary Search behind the [`SearchStrategy`] interface. The
/// per-generation measurement slice goes through the batched evaluation
/// pipeline (streamed onto `SearchContext::executor`): since the slice's
/// membership is fixed by surrogate ranking *before* any hardware runs,
/// results are bit-identical for every executor width — parallelism here
/// is pure wall-clock. (`SearchContext::eval_batch` is ignored; the
/// generation slice is the natural batch.)
pub struct EvolutionaryStrategy {
    pub cfg: EvoConfig,
}

impl EvolutionaryStrategy {
    pub fn new(cfg: EvoConfig) -> EvolutionaryStrategy {
        EvolutionaryStrategy { cfg }
    }
}

impl SearchStrategy for EvolutionaryStrategy {
    fn name(&self) -> String {
        "evolutionary".to_string()
    }

    fn search(&mut self, ctx: &SearchContext) -> SearchResult {
        let cfg = &self.cfg;
        let mut rng = Pcg::new(ctx.seed ^ 0xE5_0E_5E);
        let mut ev = ctx.batch_evaluator();
        let surrogate_baseline = ctx.surrogate.latency(ctx.base, ctx.seed ^ 0xF0F0);
        let base_sched = Schedule::new(ctx.base.clone());

        // ---- initial population: warm traces first, random fill ------------
        // Duplicates among warm replays are kept as extra population mass
        // (the pre-trait serial behavior, pinned by the workers=1 parity
        // contract); the fitness seed counts pushed members.
        let mut population: Vec<Member> = Vec::with_capacity(cfg.population);
        for replay in replay_warm_entries(&base_sched, ctx.warm, cfg.population / 2) {
            let schedule = replay.schedule;
            let fitness = surrogate_baseline
                / ctx
                    .surrogate
                    .latency(&schedule.current, ctx.seed ^ (0x5EED + population.len() as u64));
            population.push(Member { schedule, fitness });
        }
        while population.len() < cfg.population {
            let i = population.len();
            let len = 1 + rng.gen_range(cfg.init_len);
            let seq = sampler::random_sequence(&base_sched.current, len, &mut rng);
            let (schedule, _) = base_sched.apply_all(&seq);
            let fitness = surrogate_baseline
                / ctx.surrogate.latency(&schedule.current, ctx.seed ^ (i as u64 + 1));
            population.push(Member { schedule, fitness });
        }

        let mut gen = 0u64;
        // With a cache, a whole generation's measurement slice can be answered
        // for free (elites recur); bound consecutive zero-sample generations so
        // the loop cannot spin without spending budget.
        let mut stalled_gens = 0usize;
        while !ev.exhausted() {
            gen += 1;
            // ---- measure the surrogate-best slice on hardware --------------
            let mut order: Vec<usize> = (0..population.len()).collect();
            order.sort_by(|&a, &b| {
                population[b]
                    .fitness
                    .partial_cmp(&population[a].fitness)
                    .unwrap()
            });
            let used_before = ev.ev.used;
            let lats = {
                let slice: Vec<&Schedule> = order
                    .iter()
                    .take(cfg.measure_per_gen)
                    .map(|&i| &population[i].schedule)
                    .collect();
                ev.measure_batch(&slice)
            };
            // Calibration: the surrogate fitness that earned each member
            // its slot in the measured slice doubles as the prediction
            // (fitness = baseline / f̂, so f̂ = baseline / fitness).
            for (k, l) in lats.iter().enumerate() {
                if let Some(lat) = l {
                    let fit = population[order[k]].fitness;
                    if fit > 0.0 {
                        ev.ev.record_calibration(surrogate_baseline / fit, *lat);
                    }
                }
            }
            let failed: Vec<usize> = lats
                .iter()
                .enumerate()
                .filter(|(_, l)| matches!(l, Some(x) if is_failed_measurement(*x)))
                .map(|(k, _)| order[k])
                .collect();
            let n_failed = failed.len();
            // Quarantined measurements (injected faults) poison the member:
            // worst-possible fitness, so it cannot survive as an elite or
            // win a tournament — the ES analog of MCTS's zero-reward
            // backprop. Empty in every stock run.
            for i in failed {
                population[i].fitness = 0.0;
            }
            // Audit: one record per generation — the ES analog of the MCTS
            // node/backprop stream.
            if obs::audit::armed() {
                let mut r = obs::audit::record("gen", ctx.seed);
                r.set("gen", num(gen as f64))
                    .set("measured", num((ev.ev.used - used_before) as f64))
                    .set("population", num(population.len() as f64))
                    .set("best_fitness", num(population[order[0]].fitness))
                    .set("best_latency", num(ev.ev.best_latency))
                    .set("failed", num(n_failed as f64));
                obs::audit::emit(r);
            }
            if ev.ev.used == used_before {
                stalled_gens += 1;
                if stalled_gens > 50 {
                    break;
                }
            } else {
                stalled_gens = 0;
            }
            if ev.exhausted() {
                break;
            }

            // ---- next generation -------------------------------------------
            let elite_n = (cfg.population / 8).max(1);
            let mut next: Vec<Member> = Vec::with_capacity(cfg.population);
            for &i in order.iter().take(elite_n) {
                next.push(Member {
                    schedule: population[i].schedule.clone(),
                    fitness: population[i].fitness,
                });
            }
            while next.len() < cfg.population {
                let parent_a = tournament_pick(&population, cfg.tournament, &mut rng);
                let child_trace = if rng.gen_bool(cfg.mutation_prob) {
                    mutate(&population[parent_a].schedule, cfg, &mut rng)
                } else {
                    let parent_b = tournament_pick(&population, cfg.tournament, &mut rng);
                    crossover(
                        &population[parent_a].schedule,
                        &population[parent_b].schedule,
                        &mut rng,
                    )
                };
                let (schedule, _) = base_sched.apply_all(&child_trace);
                let fitness = surrogate_baseline
                    / ctx
                        .surrogate
                        .latency(&schedule.current, ctx.seed ^ gen << 16 ^ next.len() as u64);
                next.push(Member { schedule, fitness });
            }
            population = next;
        }

        ev.into_result("evolutionary", &ctx.base.name, ctx.platform.name)
    }
}

fn tournament_pick(population: &[Member], k: usize, rng: &mut Pcg) -> usize {
    let mut best = rng.gen_range(population.len());
    for _ in 1..k {
        let c = rng.gen_range(population.len());
        if population[c].fitness > population[best].fitness {
            best = c;
        }
    }
    best
}

/// Trace mutation: drop the tail, append random transforms, or both.
fn mutate(parent: &Schedule, cfg: &EvoConfig, rng: &mut Pcg) -> Vec<Transform> {
    let mut trace = parent.trace.to_vec();
    match rng.gen_range(3) {
        0 if !trace.is_empty() => {
            // Drop a random-length tail.
            let keep = rng.gen_range(trace.len());
            trace.truncate(keep);
        }
        1 if !trace.is_empty() => {
            // Drop tail then regrow.
            let keep = rng.gen_range(trace.len());
            trace.truncate(keep);
        }
        _ => {}
    }
    // Append 1-2 random transforms legal in context (applied later via
    // apply_all, which tolerates an illegal tail).
    let base = Schedule::new_shared(parent.base.clone());
    let (ctx_sched, _) = base.apply_all(&trace);
    let grow = 1 + rng.gen_range(2);
    let seq = sampler::random_sequence(&ctx_sched.current, grow, rng);
    trace.extend(seq);
    trace.truncate(cfg.max_trace_len);
    trace
}

/// Prefix crossover: a prefix of one parent + the suffix of the other.
/// Illegal suffix elements are dropped by `apply_all` later.
fn crossover(a: &Schedule, b: &Schedule, rng: &mut Pcg) -> Vec<Transform> {
    if a.trace.is_empty() {
        return b.trace.to_vec();
    }
    let cut_a = rng.gen_range(a.trace.len() + 1);
    let mut child: Vec<Transform> = a.trace.iter().take(cut_a).cloned().collect();
    if !b.trace.is_empty() {
        let cut_b = rng.gen_range(b.trace.len());
        child.extend(b.trace.iter().skip(cut_b).cloned());
    }
    child
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform, SurrogateModel};
    use crate::tir::workload::WorkloadId;

    fn run(budget: usize, seed: u64) -> SearchResult {
        let plat = Platform::core_i9();
        let base = WorkloadId::DeepSeekMoe.build();
        let surrogate = SurrogateModel::new(plat.clone());
        let hardware = HardwareModel::new(plat.clone());
        evolutionary_search(
            &base,
            &surrogate,
            &hardware,
            &EvoConfig::default(),
            &plat,
            budget,
            seed,
        )
    }

    #[test]
    fn improves_over_baseline() {
        let r = run(120, 1);
        assert!(r.best_speedup() > 1.5, "ES speedup {}", r.best_speedup());
        assert!(r.samples_used <= 120);
    }

    #[test]
    fn respects_budget_exactly() {
        let r = run(37, 2);
        assert_eq!(r.samples_used, 37);
        assert_eq!(r.curve.len(), 37);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run(40, 5);
        let b = run(40, 5);
        assert_eq!(a.best_latency, b.best_latency);
    }

    #[test]
    fn best_trace_replays() {
        let r = run(60, 3);
        let base = WorkloadId::DeepSeekMoe.build();
        let sched = Schedule::new(base);
        let (best, applied) = sched.apply_all(&r.best_trace);
        assert_eq!(applied, r.best_trace.len());
        best.current.validate().unwrap();
    }
}
