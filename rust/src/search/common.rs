//! Shared search infrastructure: proposal policies, sample accounting,
//! convergence curves and the strategy interface.
//!
//! The pieces compose bottom-up: [`Evaluator`] meters the hardware budget
//! one candidate at a time; [`BatchEvaluator`] plans candidates against
//! the cache and budget, streams the needed hardware measurements onto
//! the crate's persistent [`Executor`] (as a crate-internal
//! `PlannedBatch`), and folds
//! results back in deterministic candidate order; [`SearchStrategy`] is
//! the uniform entry point (`MctsStrategy`, `EvolutionaryStrategy`) over a
//! [`SearchContext`] carrying the models, budget, warm-start hints, the
//! executor handle and the `eval_batch` knob.
//!
//! Determinism contract: a serial executor with `eval_batch = 1`
//! reproduces the original serial search bit-for-bit; widening the
//! executor never changes results (only wall-clock) because every
//! measurement's seed is fixed at plan time and outputs fold by plan
//! index, never completion order; raising `eval_batch` changes the MCTS
//! trajectory (leaf parallelism) but stays bit-reproducible per seed.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cost::{CalibrationStats, CostModel, Platform};
use crate::db::{program_fingerprint, MeasureCache};
use crate::obs;
use crate::schedule::{Schedule, Transform};
use crate::tir::Program;
use crate::util::executor::{Executor, TaskGroup};
use crate::util::faults;
use crate::util::rng::Pcg;

pub use crate::db::WarmStart;

/// Sentinel latency of a *failed* (quarantined) measurement: an injected
/// or real hardware failure spends its sample but yields no usable
/// number. Infinity can never become best-so-far, is never cached or
/// committed, and strategies treat it pessimistically (MCTS backprops a
/// zero reward, ES assigns worst fitness). Only ever produced under an
/// armed fault plan (`util::faults`).
pub const FAILED_MEASUREMENT: f64 = f64::INFINITY;

/// Is this latency the quarantined-failure sentinel?
#[inline]
pub fn is_failed_measurement(lat: f64) -> bool {
    lat.is_infinite()
}

/// Everything one search run needs, bundled so strategies share a uniform
/// signature. Build with [`SearchContext::new`] and override the optional
/// fields (`warm`, `cache`, `executor`, `eval_batch`) as needed.
pub struct SearchContext<'a> {
    pub base: &'a Program,
    /// Rollout surrogate f̂ (never consumes samples).
    pub surrogate: &'a dyn CostModel,
    /// Hardware model f (every invocation consumes one sample).
    pub hardware: &'a dyn CostModel,
    pub platform: &'a Platform,
    /// Hardware-measurement budget (samples).
    pub budget: usize,
    pub seed: u64,
    /// Known-good traces from the tuning database, seeded into the MCTS
    /// root frontier / the evolutionary initial population.
    pub warm: Option<&'a WarmStart>,
    /// Measurement cache consulted before spending samples. The run
    /// evaluates through a private deep copy (see `MeasureCache::clone`)
    /// so concurrent runs stay independently deterministic.
    pub cache: Option<&'a MeasureCache>,
    /// Evaluate through a *shared* handle on `cache` instead of a private
    /// deep copy, so this run's measurements are visible to every other
    /// run sharing the same cache (and vice versa). Opt-in
    /// (`--share-repeat-cache`): pooling measurements across a session's
    /// repeats saves samples but deliberately breaks the repeats'
    /// independence contract — a repeat may answer from another repeat's
    /// measurement instead of its own seeded one.
    pub shared_cache: bool,
    /// The persistent executor batched hardware evaluation streams onto.
    /// Defaults to [`Executor::serial`] (inline, no threads); sessions
    /// hand every run one shared session-wide executor so nested sites
    /// (repeats × `eval_batch` × concurrently tuned models) share one
    /// core budget instead of multiplying thread pools. The executor
    /// width never changes results — only wall-clock.
    pub executor: Arc<Executor>,
    /// Candidates expanded and measured per MCTS iteration (leaf-parallel
    /// batch width). 1 = the original serial trajectory. Evolutionary
    /// search ignores this: its natural batch is the per-generation
    /// measurement slice.
    pub eval_batch: usize,
}

impl<'a> SearchContext<'a> {
    pub fn new(
        base: &'a Program,
        surrogate: &'a dyn CostModel,
        hardware: &'a dyn CostModel,
        platform: &'a Platform,
        budget: usize,
        seed: u64,
    ) -> SearchContext<'a> {
        SearchContext {
            base,
            surrogate,
            hardware,
            platform,
            budget,
            seed,
            warm: None,
            cache: None,
            shared_cache: false,
            executor: Executor::serial(),
            eval_batch: 1,
        }
    }

    /// A budget evaluator for this run (with the cache attached when the
    /// context has one): a private deep copy by default, a shared handle
    /// when [`SearchContext::shared_cache`] opts in.
    pub fn evaluator(&self) -> Evaluator<'a> {
        match self.cache {
            Some(c) => Evaluator::with_cache(
                self.hardware,
                self.base,
                self.budget,
                self.seed,
                if self.shared_cache { c.share() } else { c.clone() },
                self.platform.name,
            ),
            None => Evaluator::new(self.hardware, self.base, self.budget, self.seed),
        }
    }

    /// The batched evaluation pipeline for this run: [`Self::evaluator`]
    /// streaming its hardware measurements onto `self.executor`.
    pub fn batch_evaluator(&self) -> BatchEvaluator<'a> {
        BatchEvaluator { ev: self.evaluator(), executor: Arc::clone(&self.executor) }
    }
}

/// A search engine behind a uniform interface: MCTS (vanilla or
/// LLM-guided, via the [`ProposalPolicy`] it carries) and Evolutionary
/// Search. The coordinator dispatches through this trait; the legacy free
/// functions (`mcts_search*`, `evolutionary_search*`) are thin wrappers
/// that build a serial [`SearchContext`].
pub trait SearchStrategy {
    /// Strategy label recorded in [`SearchResult::strategy`].
    fn name(&self) -> String;
    /// Run the search to budget exhaustion (or saturation).
    fn search(&mut self, ctx: &SearchContext) -> SearchResult;
}

/// One warm-start trace replayed onto the base program, ready for seeding.
pub struct WarmReplay {
    /// Index of the source entry in `WarmStart::entries` (gaps from
    /// non-replayable entries preserved — MCTS derives surrogate seeds
    /// from this, exactly as the pre-trait serial code did).
    pub index: usize,
    pub schedule: Schedule,
    /// `db::program_fingerprint` of the replayed program.
    pub fp: u64,
    /// The entry's recorded latency.
    pub known_latency: f64,
}

/// Replay warm-start traces onto a fresh schedule of the base program,
/// dropping entries that no longer apply (partial replays are kept, like
/// any other candidate). Returns at most `max` replayed entries,
/// best-recorded-first. Deliberately does NOT deduplicate: MCTS dedups
/// against its tree fingerprints and evolutionary search keeps duplicates
/// as extra population mass — both exactly as the pre-trait serial code
/// behaved, which the serial-executor bit-parity contract pins. Shared by
/// both strategies so the replay logic cannot drift between them.
pub fn replay_warm_entries(
    base_sched: &Schedule,
    warm: Option<&WarmStart>,
    max: usize,
) -> Vec<WarmReplay> {
    let mut out = Vec::new();
    let Some(ws) = warm else { return out };
    for (index, (trace, known_latency)) in ws.entries.iter().enumerate() {
        if out.len() >= max {
            break;
        }
        let (schedule, applied) = base_sched.apply_all(trace);
        if applied == 0 {
            continue;
        }
        let fp = program_fingerprint(&schedule.current);
        out.push(WarmReplay { index, schedule, fp, known_latency: *known_latency });
    }
    out
}

/// Context handed to a proposal policy at expansion time: the selected node,
/// its ancestor chain (parent first), and their predicted scores — exactly
/// the information the paper serializes into the LLM prompt (§3.1).
pub struct ProposalContext<'a> {
    /// The node being expanded.
    pub node: &'a Schedule,
    /// Ancestors, nearest first (parent, grandparent, ...), truncated to the
    /// configured history depth.
    pub ancestors: Vec<&'a Schedule>,
    /// Predicted performance scores (higher = better) aligned with
    /// [node, ancestors...].
    pub scores: Vec<f64>,
    pub platform: &'a Platform,
    /// Monotone counter of expansions so far (lets stateful policies vary).
    pub step: usize,
}

/// A proposal policy suggests the transformation sequence for one MCTS
/// expansion. Implemented by the random policy (vanilla MCTS) and the
/// LLM reasoning engine (`crate::reasoning`).
pub trait ProposalPolicy {
    /// Propose a transformation sequence for the node in `ctx`. May return
    /// an empty vector; the search then falls back to a random transform.
    fn propose(&mut self, ctx: &ProposalContext) -> Vec<Transform>;
    fn name(&self) -> String;
}

/// Vanilla-MCTS expansion policy: one random legal transform.
pub struct RandomPolicy {
    pub rng: Pcg,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        RandomPolicy { rng: Pcg::new(seed) }
    }
}

impl ProposalPolicy for RandomPolicy {
    fn propose(&mut self, ctx: &ProposalContext) -> Vec<Transform> {
        // A short random sequence (1-4 steps): expansion edges are
        // transformation sequences, mirroring the LLM-guided variant.
        let len = 1 + self.rng.gen_range(4);
        crate::schedule::sampler::random_sequence(&ctx.node.current, len, &mut self.rng)
    }
    fn name(&self) -> String {
        "random".to_string()
    }
}

/// One hardware measurement in the search log.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// 1-based index of this sample.
    pub sample: usize,
    /// Measured latency (seconds) on the hardware model.
    pub latency: f64,
    /// Best speedup over the unoptimized baseline after this sample.
    pub best_speedup: f64,
    /// Trace length of the measured candidate.
    pub trace_len: usize,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub strategy: String,
    pub workload: String,
    pub platform: String,
    pub baseline_latency: f64,
    pub best_latency: f64,
    pub best_trace: Vec<Transform>,
    /// Full measurement log (the convergence curve).
    pub curve: Vec<Measurement>,
    pub samples_used: usize,
    /// Candidate evaluations answered by the measurement cache (no sample
    /// consumed). 0 when the run had no cache attached.
    pub cache_hits: usize,
    /// Candidate evaluations that fell through to the hardware model.
    pub cache_misses: usize,
    /// Hardware measurements that failed and were quarantined (sample
    /// spent, nothing cached or recorded). Always 0 without a fault plan.
    pub failed_measurements: usize,
    /// Cost-model calibration: the surrogate prediction that justified
    /// each measured sample vs the measured latency, aggregated into a
    /// residual summary (always on — recording costs two adds per fold).
    pub calibration: CalibrationStats,
}

impl SearchResult {
    pub fn best_speedup(&self) -> f64 {
        self.baseline_latency / self.best_latency
    }

    /// Best speedup achieved within the first `samples` measurements
    /// (the quantity plotted in Figure 3 / tabulated in Table 3).
    pub fn speedup_at(&self, samples: usize) -> f64 {
        self.curve
            .iter()
            .take_while(|m| m.sample <= samples)
            .map(|m| m.best_speedup)
            .fold(1.0, f64::max)
    }

    /// Fewest samples needed to reach `target` speedup, if ever reached.
    pub fn samples_to_reach(&self, target: f64) -> Option<usize> {
        self.curve
            .iter()
            .find(|m| m.best_speedup >= target)
            .map(|m| m.sample)
    }
}

/// Tracks the hardware-measurement budget and the convergence curve.
/// Measuring a candidate consumes one sample — the unit of the paper's
/// x-axes and of Table 1/2's "# Samples".
pub struct Evaluator<'a> {
    pub hardware: &'a dyn CostModel,
    pub baseline_latency: f64,
    pub budget: usize,
    pub used: usize,
    pub best_latency: f64,
    pub best_trace: Vec<Transform>,
    pub curve: Vec<Measurement>,
    seed: u64,
    /// Optional measurement cache (`db::MeasureCache`): when attached, a
    /// candidate whose program fingerprint is already known costs zero
    /// samples. `None` preserves the original every-measure-spends
    /// semantics.
    cache: Option<MeasureCache>,
    /// Platform name used in cache keys (empty when no cache is attached).
    platform_name: String,
    /// Evaluations answered by the cache (no hardware sample consumed).
    cache_hits: usize,
    /// Evaluations that invoked the hardware model. Counted here, not in
    /// the cache, so misses always equal actual hardware invocations (an
    /// exhausted-budget bail-out is neither).
    cache_misses: usize,
    /// Quarantined (failed) measurements so far.
    failed: usize,
    /// Per-run failure budget: once this many measurements have failed,
    /// the run reports exhaustion and stops rather than burning the whole
    /// sample budget against a broken measurement target.
    failure_budget: usize,
    /// Predicted-vs-measured residuals of this run's folded samples.
    calibration: CalibrationStats,
}

impl<'a> Evaluator<'a> {
    pub fn new(hardware: &'a dyn CostModel, base: &Program, budget: usize, seed: u64) -> Self {
        let baseline_latency = hardware.latency(base, seed ^ 0xBA5E);
        Evaluator {
            hardware,
            baseline_latency,
            budget,
            used: 0,
            best_latency: baseline_latency,
            best_trace: Vec::new(),
            curve: Vec::new(),
            seed,
            cache: None,
            platform_name: String::new(),
            cache_hits: 0,
            cache_misses: 0,
            failed: 0,
            failure_budget: budget / 4 + 8,
            calibration: CalibrationStats::default(),
        }
    }

    /// Like [`Evaluator::new`], but measurements go through `cache` first.
    /// The cache may arrive pre-populated from the tuning database, which
    /// is how warm-started runs re-evaluate known schedules for free.
    pub fn with_cache(
        hardware: &'a dyn CostModel,
        base: &Program,
        budget: usize,
        seed: u64,
        cache: MeasureCache,
        platform: &str,
    ) -> Self {
        let mut ev = Evaluator::new(hardware, base, budget, seed);
        ev.cache = Some(cache);
        ev.platform_name = platform.to_string();
        ev
    }

    pub fn exhausted(&self) -> bool {
        self.used >= self.budget || self.failed >= self.failure_budget
    }

    /// Quarantined (failed) measurements so far. Always 0 without an
    /// armed fault plan.
    pub fn failed_count(&self) -> usize {
        self.failed
    }

    /// Whether a measurement cache is attached (batch planning needs to
    /// know whether fingerprints are worth computing).
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Cache accounting so far (hits, misses); (0, 0) without a cache.
    pub fn cache_counts(&self) -> (usize, usize) {
        (self.cache_hits, self.cache_misses)
    }

    /// Record one cost-model calibration pair: the surrogate latency that
    /// justified spending this sample vs the measured latency. Strictly
    /// accounting — never feeds back into the search (determinism), and
    /// quarantine sentinels are ignored inside [`CalibrationStats`].
    pub fn record_calibration(&mut self, predicted: f64, measured: f64) {
        self.calibration.record(predicted, measured);
        // Audit: the predicted-vs-measured pair behind this sample.
        if obs::audit::armed() {
            use crate::util::json::{num, Json};
            let mut r = obs::audit::record("measure", self.seed);
            r.set("sample", num(self.used as f64)).set("predicted", num(predicted));
            if measured.is_finite() {
                r.set("latency", num(measured));
            } else {
                r.set("failed", Json::Bool(true));
            }
            obs::audit::emit(r);
        }
    }

    /// Evaluate a candidate. A measurement-cache hit returns the known
    /// latency without consuming a sample; otherwise the hardware model is
    /// invoked and one sample of the budget is spent. Returns None when a
    /// hardware measurement is needed but the budget is exhausted.
    pub fn measure(&mut self, candidate: &Schedule) -> Option<f64> {
        let fp = self
            .cache
            .is_some()
            .then(|| program_fingerprint(&candidate.current));
        self.measure_inner(candidate, fp)
    }

    /// Like [`Evaluator::measure`], with the candidate's
    /// `db::program_fingerprint` already computed — callers that fingerprint
    /// anyway (MCTS tree dedup) avoid hashing the program twice per sample.
    pub fn measure_with_fingerprint(&mut self, candidate: &Schedule, fp: u64) -> Option<f64> {
        self.measure_inner(candidate, Some(fp))
    }

    fn measure_inner(&mut self, candidate: &Schedule, fp: Option<u64>) -> Option<f64> {
        let lat = if let (Some(cache), Some(fp)) = (&mut self.cache, fp) {
            let known = cache.get(fp, &self.platform_name);
            obs::instant(obs::EventKind::CacheProbe, known.is_some() as u64);
            match known {
                Some(known) => {
                    self.cache_hits += 1;
                    known
                }
                None => {
                    if self.used >= self.budget {
                        return None;
                    }
                    self.cache_misses += 1;
                    self.used += 1;
                    if faults::measure_fault(self.seed.wrapping_add(self.used as u64)) {
                        return Some(self.quarantine(self.used));
                    }
                    let _sp = obs::span(obs::EventKind::Measure, self.used as u64);
                    let lat = self
                        .hardware
                        .latency(&candidate.current, self.seed.wrapping_add(self.used as u64));
                    cache.insert(fp, &self.platform_name, lat);
                    lat
                }
            }
        } else {
            if self.exhausted() {
                return None;
            }
            self.used += 1;
            if faults::measure_fault(self.seed.wrapping_add(self.used as u64)) {
                return Some(self.quarantine(self.used));
            }
            let _sp = obs::span(obs::EventKind::Measure, self.used as u64);
            self.hardware
                .latency(&candidate.current, self.seed.wrapping_add(self.used as u64))
        };
        self.record(candidate, lat);
        Some(lat)
    }

    /// Fold a failed measurement: the sample is spent and the failure
    /// charged against the failure budget, but nothing enters the cache,
    /// the curve or best-so-far — the candidate simply has no usable
    /// number, and the caller receives the [`FAILED_MEASUREMENT`]
    /// sentinel to score pessimistically.
    fn quarantine(&mut self, sample: usize) -> f64 {
        self.failed += 1;
        obs::instant(obs::EventKind::MeasureFail, sample as u64);
        FAILED_MEASUREMENT
    }

    /// Fold one resolved measurement into best-so-far and the curve.
    /// Cache hits log at the current sample count (no sample consumed),
    /// so a warm start can reach a target speedup "at sample 0".
    fn record(&mut self, candidate: &Schedule, lat: f64) {
        if lat < self.best_latency {
            self.best_latency = lat;
            self.best_trace = candidate.trace.to_vec();
        }
        self.curve.push(Measurement {
            sample: self.used,
            latency: lat,
            best_speedup: self.baseline_latency / self.best_latency,
            trace_len: candidate.trace.len(),
        });
    }

    pub fn into_result(self, strategy: &str, workload: &str, platform: &str) -> SearchResult {
        let (cache_hits, cache_misses) = self.cache_counts();
        SearchResult {
            strategy: strategy.to_string(),
            workload: workload.to_string(),
            platform: platform.to_string(),
            baseline_latency: self.baseline_latency,
            best_latency: self.best_latency,
            best_trace: self.best_trace,
            curve: self.curve,
            samples_used: self.used,
            cache_hits,
            cache_misses,
            failed_measurements: self.failed,
            calibration: self.calibration,
        }
    }
}

/// How one candidate of a batch resolves against cache and budget,
/// decided serially at plan time so the parallel fan-out cannot affect
/// accounting order.
enum BatchPlan {
    /// Already in the cache: free, latency known at plan time.
    Hit(f64),
    /// Needs a hardware measurement; `job` indexes the fan-out results.
    /// `fp` is the candidate's fingerprint, kept for the cache insert at
    /// fold time (None when the caller evaluates fingerprint-less).
    Miss { job: usize, fp: Option<u64> },
    /// Same fingerprint as an earlier miss in this batch: free once that
    /// job resolves (the serial loop would hit the just-inserted entry).
    HitOfMiss { job: usize },
    /// The measurement fails (injected fault, decided at plan time from
    /// the plan-time seed): the sample is spent but quarantined — never
    /// cached, never recorded. Only occurs under an armed fault plan.
    Failed,
}

/// The batched evaluation pipeline: wraps an [`Evaluator`], plans
/// candidates against the measurement cache and remaining budget, streams
/// the required hardware measurements onto the persistent [`Executor`],
/// then folds results back in candidate order.
///
/// Results are bit-identical to calling [`Evaluator::measure`] on each
/// candidate in order (with callers breaking at the first `None`), for
/// every executor width: each measurement's sample number — and therefore
/// its seed — is assigned serially at plan time, and outputs land by plan
/// index, never completion order.
pub struct BatchEvaluator<'a> {
    pub ev: Evaluator<'a>,
    /// The persistent executor the hardware measurements stream onto (a
    /// serial executor runs them inline — the exact serial path).
    executor: Arc<Executor>,
}

impl<'a> BatchEvaluator<'a> {
    pub fn new(ev: Evaluator<'a>, executor: Arc<Executor>) -> BatchEvaluator<'a> {
        BatchEvaluator { ev, executor }
    }

    pub fn exhausted(&self) -> bool {
        self.ev.exhausted()
    }

    pub fn into_result(self, strategy: &str, workload: &str, platform: &str) -> SearchResult {
        self.ev.into_result(strategy, workload, platform)
    }

    /// Start a streaming batch: candidates are planned — and their
    /// hardware measurements submitted to the executor — one at a time as
    /// [`PlannedBatch::submit`] is called, so callers (leaf-parallel MCTS)
    /// overlap candidate selection with measurement. Finish with
    /// [`PlannedBatch::finish`] to fold results in submission order.
    ///
    /// Crate-private like `Executor::group`: the in-flight batch holds
    /// borrowing tasks and is only sound while never leaked before
    /// `finish`/drop — in-crate callers uphold that; external users get
    /// [`BatchEvaluator::measure_batch`].
    pub(crate) fn begin_batch<'s>(&'s mut self) -> PlannedBatch<'s, 'a> {
        let group = self.executor.group();
        PlannedBatch {
            ev: &mut self.ev,
            group,
            plans: Vec::new(),
            fp_to_job: HashMap::new(),
            n_jobs: 0,
            n_submitted: 0,
            exhausted: false,
        }
    }

    /// Evaluate a batch of candidates. Fingerprints are computed here when
    /// a cache is attached (as [`Evaluator::measure`] would).
    pub fn measure_batch(&mut self, candidates: &[&Schedule]) -> Vec<Option<f64>> {
        let fps: Option<Vec<u64>> = self
            .ev
            .has_cache()
            .then(|| candidates.iter().map(|c| program_fingerprint(&c.current)).collect());
        self.measure_batch_inner(candidates, fps.as_deref())
    }

    /// Like [`BatchEvaluator::measure_batch`] with fingerprints already
    /// computed (MCTS fingerprints every candidate for tree dedup anyway).
    pub fn measure_batch_with_fingerprints(
        &mut self,
        candidates: &[(&Schedule, u64)],
    ) -> Vec<Option<f64>> {
        let scheds: Vec<&Schedule> = candidates.iter().map(|&(s, _)| s).collect();
        let fps: Vec<u64> = candidates.iter().map(|&(_, fp)| fp).collect();
        self.measure_batch_inner(&scheds, Some(&fps))
    }

    /// Returned vector is aligned with `candidates`; a `None` means the
    /// budget could not afford that candidate's measurement, and (matching
    /// the serial break-on-`None` pattern) every later candidate is also
    /// `None` — unevaluated, even if it would have been a cache hit.
    fn measure_batch_inner(
        &mut self,
        candidates: &[&Schedule],
        fps: Option<&[u64]>,
    ) -> Vec<Option<f64>> {
        let mut batch = self.begin_batch();
        for (i, c) in candidates.iter().enumerate() {
            if !batch.submit(c, fps.map(|f| f[i])) {
                break; // budget exhausted: this and all later candidates are None
            }
        }
        batch.finish(candidates)
    }
}

/// An in-flight evaluation batch (see [`BatchEvaluator::begin_batch`]).
///
/// `submit` lays down the plan serially in call order — cache probe,
/// in-batch duplicate detection, sample-number (and therefore seed)
/// assignment — and immediately streams any needed hardware measurement
/// onto the executor, where persistent workers pick it up while the
/// caller keeps selecting candidates. `finish` waits for the group and
/// folds in submission order, making the whole pipeline bit-identical to
/// the serial measure loop for every executor width.
pub(crate) struct PlannedBatch<'s, 'a> {
    ev: &'s mut Evaluator<'a>,
    group: TaskGroup<'a, f64>,
    plans: Vec<BatchPlan>,
    fp_to_job: HashMap<u64, usize>,
    /// Samples this batch has planned (executor jobs + quarantined
    /// failures) — the budget and sample-number accounting unit.
    n_jobs: usize,
    /// Executor jobs actually submitted (indexes the fan-out results).
    n_submitted: usize,
    exhausted: bool,
}

impl<'s, 'a> PlannedBatch<'s, 'a> {
    /// Plan one candidate and (on a cache miss) submit its hardware
    /// measurement. Returns `false` — leaving the candidate unplanned —
    /// once the remaining budget cannot afford another measurement; the
    /// serial contract then makes every later candidate unevaluated too,
    /// so callers should stop submitting.
    pub(crate) fn submit(&mut self, candidate: &Schedule, fp: Option<u64>) -> bool {
        if self.exhausted {
            return false;
        }
        let ev = &mut *self.ev;
        let cached = match (ev.cache.as_ref(), fp) {
            (Some(cache), Some(fp)) => {
                let probe = match cache.get(fp, &ev.platform_name) {
                    Some(known) => Some(BatchPlan::Hit(known)),
                    None => self.fp_to_job.get(&fp).map(|&j| BatchPlan::HitOfMiss { job: j }),
                };
                obs::instant(obs::EventKind::CacheProbe, probe.is_some() as u64);
                probe
            }
            _ => None,
        };
        obs::instant(obs::EventKind::Plan, self.plans.len() as u64);
        let plan = match cached {
            Some(p) => p,
            None => {
                if ev.used + self.n_jobs >= ev.budget {
                    self.exhausted = true;
                    return false;
                }
                let sample = ev.used + self.n_jobs + 1;
                self.n_jobs += 1;
                let seed = ev.seed.wrapping_add(sample as u64);
                // The fault roll keys on the plan-time seed, so an
                // injected failure schedule is identical at every worker
                // count and batch width (a no-op load when disarmed).
                if faults::measure_fault(seed) {
                    obs::instant(obs::EventKind::MeasureFail, sample as u64);
                    BatchPlan::Failed
                } else {
                    let job = self.n_submitted;
                    self.n_submitted += 1;
                    obs::instant(obs::EventKind::Submit, sample as u64);
                    // The job owns a CoW clone of the program (a handful of
                    // Arc bumps): the caller's candidate storage may move or
                    // grow while the measurement is in flight.
                    let hw = ev.hardware;
                    let prog = candidate.current.clone();
                    self.group.submit(move || {
                        // The span's `arg` is the plan-time sample number, so
                        // a workers=N trace diffs against workers=1 by index.
                        let _sp = obs::span(obs::EventKind::Measure, sample as u64);
                        hw.latency(&prog, seed)
                    });
                    if let Some(f) = fp {
                        self.fp_to_job.insert(f, job);
                    }
                    BatchPlan::Miss { job, fp }
                }
            }
        };
        self.plans.push(plan);
        true
    }

    /// Wait for the in-flight measurements and fold everything in
    /// submission order. `candidates` must be the submitted schedules in
    /// submission order (it may extend past the plans — those trailing
    /// candidates, rejected by budget at plan time, fold to `None`).
    pub(crate) fn finish(self, candidates: &[&Schedule]) -> Vec<Option<f64>> {
        debug_assert!(candidates.len() >= self.plans.len());
        let measured = self.group.wait();
        let _sp = obs::span(obs::EventKind::Fold, self.n_jobs as u64);
        let ev = self.ev;
        let mut out: Vec<Option<f64>> = Vec::with_capacity(candidates.len());
        for (i, plan) in self.plans.iter().enumerate() {
            let lat = match *plan {
                BatchPlan::Hit(known) => {
                    ev.cache_hits += 1;
                    known
                }
                BatchPlan::HitOfMiss { job } => {
                    ev.cache_hits += 1;
                    measured[job]
                }
                BatchPlan::Miss { job, fp } => {
                    let lat = measured[job];
                    ev.used += 1;
                    if let (Some(cache), Some(f)) = (&ev.cache, fp) {
                        ev.cache_misses += 1;
                        cache.insert(f, &ev.platform_name, lat);
                    }
                    lat
                }
                BatchPlan::Failed => {
                    // Quarantine: the sample is spent and the failure
                    // charged, but nothing is cached or recorded — the
                    // caller sees the sentinel and scores pessimistically.
                    ev.used += 1;
                    if ev.cache.is_some() {
                        ev.cache_misses += 1;
                    }
                    ev.failed += 1;
                    out.push(Some(FAILED_MEASUREMENT));
                    continue;
                }
            };
            ev.record(candidates[i], lat);
            out.push(Some(lat));
        }
        out.resize(candidates.len(), None);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform};
    use crate::tir::workload::WorkloadId;

    #[test]
    fn evaluator_budget_and_best_tracking() {
        let hw = HardwareModel::new(Platform::core_i9());
        let base = WorkloadId::DeepSeekMoe.build_test();
        let mut ev = Evaluator::new(&hw, &base, 3, 7);
        let sched = Schedule::new(base.clone());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_none(), "budget exhausted");
        assert_eq!(ev.used, 3);
        let r = ev.into_result("test", "w", "p");
        assert_eq!(r.curve.len(), 3);
        assert!(r.best_speedup() > 0.5);
    }

    #[test]
    fn cached_reevaluation_consumes_zero_samples() {
        let hw = HardwareModel::new(Platform::core_i9());
        let base = WorkloadId::DeepSeekMoe.build_test();
        let mut ev =
            Evaluator::with_cache(&hw, &base, 5, 7, MeasureCache::new(), "core_i9");
        let sched = Schedule::new(base.clone())
            .apply(crate::schedule::Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        let first = ev.measure(&sched).unwrap();
        assert_eq!(ev.used, 1, "first evaluation spends a sample");
        // Second evaluation of the identical candidate: cache hit, zero
        // additional samples, same latency.
        let second = ev.measure(&sched).unwrap();
        assert_eq!(second, first);
        assert_eq!(ev.used, 1, "cache hit must not consume a sample");
        assert_eq!(ev.cache_counts(), (1, 1));
        let r = ev.into_result("t", "w", "core_i9");
        assert_eq!(r.cache_hits, 1);
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.samples_used, 1);
    }

    #[test]
    fn prepopulated_cache_answers_before_any_sample() {
        let hw = HardwareModel::new(Platform::core_i9());
        let base = WorkloadId::Llama4Mlp.build_test();
        let sched = Schedule::new(base.clone())
            .apply(crate::schedule::Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        let cache = MeasureCache::new();
        cache.insert(program_fingerprint(&sched.current), "core_i9", 0.125);
        let mut ev = Evaluator::with_cache(&hw, &base, 5, 7, cache, "core_i9");
        assert_eq!(ev.measure(&sched), Some(0.125));
        assert_eq!(ev.used, 0, "warm hit costs nothing");
        assert_eq!(ev.curve.len(), 1);
        assert_eq!(ev.curve[0].sample, 0);
    }

    #[test]
    fn shared_cache_pools_measurements_across_evaluators() {
        let hw = HardwareModel::new(Platform::core_i9());
        let base = WorkloadId::DeepSeekMoe.build_test();
        let plat = Platform::core_i9();
        let pool = MeasureCache::new();
        let sched = Schedule::new(base.clone())
            .apply(crate::schedule::Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();

        // Default (private clone): the second evaluator re-measures.
        let mut ctx = SearchContext::new(&base, &hw, &hw, &plat, 5, 7);
        ctx.cache = Some(&pool);
        let mut ev1 = ctx.evaluator();
        ev1.measure(&sched).unwrap();
        assert_eq!(ev1.cache_counts(), (0, 1));
        let mut ev2 = ctx.evaluator();
        ev2.measure(&sched).unwrap();
        assert_eq!(
            ev2.cache_counts(),
            (0, 1),
            "private clones must not leak measurements between runs"
        );
        assert!(pool.is_empty(), "clones never write back to the session pool");

        // Opt-in sharing: the second evaluator answers from the first's
        // measurement without spending a sample.
        ctx.shared_cache = true;
        let mut ev3 = ctx.evaluator();
        let first = ev3.measure(&sched).unwrap();
        assert_eq!(ev3.cache_counts(), (0, 1));
        assert_eq!(pool.len(), 1, "shared handle writes into the pool");
        let mut ev4 = ctx.evaluator();
        let second = ev4.measure(&sched).unwrap();
        assert_eq!(second, first);
        assert_eq!(
            ev4.cache_counts(),
            (1, 0),
            "a pooled measurement must answer the repeat for free"
        );
        assert_eq!(ev4.used, 0);
    }

    #[test]
    fn speedup_at_monotone() {
        let hw = HardwareModel::new(Platform::core_i9());
        let base = WorkloadId::Llama4Mlp.build_test();
        let mut ev = Evaluator::new(&hw, &base, 10, 1);
        let mut rng = Pcg::new(5);
        let sched = Schedule::new(base.clone());
        for _ in 0..10 {
            let seq = crate::schedule::sampler::random_sequence(&sched.current, 3, &mut rng);
            let (s, _) = sched.apply_all(&seq);
            ev.measure(&s);
        }
        let r = ev.into_result("t", "w", "p");
        assert!(r.speedup_at(10) >= r.speedup_at(3));
        assert!(r.speedup_at(3) >= r.speedup_at(1));
    }

    #[test]
    fn random_policy_proposes_legal() {
        let base = WorkloadId::FluxConv.build_test();
        let sched = Schedule::new(base);
        let plat = Platform::core_i9();
        let mut pol = RandomPolicy::new(3);
        let ctx = ProposalContext {
            node: &sched,
            ancestors: vec![],
            scores: vec![1.0],
            platform: &plat,
            step: 0,
        };
        let ts = pol.propose(&ctx);
        assert!((1..=4).contains(&ts.len()));
        // The whole sequence must apply in order.
        let (out, applied) = sched.apply_all(&ts);
        assert_eq!(applied, ts.len());
        out.current.validate().unwrap();
    }
}
