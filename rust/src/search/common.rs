//! Shared search infrastructure: proposal policies, sample accounting,
//! convergence curves and the strategy interface.

use crate::cost::{CostModel, Platform};
use crate::db::{program_fingerprint, MeasureCache};
use crate::schedule::{Schedule, Transform};
use crate::tir::Program;
use crate::util::rng::Pcg;

pub use crate::db::WarmStart;

/// Context handed to a proposal policy at expansion time: the selected node,
/// its ancestor chain (parent first), and their predicted scores — exactly
/// the information the paper serializes into the LLM prompt (§3.1).
pub struct ProposalContext<'a> {
    /// The node being expanded.
    pub node: &'a Schedule,
    /// Ancestors, nearest first (parent, grandparent, ...), truncated to the
    /// configured history depth.
    pub ancestors: Vec<&'a Schedule>,
    /// Predicted performance scores (higher = better) aligned with
    /// [node, ancestors...].
    pub scores: Vec<f64>,
    pub platform: &'a Platform,
    /// Monotone counter of expansions so far (lets stateful policies vary).
    pub step: usize,
}

/// A proposal policy suggests the transformation sequence for one MCTS
/// expansion. Implemented by the random policy (vanilla MCTS) and the
/// LLM reasoning engine (`crate::reasoning`).
pub trait ProposalPolicy {
    /// Propose a transformation sequence for the node in `ctx`. May return
    /// an empty vector; the search then falls back to a random transform.
    fn propose(&mut self, ctx: &ProposalContext) -> Vec<Transform>;
    fn name(&self) -> String;
}

/// Vanilla-MCTS expansion policy: one random legal transform.
pub struct RandomPolicy {
    pub rng: Pcg,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        RandomPolicy { rng: Pcg::new(seed) }
    }
}

impl ProposalPolicy for RandomPolicy {
    fn propose(&mut self, ctx: &ProposalContext) -> Vec<Transform> {
        // A short random sequence (1-4 steps): expansion edges are
        // transformation sequences, mirroring the LLM-guided variant.
        let len = 1 + self.rng.gen_range(4);
        crate::schedule::sampler::random_sequence(&ctx.node.current, len, &mut self.rng)
    }
    fn name(&self) -> String {
        "random".to_string()
    }
}

/// One hardware measurement in the search log.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// 1-based index of this sample.
    pub sample: usize,
    /// Measured latency (seconds) on the hardware model.
    pub latency: f64,
    /// Best speedup over the unoptimized baseline after this sample.
    pub best_speedup: f64,
    /// Trace length of the measured candidate.
    pub trace_len: usize,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub strategy: String,
    pub workload: String,
    pub platform: String,
    pub baseline_latency: f64,
    pub best_latency: f64,
    pub best_trace: Vec<Transform>,
    /// Full measurement log (the convergence curve).
    pub curve: Vec<Measurement>,
    pub samples_used: usize,
    /// Candidate evaluations answered by the measurement cache (no sample
    /// consumed). 0 when the run had no cache attached.
    pub cache_hits: usize,
    /// Candidate evaluations that fell through to the hardware model.
    pub cache_misses: usize,
}

impl SearchResult {
    pub fn best_speedup(&self) -> f64 {
        self.baseline_latency / self.best_latency
    }

    /// Best speedup achieved within the first `samples` measurements
    /// (the quantity plotted in Figure 3 / tabulated in Table 3).
    pub fn speedup_at(&self, samples: usize) -> f64 {
        self.curve
            .iter()
            .take_while(|m| m.sample <= samples)
            .map(|m| m.best_speedup)
            .fold(1.0, f64::max)
    }

    /// Fewest samples needed to reach `target` speedup, if ever reached.
    pub fn samples_to_reach(&self, target: f64) -> Option<usize> {
        self.curve
            .iter()
            .find(|m| m.best_speedup >= target)
            .map(|m| m.sample)
    }
}

/// Tracks the hardware-measurement budget and the convergence curve.
/// Measuring a candidate consumes one sample — the unit of the paper's
/// x-axes and of Table 1/2's "# Samples".
pub struct Evaluator<'a> {
    pub hardware: &'a dyn CostModel,
    pub baseline_latency: f64,
    pub budget: usize,
    pub used: usize,
    pub best_latency: f64,
    pub best_trace: Vec<Transform>,
    pub curve: Vec<Measurement>,
    seed: u64,
    /// Optional measurement cache (`db::MeasureCache`): when attached, a
    /// candidate whose program fingerprint is already known costs zero
    /// samples. `None` preserves the original every-measure-spends
    /// semantics.
    cache: Option<MeasureCache>,
    /// Platform name used in cache keys (empty when no cache is attached).
    platform_name: String,
    /// Evaluations answered by the cache (no hardware sample consumed).
    cache_hits: usize,
    /// Evaluations that invoked the hardware model. Counted here, not in
    /// the cache, so misses always equal actual hardware invocations (an
    /// exhausted-budget bail-out is neither).
    cache_misses: usize,
}

impl<'a> Evaluator<'a> {
    pub fn new(hardware: &'a dyn CostModel, base: &Program, budget: usize, seed: u64) -> Self {
        let baseline_latency = hardware.latency(base, seed ^ 0xBA5E);
        Evaluator {
            hardware,
            baseline_latency,
            budget,
            used: 0,
            best_latency: baseline_latency,
            best_trace: Vec::new(),
            curve: Vec::new(),
            seed,
            cache: None,
            platform_name: String::new(),
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Like [`Evaluator::new`], but measurements go through `cache` first.
    /// The cache may arrive pre-populated from the tuning database, which
    /// is how warm-started runs re-evaluate known schedules for free.
    pub fn with_cache(
        hardware: &'a dyn CostModel,
        base: &Program,
        budget: usize,
        seed: u64,
        cache: MeasureCache,
        platform: &str,
    ) -> Self {
        let mut ev = Evaluator::new(hardware, base, budget, seed);
        ev.cache = Some(cache);
        ev.platform_name = platform.to_string();
        ev
    }

    pub fn exhausted(&self) -> bool {
        self.used >= self.budget
    }

    /// Cache accounting so far (hits, misses); (0, 0) without a cache.
    pub fn cache_counts(&self) -> (usize, usize) {
        (self.cache_hits, self.cache_misses)
    }

    /// Evaluate a candidate. A measurement-cache hit returns the known
    /// latency without consuming a sample; otherwise the hardware model is
    /// invoked and one sample of the budget is spent. Returns None when a
    /// hardware measurement is needed but the budget is exhausted.
    pub fn measure(&mut self, candidate: &Schedule) -> Option<f64> {
        let fp = self
            .cache
            .is_some()
            .then(|| program_fingerprint(&candidate.current));
        self.measure_inner(candidate, fp)
    }

    /// Like [`Evaluator::measure`], with the candidate's
    /// `db::program_fingerprint` already computed — callers that fingerprint
    /// anyway (MCTS tree dedup) avoid hashing the program twice per sample.
    pub fn measure_with_fingerprint(&mut self, candidate: &Schedule, fp: u64) -> Option<f64> {
        self.measure_inner(candidate, Some(fp))
    }

    fn measure_inner(&mut self, candidate: &Schedule, fp: Option<u64>) -> Option<f64> {
        let lat = if let (Some(cache), Some(fp)) = (&mut self.cache, fp) {
            match cache.get(fp, &self.platform_name) {
                Some(known) => {
                    self.cache_hits += 1;
                    known
                }
                None => {
                    if self.used >= self.budget {
                        return None;
                    }
                    self.cache_misses += 1;
                    self.used += 1;
                    let lat = self
                        .hardware
                        .latency(&candidate.current, self.seed.wrapping_add(self.used as u64));
                    cache.insert(fp, &self.platform_name, lat);
                    lat
                }
            }
        } else {
            if self.exhausted() {
                return None;
            }
            self.used += 1;
            self.hardware
                .latency(&candidate.current, self.seed.wrapping_add(self.used as u64))
        };
        if lat < self.best_latency {
            self.best_latency = lat;
            self.best_trace = candidate.trace.clone();
        }
        // Cache hits log at the current sample count (no sample consumed),
        // so a warm start can reach a target speedup "at sample 0".
        self.curve.push(Measurement {
            sample: self.used,
            latency: lat,
            best_speedup: self.baseline_latency / self.best_latency,
            trace_len: candidate.trace.len(),
        });
        Some(lat)
    }

    pub fn into_result(self, strategy: &str, workload: &str, platform: &str) -> SearchResult {
        let (cache_hits, cache_misses) = self.cache_counts();
        SearchResult {
            strategy: strategy.to_string(),
            workload: workload.to_string(),
            platform: platform.to_string(),
            baseline_latency: self.baseline_latency,
            best_latency: self.best_latency,
            best_trace: self.best_trace,
            curve: self.curve,
            samples_used: self.used,
            cache_hits,
            cache_misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform};
    use crate::tir::workload::WorkloadId;

    #[test]
    fn evaluator_budget_and_best_tracking() {
        let hw = HardwareModel { platform: Platform::core_i9() };
        let base = WorkloadId::DeepSeekMoe.build_test();
        let mut ev = Evaluator::new(&hw, &base, 3, 7);
        let sched = Schedule::new(base.clone());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_some());
        assert!(ev.measure(&sched).is_none(), "budget exhausted");
        assert_eq!(ev.used, 3);
        let r = ev.into_result("test", "w", "p");
        assert_eq!(r.curve.len(), 3);
        assert!(r.best_speedup() > 0.5);
    }

    #[test]
    fn cached_reevaluation_consumes_zero_samples() {
        let hw = HardwareModel { platform: Platform::core_i9() };
        let base = WorkloadId::DeepSeekMoe.build_test();
        let mut ev =
            Evaluator::with_cache(&hw, &base, 5, 7, MeasureCache::new(), "core_i9");
        let sched = Schedule::new(base.clone())
            .apply(crate::schedule::Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        let first = ev.measure(&sched).unwrap();
        assert_eq!(ev.used, 1, "first evaluation spends a sample");
        // Second evaluation of the identical candidate: cache hit, zero
        // additional samples, same latency.
        let second = ev.measure(&sched).unwrap();
        assert_eq!(second, first);
        assert_eq!(ev.used, 1, "cache hit must not consume a sample");
        assert_eq!(ev.cache_counts(), (1, 1));
        let r = ev.into_result("t", "w", "core_i9");
        assert_eq!(r.cache_hits, 1);
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.samples_used, 1);
    }

    #[test]
    fn prepopulated_cache_answers_before_any_sample() {
        let hw = HardwareModel { platform: Platform::core_i9() };
        let base = WorkloadId::Llama4Mlp.build_test();
        let sched = Schedule::new(base.clone())
            .apply(crate::schedule::Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        let mut cache = MeasureCache::new();
        cache.insert(program_fingerprint(&sched.current), "core_i9", 0.125);
        let mut ev = Evaluator::with_cache(&hw, &base, 5, 7, cache, "core_i9");
        assert_eq!(ev.measure(&sched), Some(0.125));
        assert_eq!(ev.used, 0, "warm hit costs nothing");
        assert_eq!(ev.curve.len(), 1);
        assert_eq!(ev.curve[0].sample, 0);
    }

    #[test]
    fn speedup_at_monotone() {
        let hw = HardwareModel { platform: Platform::core_i9() };
        let base = WorkloadId::Llama4Mlp.build_test();
        let mut ev = Evaluator::new(&hw, &base, 10, 1);
        let mut rng = Pcg::new(5);
        let sched = Schedule::new(base.clone());
        for _ in 0..10 {
            let seq = crate::schedule::sampler::random_sequence(&sched.current, 3, &mut rng);
            let (s, _) = sched.apply_all(&seq);
            ev.measure(&s);
        }
        let r = ev.into_result("t", "w", "p");
        assert!(r.speedup_at(10) >= r.speedup_at(3));
        assert!(r.speedup_at(3) >= r.speedup_at(1));
    }

    #[test]
    fn random_policy_proposes_legal() {
        let base = WorkloadId::FluxConv.build_test();
        let sched = Schedule::new(base);
        let plat = Platform::core_i9();
        let mut pol = RandomPolicy::new(3);
        let ctx = ProposalContext {
            node: &sched,
            ancestors: vec![],
            scores: vec![1.0],
            platform: &plat,
            step: 0,
        };
        let ts = pol.propose(&ctx);
        assert!((1..=4).contains(&ts.len()));
        // The whole sequence must apply in order.
        let (out, applied) = sched.apply_all(&ts);
        assert_eq!(applied, ts.len());
        out.current.validate().unwrap();
    }
}
