//! Minimal deterministic worker pool over scoped threads.
//!
//! One shape serves every parallel site in the crate (batched cost-model
//! evaluation, session repeats, concurrent model tuning): split a slice of
//! work items into contiguous chunks, one scoped thread per chunk. The
//! partition depends only on `(len, workers)`, so per-item outputs written
//! through the items land identically for every worker count — the
//! determinism contract of the parallel pipeline rests on this.

use std::thread;

/// Run `f` over disjoint contiguous chunks of `items`, on up to `workers`
/// scoped threads. `workers <= 1` (or a single item) runs `f` inline on
/// the whole slice — the exact serial path, no threads spawned. A panic
/// in any chunk propagates to the caller (scoped threads re-raise on
/// join).
pub fn scoped_chunks<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(&mut [T]) + Sync,
{
    if items.is_empty() {
        return;
    }
    let threads = workers.max(1).min(items.len());
    if threads == 1 {
        f(items);
        return;
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    thread::scope(|scope| {
        for batch in items.chunks_mut(chunk) {
            scope.spawn(move || f(batch));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_item_exactly_once_for_any_worker_count() {
        for workers in [0, 1, 2, 3, 7, 64] {
            let mut items: Vec<usize> = vec![0; 23];
            scoped_chunks(&mut items, workers, |batch| {
                for x in batch.iter_mut() {
                    *x += 1;
                }
            });
            assert!(items.iter().all(|&x| x == 1), "workers={workers}: {items:?}");
        }
    }

    #[test]
    fn empty_input_is_a_no_op() {
        let mut items: Vec<u8> = Vec::new();
        scoped_chunks(&mut items, 4, |_| panic!("must not be called"));
    }
}
