//! Micro-benchmark timing harness (criterion is unavailable offline).
//!
//! Benches in `rust/benches/` are plain `main()` binaries (`harness = false`)
//! that use `Bencher` for wall-clock measurement of hot paths and the table
//! regenerators for paper experiments.

use std::time::{Duration, Instant};

use super::stats;

/// One measured benchmark: warms up, then runs timed batches until the
/// target measurement time has elapsed, reporting per-iteration statistics.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub throughput_per_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
        }
    }

    /// Run `f` repeatedly; returns per-iteration timing stats. The closure's
    /// return value is consumed with `std::hint::black_box` to prevent the
    /// optimizer from deleting the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup phase: also estimates per-iteration cost to pick batch size.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
        // Batch so each sample is >= ~100us to keep timer overhead <1%.
        let batch = ((100_000.0 / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.measure || total_iters < self.min_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            total_iters += batch;
            if samples_ns.len() > 10_000 {
                break;
            }
        }

        let mean_ns = stats::mean(&samples_ns);
        BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns,
            stddev_ns: stats::stddev(&samples_ns),
            median_ns: stats::median(&samples_ns),
            throughput_per_s: if mean_ns > 0.0 { 1e9 / mean_ns } else { 0.0 },
        }
    }
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} /iter  (median {}, sd {}, {:.0}/s, {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.stddev_ns),
            self.throughput_per_s,
            self.iters
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
        };
        let r = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("us"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
