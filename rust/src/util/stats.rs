//! Statistics helpers used by the report generators and bench harness.

/// Arithmetic mean. Returns 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over strictly-positive values (values <= 0 are skipped,
/// matching how the paper's geomean rows treat ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator). 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (averages the two central elements for even n).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Running summary of a stream of observations (used by serving metrics).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { count: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let n = self.count as f64;
        ((self.sum_sq - self.sum * self.sum / n) / (n - 1.0)).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_skips_nonpositive() {
        let g = geomean(&[2.0, 0.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_sorts_unsorted_input() {
        // Callers hand over raw buffers (e.g. an unsorted latency
        // reservoir); percentile must not assume order or mutate input.
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
        assert_eq!(xs, [9.0, 1.0, 5.0, 3.0, 7.0], "input untouched");
    }

    #[test]
    fn summary_stream() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            s.record(x);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.stddev() - 1.0).abs() < 1e-12);
    }
}
