//! Minimal JSON value model + writer/parser.
//!
//! serde/serde_json are unavailable offline; the coordinator only needs to
//! (a) emit run records and metrics as JSON for EXPERIMENTS.md tooling and
//! (b) read back its own run registry, so a small self-contained
//! implementation suffices.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable
/// across runs — diffs of run records stay readable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object (programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let close_pad = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, indent + 1);
                }
                let _ = write!(out, "\n{close_pad}]");
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                let _ = write!(out, "\n{close_pad}}}");
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document. Returns None on malformed input.
    pub fn parse(s: &str) -> Option<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos == p.bytes.len() {
            Some(v)
        } else {
            None
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Option<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Some(())
        } else {
            None
        }
    }

    fn lit(&mut self, s: &str) -> Option<()> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Some(())
        } else {
            None
        }
    }

    fn value(&mut self) -> Option<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.lit("null").map(|_| Json::Null),
            b't' => self.lit("true").map(|_| Json::Bool(true)),
            b'f' => self.lit("false").map(|_| Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Some(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(self.bytes.get(self.pos..self.pos + 4)?).ok()?;
                            let code = u32::from_str_radix(hex, 16).ok()?;
                            self.pos += 4;
                            s.push(char::from_u32(code)?);
                        }
                        _ => return None,
                    }
                }
                _ => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).ok()?;
                    let c = rest.chars().next()?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Option<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()?
            .parse::<f64>()
            .ok()
            .map(Json::Num)
    }

    fn array(&mut self) -> Option<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Some(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Some(Json::Arr(v));
                }
                _ => return None,
            }
        }
    }

    fn object(&mut self) -> Option<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Some(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Some(Json::Obj(m));
                }
                _ => return None,
            }
        }
    }
}

/// Convenience constructors.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", s("llama3_attention"))
            .set("speedup", num(7.08))
            .set("samples", num(36.0))
            .set("curve", arr(vec![num(1.0), num(4.52), num(7.08)]));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_literals() {
        assert_eq!(Json::parse("null"), Some(Json::Null));
        assert_eq!(Json::parse("true"), Some(Json::Bool(true)));
        assert_eq!(Json::parse(" false "), Some(Json::Bool(false)));
        assert_eq!(Json::parse("-3.5e2"), Some(Json::Num(-350.0)));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j, Json::Str("a\nb\t\"c\" A".to_string()));
    }

    #[test]
    fn escape_roundtrip() {
        let orig = Json::Str("line1\nline2\t\"quoted\" \\back".to_string());
        assert_eq!(Json::parse(&orig.to_string()), Some(orig));
    }

    #[test]
    fn unicode_passthrough() {
        let orig = Json::Str("héllo ∞ 日本".to_string());
        assert_eq!(Json::parse(&orig.to_string()), Some(orig));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert_eq!(Json::parse("{} x"), None);
        assert_eq!(Json::parse("[1,]"), None);
    }

    #[test]
    fn pretty_parses_back() {
        let mut j = Json::obj();
        j.set("a", arr(vec![num(1.0), Json::obj()]));
        j.set("b", Json::Null);
        assert_eq!(Json::parse(&j.to_pretty()), Some(j));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(num(36.0).to_string(), "36");
        assert_eq!(num(7.08).to_string(), "7.08");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]"), Some(Json::Arr(vec![])));
        assert_eq!(Json::parse("{}"), Some(Json::obj()));
    }
}
