//! Deterministic fault injection for the tuning plane.
//!
//! A *fault plan* is a seeded schedule of failures — LLM call errors and
//! timeouts, hardware-measurement failures, and a process "crash" after a
//! fixed number of measurements — armed process-wide via the `RCC_FAULTS`
//! environment variable, `--faults`, or `[faults] spec` in a tune config:
//!
//! ```text
//! RCC_FAULTS="llm_error=0.05,llm_timeout=0.02,measure_fail=0.03,crash_at_step=40,seed=1"
//! ```
//!
//! Determinism contract (mirrors `obs`): the disabled path is a single
//! relaxed atomic load and nothing else — with no plan armed every fault
//! site behaves bit-identically to a build without this module. When a
//! plan is armed, each fault decision is a *stateless* hash of
//! `(plan seed, site, token)` where the token is already fixed at plan
//! time (the measurement's plan-time seed, the policy's call index), so
//! decisions are independent of thread scheduling and worker count and
//! never touch any search RNG.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Parsed fault schedule. Probabilities are in `[0, 1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Probability an LLM call attempt fails with a (retryable) error.
    pub llm_error: f64,
    /// Probability an LLM call attempt times out (classified separately).
    pub llm_timeout: f64,
    /// Probability a hardware measurement fails (quarantined, not cached).
    pub measure_fail: f64,
    /// Simulate a process kill once this many measurements have run
    /// (checked at session checkpoint boundaries).
    pub crash_at_step: Option<u64>,
    /// Seed for the stateless fault hash.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { llm_error: 0.0, llm_timeout: 0.0, measure_fail: 0.0, crash_at_step: None, seed: 0 }
    }
}

impl FaultPlan {
    /// Parse a `k=v,...` spec, e.g.
    /// `llm_error=0.05,llm_timeout=0.02,measure_fail=0.03,crash_at_step=40,seed=1`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("fault knob `{part}` is not key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 = v.parse().map_err(|_| format!("bad value for `{k}`: `{v}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("`{k}` must be a probability in [0,1], got {v}"));
                }
                Ok(p)
            };
            match k {
                "llm_error" => plan.llm_error = prob(v)?,
                "llm_timeout" => plan.llm_timeout = prob(v)?,
                "measure_fail" => plan.measure_fail = prob(v)?,
                "crash_at_step" => {
                    let n: u64 = v.parse().map_err(|_| format!("bad value for `crash_at_step`: `{v}`"))?;
                    plan.crash_at_step = Some(n);
                }
                "seed" => {
                    plan.seed = v.parse().map_err(|_| format!("bad value for `seed`: `{v}`"))?;
                }
                _ => return Err(format!("unknown fault knob `{k}`")),
            }
        }
        Ok(plan)
    }

    fn is_noop(&self) -> bool {
        self.llm_error == 0.0
            && self.llm_timeout == 0.0
            && self.measure_fail == 0.0
            && self.crash_at_step.is_none()
    }
}

// The armed plan lives in atomics (f64 probabilities as bit patterns) so
// fault rolls are lock-free; ARMED is the one flag the disabled fast path
// loads. `u64::MAX` in CRASH_AT means "no crash scheduled".
static ARMED: AtomicBool = AtomicBool::new(false);
static LLM_ERROR: AtomicU64 = AtomicU64::new(0);
static LLM_TIMEOUT: AtomicU64 = AtomicU64::new(0);
static MEASURE_FAIL: AtomicU64 = AtomicU64::new(0);
static CRASH_AT: AtomicU64 = AtomicU64::new(u64::MAX);
static SEED: AtomicU64 = AtomicU64::new(0);
/// Global measurement-step counter (only advanced while armed).
static STEP: AtomicU64 = AtomicU64::new(0);

/// Arm a fault plan process-wide (resets the measurement-step counter).
/// A no-op plan (all zeros) disarms instead, so `RCC_FAULTS=""` and an
/// all-default spec cost nothing.
pub fn arm(plan: &FaultPlan) {
    if plan.is_noop() {
        disarm();
        return;
    }
    LLM_ERROR.store(plan.llm_error.to_bits(), Ordering::Relaxed);
    LLM_TIMEOUT.store(plan.llm_timeout.to_bits(), Ordering::Relaxed);
    MEASURE_FAIL.store(plan.measure_fail.to_bits(), Ordering::Relaxed);
    CRASH_AT.store(plan.crash_at_step.unwrap_or(u64::MAX), Ordering::Relaxed);
    SEED.store(plan.seed, Ordering::Relaxed);
    STEP.store(0, Ordering::Relaxed);
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm all fault injection (the default state).
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    CRASH_AT.store(u64::MAX, Ordering::Relaxed);
    STEP.store(0, Ordering::Relaxed);
}

/// One relaxed load; `false` in every stock run.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// The currently armed plan, if any (for reporting).
pub fn plan() -> Option<FaultPlan> {
    if !armed() {
        return None;
    }
    let crash = CRASH_AT.load(Ordering::Relaxed);
    Some(FaultPlan {
        llm_error: f64::from_bits(LLM_ERROR.load(Ordering::Relaxed)),
        llm_timeout: f64::from_bits(LLM_TIMEOUT.load(Ordering::Relaxed)),
        measure_fail: f64::from_bits(MEASURE_FAIL.load(Ordering::Relaxed)),
        crash_at_step: (crash != u64::MAX).then_some(crash),
        seed: SEED.load(Ordering::Relaxed),
    })
}

/// Classification of a failed LLM call attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LlmFault {
    Error,
    Timeout,
}

// Distinct site constants keep the three roll streams independent even
// when tokens collide.
const SITE_LLM_ERROR: u64 = 0x11;
const SITE_LLM_TIMEOUT: u64 = 0x22;
const SITE_MEASURE: u64 = 0x33;

/// Stateless uniform draw in `[0, 1)` from `(seed, site, token)` — a
/// splitmix64 finalizer over the mixed key. No shared state, so the
/// result is identical regardless of which thread asks, in which order.
fn roll(site: u64, token: u64) -> f64 {
    roll_from(SEED.load(Ordering::Relaxed), site, token)
}

fn roll_from(seed: u64, site: u64, token: u64) -> f64 {
    let mut x = seed
        ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ token.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Does LLM call attempt `token` fail, and how? `None` when disarmed or
/// the attempt succeeds. Errors are rolled before timeouts so the two
/// probabilities are independent knobs, not a partition.
#[inline]
pub fn llm_fault(token: u64) -> Option<LlmFault> {
    if !armed() {
        return None;
    }
    if roll(SITE_LLM_ERROR, token) < f64::from_bits(LLM_ERROR.load(Ordering::Relaxed)) {
        return Some(LlmFault::Error);
    }
    if roll(SITE_LLM_TIMEOUT, token) < f64::from_bits(LLM_TIMEOUT.load(Ordering::Relaxed)) {
        return Some(LlmFault::Timeout);
    }
    None
}

/// Does the hardware measurement with plan-time seed `token` fail?
/// Also advances the global measurement-step counter (crash clock).
#[inline]
pub fn measure_fault(token: u64) -> bool {
    if !armed() {
        return false;
    }
    STEP.fetch_add(1, Ordering::Relaxed);
    roll(SITE_MEASURE, token) < f64::from_bits(MEASURE_FAIL.load(Ordering::Relaxed))
}

/// Is a crash scheduled at all? (Sessions serialize repeats when it is,
/// so checkpoint boundaries are meaningful; by the workers contract that
/// never changes results.)
#[inline]
pub fn crash_armed() -> bool {
    armed() && CRASH_AT.load(Ordering::Relaxed) != u64::MAX
}

/// Has the measurement-step counter crossed `crash_at_step`? Checked at
/// session checkpoint boundaries; the session then returns an error as if
/// the process had been killed, leaving its journal behind for `--resume`.
#[inline]
pub fn crash_due() -> bool {
    crash_armed() && STEP.load(Ordering::Relaxed) >= CRASH_AT.load(Ordering::Relaxed)
}

/// Measurement steps taken since arming (for reporting/tests).
pub fn steps() -> u64 {
    STEP.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    // Fault state is process-global and unit tests share one process with
    // every other lib test (including determinism suites running live
    // searches), so nothing here may call `arm`. Global arm/disarm,
    // crash-clock and end-to-end behavior are covered by
    // `tests/failure_injection.rs`, whose binary serializes fault-armed
    // tests behind one mutex.
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse(
            "llm_error=0.05, llm_timeout=0.02,measure_fail=0.03,crash_at_step=40,seed=7",
        )
        .unwrap();
        assert_eq!(p.llm_error, 0.05);
        assert_eq!(p.llm_timeout, 0.02);
        assert_eq!(p.measure_fail, 0.03);
        assert_eq!(p.crash_at_step, Some(40));
        assert_eq!(p.seed, 7);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FaultPlan::parse("llm_error").is_err());
        assert!(FaultPlan::parse("llm_error=2.0").is_err());
        assert!(FaultPlan::parse("warp_core=0.1").is_err());
        assert!(FaultPlan::parse("crash_at_step=soon").is_err());
        // Empty/trailing separators are tolerated.
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("measure_fail=0.5,").is_ok());
    }

    #[test]
    fn pure_rolls_are_deterministic_and_seed_sensitive() {
        let a: Vec<f64> = (0..64).map(|t| roll_from(1, SITE_MEASURE, t)).collect();
        let b: Vec<f64> = (0..64).map(|t| roll_from(1, SITE_MEASURE, t)).collect();
        assert_eq!(a, b, "same key -> same draw, no hidden state");
        assert!(a.iter().all(|&x| (0.0..1.0).contains(&x)));
        assert!(a.iter().any(|&x| x < 0.5) && a.iter().any(|&x| x >= 0.5));
        // Seed and site each reshuffle the stream.
        assert_ne!(a, (0..64).map(|t| roll_from(2, SITE_MEASURE, t)).collect::<Vec<_>>());
        assert_ne!(a, (0..64).map(|t| roll_from(1, SITE_LLM_ERROR, t)).collect::<Vec<_>>());
    }

    #[test]
    fn noop_detection() {
        assert!(FaultPlan::default().is_noop());
        assert!(FaultPlan { seed: 9, ..FaultPlan::default() }.is_noop());
        assert!(!FaultPlan { measure_fail: 0.1, ..FaultPlan::default() }.is_noop());
        assert!(!FaultPlan { crash_at_step: Some(1), ..FaultPlan::default() }.is_noop());
    }
}
