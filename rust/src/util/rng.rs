//! Deterministic PCG64-family random number generator.
//!
//! The `rand` crate is unavailable offline, so the search engines use this
//! small, seedable generator. Determinism matters: every experiment in
//! EXPERIMENTS.md is reproduced from a fixed seed set, and MCTS/ES runs must
//! replay bit-identically for the trace-replay property tests.

/// A PCG-XSH-RR 64/32 generator extended to produce 64-bit outputs by
/// concatenating two 32-bit draws. Period 2^64 per stream; streams are
/// selected by the odd `inc` increment derived from the seed.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a 64-bit seed. Two generators with different
    /// seeds produce independent streams (seed also perturbs the stream id).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (seed.wrapping_mul(0x9E3779B97F4A7C15) | 1),
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator; used to give each MCTS rollout / ES member
    /// an independent stream while keeping the parent replayable.
    pub fn fork(&mut self) -> Pcg {
        let s = self.next_u64();
        Pcg::new(s ^ 0xD1B54A32D192ED03)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n). Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple and fine
    /// for the noise models here).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_range(weights.len());
        }
        let mut t = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg::new(7);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Pcg::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Pcg::new(3);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_f64_mean_near_half() {
        let mut r = Pcg::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Pcg::new(13);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted_index(&w), 1);
        }
    }

    #[test]
    fn weighted_index_zero_weights_uniform() {
        let mut r = Pcg::new(17);
        let w = [0.0, 0.0];
        let mut seen = [false; 2];
        for _ in 0..100 {
            seen[r.weighted_index(&w)] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Pcg::new(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
