//! A persistent (structurally shared) chunked vector.
//!
//! `Schedule` clones its transform trace and rendered trace text on every
//! search-tree edge; with plain `Vec`s a depth-L chain deep-copies
//! O(L) elements per edge — O(L²) total strings/transforms for one branch.
//! [`PVec`] freezes full chunks behind `Arc<[T]>` and keeps only a small
//! owned tail, so cloning costs O(L/CHUNK) reference bumps plus at most
//! `CHUNK` element clones, while iteration order and contents are exactly
//! those of a `Vec`.
//!
//! The structure is append-only (push), which is all a trace needs; for
//! arbitrary edits, convert with [`PVec::to_vec`] and rebuild.

use std::sync::Arc;

/// Elements per frozen chunk. Small enough that the owned tail stays cheap
/// to clone, large enough that deep traces are mostly shared `Arc`s.
const CHUNK: usize = 16;

#[derive(Debug, Clone)]
pub struct PVec<T> {
    /// Frozen, shared prefix; every chunk holds exactly `CHUNK` elements.
    chunks: Vec<Arc<[T]>>,
    /// Owned tail, length < `CHUNK`.
    tail: Vec<T>,
}

impl<T> Default for PVec<T> {
    fn default() -> Self {
        PVec { chunks: Vec::new(), tail: Vec::new() }
    }
}

impl<T: Clone> PVec<T> {
    pub fn new() -> PVec<T> {
        PVec::default()
    }

    pub fn len(&self) -> usize {
        self.chunks.len() * CHUNK + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty() && self.tail.is_empty()
    }

    /// Append one element; seals the tail into a shared chunk when full.
    pub fn push(&mut self, item: T) {
        self.tail.push(item);
        if self.tail.len() == CHUNK {
            self.chunks.push(std::mem::take(&mut self.tail).into());
        }
    }

    /// In-order iteration over all elements.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.chunks.iter().flat_map(|c| c.iter()).chain(self.tail.iter())
    }

    pub fn get(&self, i: usize) -> Option<&T> {
        let c = i / CHUNK;
        if c < self.chunks.len() {
            self.chunks[c].get(i % CHUNK)
        } else {
            self.tail.get(i - self.chunks.len() * CHUNK)
        }
    }

    /// Materialize as a plain `Vec` (for APIs that need a slice).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }
}

impl<T: Clone> FromIterator<T> for PVec<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> PVec<T> {
        let mut v = PVec::new();
        for item in iter {
            v.push(item);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_iter_roundtrip() {
        let mut v: PVec<usize> = PVec::new();
        assert!(v.is_empty());
        for i in 0..100 {
            v.push(i);
            assert_eq!(v.len(), i + 1);
        }
        assert_eq!(v.to_vec(), (0..100).collect::<Vec<_>>());
        assert_eq!(v.get(0), Some(&0));
        assert_eq!(v.get(CHUNK), Some(&CHUNK));
        assert_eq!(v.get(99), Some(&99));
        assert_eq!(v.get(100), None);
    }

    #[test]
    fn clone_shares_frozen_chunks() {
        let mut v: PVec<u64> = (0..(3 * CHUNK as u64 + 5)).collect();
        let w = v.clone();
        assert_eq!(v.to_vec(), w.to_vec());
        for (a, b) in v.chunks.iter().zip(&w.chunks) {
            assert!(Arc::ptr_eq(a, b), "frozen chunks must be shared, not copied");
        }
        // Diverging after the clone leaves the original untouched.
        v.push(999);
        assert_eq!(w.len(), 3 * CHUNK + 5);
        assert_eq!(v.len(), 3 * CHUNK + 6);
        assert_eq!(*v.iter().last().unwrap(), 999);
    }

    #[test]
    fn boundary_at_exact_chunk_multiple() {
        let v: PVec<usize> = (0..2 * CHUNK).collect();
        assert_eq!(v.len(), 2 * CHUNK);
        assert!(v.tail.is_empty(), "full tails must be sealed");
        assert_eq!(v.to_vec(), (0..2 * CHUNK).collect::<Vec<_>>());
    }

    #[test]
    fn from_iterator_collects() {
        let v: PVec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        assert_eq!(v.len(), 3);
        assert_eq!(v.to_vec(), vec!["a", "b", "c"]);
    }
}
