//! Minimal property-based testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` random inputs produced by a
//! generator closure; on failure it reports the seed and case index so the
//! exact failing input can be replayed deterministically.

use super::rng::Pcg;

/// Run `prop` on `cases` inputs drawn from `gen`. Panics with a replayable
/// seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "add-commutes",
            1,
            200,
            |r| (r.gen_range(1000) as i64, r.gen_range(1000) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            2,
            10,
            |r| r.gen_range(5),
            |_| Err("nope".into()),
        );
    }
}
