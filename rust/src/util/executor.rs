//! Persistent work-stealing executor — the one scheduler behind every
//! parallel site in the crate.
//!
//! Before PR 5 each parallel site (batched cost-model evaluation, session
//! repeats, `rcc serve --tune` model fleets) spawned and joined fresh
//! scoped threads per call, so MCTS workers went cold between iterations
//! and nested sites (repeats × `eval_batch` × models) multiplied into
//! `workers²` OS threads with no global view. The [`Executor`] replaces
//! all of that with one long-lived pool:
//!
//! - **Persistent workers.** `Executor::new(workers)` spawns
//!   `workers - 1` long-lived threads (the submitting thread is the
//!   remaining worker — see *helping* below). They stay hot for the
//!   lifetime of the executor instead of being re-created per batch.
//! - **Per-worker deques with stealing.** Submitted tasks land round-robin
//!   on per-worker deques; a worker pops its own deque newest-first (its
//!   own nested subtasks run soonest) and steals oldest-first from the
//!   others when idle, so an imbalanced batch never strands cores.
//! - **Deterministic fold.** Work is submitted in *task groups*
//!   ([`Executor::run`] / [`Executor::group`]): every task's output lands
//!   in a result slot chosen by submission index, never by completion
//!   order. Callers fix all order-sensitive state (measurement seeds,
//!   sample numbers) at plan time, so the scheduler only ever changes
//!   wall-clock — the PR 2/3 determinism contract (`workers` never
//!   changes results; `workers = 1` is the exact serial path, inline, no
//!   threads) survives verbatim.
//! - **Nesting without oversubscription.** A task running on a worker may
//!   submit its own group: while a group is unfinished, its submitter
//!   *helps* — it pops and runs queued tasks (its own group's or any
//!   other's) instead of blocking. Total concurrency therefore stays at
//!   `workers` no matter how deeply session repeats, evaluation batches
//!   and model fleets nest, and a waiting submitter can never deadlock
//!   the pool (every waiter is also an executor).
//! - **Panic propagation.** A panicking task marks its group; the
//!   submitter re-raises the payload after the group drains. A panic
//!   fails the submitting group — it never hangs the executor or poisons
//!   the worker threads (workers run every task under `catch_unwind`).
//!
//! # Safety
//!
//! Group tasks may borrow the submitter's stack (`&dyn CostModel`,
//! slices, caches). Internally each task is boxed and its lifetime erased
//! to `'static` before it is queued — sound because a [`TaskGroup`] never
//! lets those borrows outlive it: both [`TaskGroup::wait`] and its `Drop`
//! run the group to completion (executing tasks on the calling thread if
//! need be) before returning. The one obligation on callers inside this
//! crate: never `mem::forget` a `TaskGroup`.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs;

/// A queued task with its lifetime erased (see module-level Safety notes).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Always-on per-deque scheduling counters (relaxed atomics bumped at
/// sites that already hold the deque mutex — cheap enough to never gate).
/// Recording them cannot change scheduling decisions or task results;
/// they are strictly write-only telemetry.
struct DequeStats {
    own_pops: AtomicU64,
    steals: AtomicU64,
    idle_wakeups: AtomicU64,
    queue_hwm: AtomicU64,
}

impl DequeStats {
    fn new() -> DequeStats {
        DequeStats {
            own_pops: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            idle_wakeups: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
        }
    }
}

/// Snapshot of one worker's scheduling counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Tasks this worker popped from its own deque (newest-first).
    pub own_pops: u64,
    /// Tasks this worker stole from other workers' deques.
    pub steals: u64,
    /// Times this worker woke from an idle park.
    pub idle_wakeups: u64,
    /// High-water mark of this worker's deque depth.
    pub queue_hwm: u64,
}

/// Snapshot of an executor's scheduling counters ([`Executor::stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// One entry per worker thread (empty for the serial executor).
    pub per_worker: Vec<WorkerStats>,
    /// Steals performed by helping submitters (threads blocked on a
    /// group running queued work instead of sleeping).
    pub help_steals: u64,
}

impl ExecutorStats {
    pub fn total_own_pops(&self) -> u64 {
        self.per_worker.iter().map(|w| w.own_pops).sum()
    }

    /// Worker steals plus helping-submitter steals.
    pub fn total_steals(&self) -> u64 {
        self.per_worker.iter().map(|w| w.steals).sum::<u64>() + self.help_steals
    }

    pub fn total_idle_wakeups(&self) -> u64 {
        self.per_worker.iter().map(|w| w.idle_wakeups).sum()
    }

    /// Deepest any single deque ever got.
    pub fn queue_hwm(&self) -> u64 {
        self.per_worker.iter().map(|w| w.queue_hwm).max().unwrap_or(0)
    }
}

/// State shared between the executor handle, its worker threads and every
/// task group (groups hold their own `Arc`, so a group can finish — by
/// helping — even while the executor itself is being dropped).
struct Shared {
    /// One deque per worker thread; submitters distribute round-robin.
    deques: Vec<Mutex<VecDeque<Job>>>,
    /// Queued-but-unclaimed jobs (wakes sleeping workers cheaply).
    pending: AtomicUsize,
    /// Group submitters currently parked on `done_cv` — lets the per-task
    /// completion path skip the global lock entirely when nobody waits.
    /// The waiter/completion handshake is SeqCst (Dekker-style): a waiter
    /// registers *then* re-checks its counter under `sync`; a completion
    /// decrements the counter *then* loads `waiters` — so one of them
    /// always sees the other.
    waiters: AtomicUsize,
    /// Round-robin submission cursor.
    cursor: AtomicUsize,
    shutdown: AtomicBool,
    /// Sleep coordination. Two condvars under one mutex so wakeups are
    /// targeted: a push wakes exactly one idle worker (`work_cv`,
    /// `notify_one` — no thundering herd racing for one job), a
    /// completion wakes only group waiters (`done_cv`; there are at most
    /// a handful). Sleepers re-check their counter under `sync` before
    /// waiting, so notifications cannot be lost; the wait timeouts are
    /// backstops only.
    sync: Mutex<()>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Per-deque telemetry, parallel to `deques` (see [`DequeStats`]).
    stats: Vec<DequeStats>,
    /// Steals by helping submitters (they have no home deque).
    help_steals: AtomicU64,
}

impl Shared {
    fn push(&self, job: Job) {
        debug_assert!(!self.deques.is_empty(), "serial executors never queue");
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.deques.len();
        let depth = {
            let mut q = self.deques[i].lock().unwrap();
            q.push_back(job);
            q.len() as u64
        };
        self.stats[i].queue_hwm.fetch_max(depth, Ordering::Relaxed);
        obs::metrics::exec_queue_depth(depth);
        self.pending.fetch_add(1, Ordering::Release);
        let _g = self.sync.lock().unwrap();
        self.work_cv.notify_one();
    }

    /// Worker pop: own deque newest-first, then steal oldest-first.
    fn pop(&self, home: usize) -> Option<Job> {
        let n = self.deques.len();
        if n == 0 {
            return None;
        }
        if let Some(j) = self.deques[home % n].lock().unwrap().pop_back() {
            self.pending.fetch_sub(1, Ordering::AcqRel);
            self.stats[home % n].own_pops.fetch_add(1, Ordering::Relaxed);
            obs::metrics::exec_own_pop();
            return Some(j);
        }
        for k in 1..n {
            if let Some(j) = self.deques[(home + k) % n].lock().unwrap().pop_front() {
                self.pending.fetch_sub(1, Ordering::AcqRel);
                self.stats[home % n].steals.fetch_add(1, Ordering::Relaxed);
                obs::metrics::exec_steal();
                return Some(j);
            }
        }
        None
    }

    /// Steal for a helping submitter (oldest-first across all deques).
    fn steal(&self) -> Option<Job> {
        for q in &self.deques {
            if let Some(j) = q.lock().unwrap().pop_front() {
                self.pending.fetch_sub(1, Ordering::AcqRel);
                self.help_steals.fetch_add(1, Ordering::Relaxed);
                obs::metrics::exec_help_steal();
                return Some(j);
            }
        }
        None
    }

    /// A task finished: wake any group waiter to re-check its counter.
    /// Lock-free in the common no-waiter case (see `waiters`).
    fn notify_done(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        let _g = self.sync.lock().unwrap();
        self.done_cv.notify_all();
    }

    /// Shutdown / teardown: wake everything.
    fn notify_all(&self) {
        let _g = self.sync.lock().unwrap();
        self.work_cv.notify_all();
        self.done_cv.notify_all();
    }
}

fn worker_loop(shared: Arc<Shared>, home: usize) {
    loop {
        if let Some(job) = shared.pop(home) {
            job(); // the job's epilogue notifies its waiting group itself
            continue;
        }
        let g = shared.sync.lock().unwrap();
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        if shared.pending.load(Ordering::Acquire) == 0 {
            // Timeout is a backstop only; pushes notify under `sync`.
            let _ = shared.work_cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
            shared.stats[home].idle_wakeups.fetch_add(1, Ordering::Relaxed);
            obs::metrics::exec_idle_wakeup();
        }
    }
}

/// Per-group completion state shared with every queued task of the group.
struct GroupCore {
    remaining: AtomicUsize,
    /// First panic payload from any task of this group.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// The crate-wide persistent executor. Construct once per session (or
/// process) with [`Executor::new`] and share the `Arc` across every
/// parallel site; see the module docs for the scheduling model.
pub struct Executor {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("workers", &self.workers).finish()
    }
}

impl Executor {
    /// An executor with `workers` total parallelism: `workers - 1`
    /// persistent threads plus the submitting thread (which helps while
    /// waiting). `workers <= 1` spawns nothing — every group runs inline,
    /// the exact serial path.
    pub fn new(workers: usize) -> Arc<Executor> {
        let workers = workers.max(1);
        let threads = workers - 1;
        let shared = Arc::new(Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            sync: Mutex::new(()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            stats: (0..threads).map(|_| DequeStats::new()).collect(),
            help_steals: AtomicU64::new(0),
        });
        let handles = (0..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rcc-exec-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawning executor worker thread")
            })
            .collect();
        Arc::new(Executor { shared, handles: Mutex::new(handles), workers })
    }

    /// The inline/serial executor (`workers = 1`): no threads, every task
    /// runs on the submitting thread in submission order.
    pub fn serial() -> Arc<Executor> {
        Executor::new(1)
    }

    /// Configured total parallelism (threads + the helping submitter).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether groups run inline on the submitter (no worker threads).
    pub fn is_serial(&self) -> bool {
        self.workers <= 1
    }

    /// Snapshot this executor's scheduling counters: per-worker own-pops,
    /// steals, idle wakeups and queue-depth high-water marks, plus steals
    /// by helping submitters. Counters are always on (recording them never
    /// affects scheduling or results) and only ever grow, so deltas of two
    /// snapshots attribute work to a window.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            per_worker: self
                .shared
                .stats
                .iter()
                .map(|s| WorkerStats {
                    own_pops: s.own_pops.load(Ordering::Relaxed),
                    steals: s.steals.load(Ordering::Relaxed),
                    idle_wakeups: s.idle_wakeups.load(Ordering::Relaxed),
                    queue_hwm: s.queue_hwm.load(Ordering::Relaxed),
                })
                .collect(),
            help_steals: self.shared.help_steals.load(Ordering::Relaxed),
        }
    }

    /// An incremental task group: submit tasks one at a time (they start
    /// running once a second task arrives — see lazy first dispatch),
    /// then [`TaskGroup::wait`] for all results in submission order. This
    /// is how leaf-parallel MCTS overlaps leaf selection with measurement.
    ///
    /// Crate-private on purpose: a caller-owned group of borrowing tasks
    /// is only sound while the group is never leaked (`mem::forget`),
    /// which the compiler cannot enforce — in-crate call sites uphold it,
    /// external users get the sound [`Executor::run`] (which never hands
    /// the group out).
    pub(crate) fn group<'scope, T: Send + 'scope>(&self) -> TaskGroup<'scope, T> {
        TaskGroup {
            shared: Arc::clone(&self.shared),
            serial: self.is_serial(),
            slots: Vec::new(),
            core: Arc::new(GroupCore {
                remaining: AtomicUsize::new(0),
                panic: Mutex::new(None),
            }),
            deferred: None,
            _scope: PhantomData,
        }
    }

    /// Run a batch of tasks and return their outputs **by submission
    /// index** (never completion order). Blocks until every task
    /// finished, helping with queued work meanwhile; re-raises the first
    /// task panic after the group drains.
    pub fn run<'scope, T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'scope,
        F: FnOnce() -> T + Send + 'scope,
    {
        let mut group = self.group::<T>();
        for t in tasks {
            group.submit(t);
        }
        group.wait()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// An in-flight task group (see [`Executor::group`]). Results land by
/// submission index. Dropping an unfinished group blocks until its tasks
/// drain (discarding results), so borrowed task inputs can never dangle.
pub struct TaskGroup<'scope, T: Send + 'scope> {
    shared: Arc<Shared>,
    serial: bool,
    slots: Vec<Arc<Mutex<Option<T>>>>,
    core: Arc<GroupCore>,
    /// Lazy first dispatch: the first parallel task is held back until a
    /// second one arrives. A group that only ever gets one task (the
    /// default `eval_batch = 1` measurement path) then runs it inline at
    /// `wait`, with zero queue/wakeup traffic — the old single-job
    /// shortcut, preserved — while multi-task groups flush it on the
    /// second submit and stream from there.
    deferred: Option<Box<dyn FnOnce() + Send + 'scope>>,
    /// Invariant over `'scope`: tasks may borrow the submitter's stack.
    _scope: PhantomData<fn(&'scope ()) -> &'scope ()>,
}

impl<'scope, T: Send + 'scope> TaskGroup<'scope, T> {
    /// Number of tasks submitted so far.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Submit one task. On a serial executor it runs inline right here
    /// (panics propagate directly — the exact serial path); otherwise it
    /// is queued for the worker pool and runs concurrently with further
    /// submissions.
    pub fn submit<F>(&mut self, f: F)
    where
        F: FnOnce() -> T + Send + 'scope,
    {
        let slot = Arc::new(Mutex::new(None));
        self.slots.push(Arc::clone(&slot));
        if self.serial {
            *slot.lock().unwrap() = Some(f());
            return;
        }
        // Count before queueing: the job may finish before we return.
        self.core.remaining.fetch_add(1, Ordering::AcqRel);
        let core = Arc::clone(&self.core);
        let shared = Arc::clone(&self.shared);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            match panic::catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => *slot.lock().unwrap() = Some(v),
                Err(p) => {
                    core.panic.lock().unwrap().get_or_insert(p);
                }
            }
            // SeqCst so a concurrently-registering waiter and this
            // completion cannot miss each other (see `Shared::waiters`).
            core.remaining.fetch_sub(1, Ordering::SeqCst);
            shared.notify_done();
        });
        // Lazy first dispatch (see the `deferred` field): the group's
        // first task is held on the submitter until a second one proves
        // the group is worth fanning out.
        match self.deferred.take() {
            Some(prev) => {
                self.dispatch(prev);
                self.dispatch(job);
            }
            None if self.slots.len() == 1 => self.deferred = Some(job),
            None => self.dispatch(job),
        }
    }

    /// Queue one wrapped task on the worker pool.
    fn dispatch(&self, job: Box<dyn FnOnce() + Send + 'scope>) {
        // SAFETY: lifetime erasure only — same layout. The group never
        // outlives `'scope` with tasks still queued or running: `wait`
        // and `Drop` both run the group to completion first (module docs).
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        self.shared.push(job);
    }

    /// Run queued work until every task of this group has finished.
    fn join(&mut self) {
        if let Some(job) = self.deferred.take() {
            job(); // single-task group: run inline, no executor traffic
        }
        while self.core.remaining.load(Ordering::Acquire) > 0 {
            // Help: run anything queued (this group's tasks or another's
            // — every waiter is also an executor, so nesting can't
            // deadlock and total concurrency stays at `workers`). The
            // job's own epilogue notifies whichever group it belongs to.
            if let Some(job) = self.shared.steal() {
                job();
                continue;
            }
            // Nothing to steal: our tasks are in flight on other workers.
            // Register as a waiter *before* the final re-check, so a
            // completion that just decremented `remaining` either sees us
            // (and notifies under `sync`, which we hold until parked) or
            // happened early enough that our re-check sees zero.
            let g = self.shared.sync.lock().unwrap();
            self.shared.waiters.fetch_add(1, Ordering::SeqCst);
            if self.core.remaining.load(Ordering::SeqCst) > 0
                && self.shared.pending.load(Ordering::Acquire) == 0
            {
                let _ = self
                    .shared
                    .done_cv
                    .wait_timeout(g, Duration::from_millis(1))
                    .unwrap();
            }
            self.shared.waiters.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Block until every task finished (helping meanwhile) and return the
    /// results in submission order. Re-raises the first task panic.
    pub fn wait(mut self) -> Vec<T> {
        self.join();
        if let Some(p) = self.core.panic.lock().unwrap().take() {
            panic::resume_unwind(p);
        }
        std::mem::take(&mut self.slots)
            .into_iter()
            .map(|s| s.lock().unwrap().take().expect("task group slot filled"))
            .collect()
    }
}

impl<'scope, T: Send + 'scope> Drop for TaskGroup<'scope, T> {
    fn drop(&mut self) {
        // Run to completion even when abandoned (or unwinding), so tasks
        // borrowing the submitter's stack can never outlive it. Panic
        // payloads of an abandoned group are dropped, not re-raised.
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_by_submission_index_for_any_worker_count() {
        for workers in [1, 2, 3, 8] {
            let exec = Executor::new(workers);
            let tasks: Vec<_> = (0..23usize).map(|i| move || i * i).collect();
            let out = exec.run(tasks);
            assert_eq!(
                out,
                (0..23usize).map(|i| i * i).collect::<Vec<_>>(),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn serial_executor_runs_inline_in_order() {
        let exec = Executor::serial();
        assert!(exec.is_serial());
        let order = Mutex::new(Vec::new());
        let tasks: Vec<_> = (0..5usize)
            .map(|i| {
                let order = &order;
                move || {
                    order.lock().unwrap().push(i);
                    i
                }
            })
            .collect();
        let out = exec.run(tasks);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4], "strict submission order");
    }

    #[test]
    fn tasks_can_borrow_the_submitters_stack() {
        let exec = Executor::new(4);
        let data: Vec<u64> = (0..100).collect();
        let slice = &data;
        let tasks: Vec<_> = (0..10usize)
            .map(|i| move || slice[i * 10..(i + 1) * 10].iter().sum::<u64>())
            .collect();
        let out = exec.run(tasks);
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn nested_groups_share_one_pool_without_deadlock() {
        let exec = Executor::new(3);
        // 4 outer tasks × 6 inner tasks on a 3-wide pool: submitters must
        // help or this oversubscribed nest would starve.
        let exec_ref = &exec;
        let outer: Vec<_> = (0..4u64)
            .map(|i| {
                move || {
                    let inner: Vec<_> =
                        (0..6u64).map(|j| move || i * 100 + j).collect();
                    exec_ref.run(inner).into_iter().sum::<u64>()
                }
            })
            .collect();
        let out = exec.run(outer);
        let expect: Vec<u64> = (0..4u64).map(|i| (0..6u64).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn single_task_group_runs_inline_on_the_submitter() {
        // Lazy first dispatch: a group that only ever gets one task must
        // execute it on the calling thread (the old single-job shortcut),
        // not round-trip through the worker deques.
        let exec = Executor::new(4);
        let me = std::thread::current().id();
        let mut g = exec.group::<std::thread::ThreadId>();
        g.submit(|| std::thread::current().id());
        assert_eq!(g.wait(), vec![me], "lone task must run inline at wait");
    }

    #[test]
    fn incremental_group_overlaps_submission_and_execution() {
        let exec = Executor::new(4);
        let mut group = exec.group::<usize>();
        for i in 0..16usize {
            group.submit(move || i + 1);
        }
        assert_eq!(group.len(), 16);
        let out = group.wait();
        assert_eq!(out, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_fails_the_group_not_the_executor() {
        let exec = Executor::new(4);
        let exec_ref = &exec;
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("injected task failure")),
                Box::new(|| 3),
            ];
            exec_ref.run(tasks)
        }));
        assert!(attempt.is_err(), "group must re-raise the task panic");
        // The executor survives and keeps scheduling correctly.
        let out = exec.run((0..8usize).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panic_in_nested_group_propagates_to_the_outer_group() {
        let exec = Executor::new(4);
        let exec_ref = &exec;
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
            exec_ref.run(vec![move || {
                let inner: Vec<Box<dyn FnOnce() -> usize + Send>> =
                    vec![Box::new(|| panic!("inner failure"))];
                exec_ref.run(inner)
            }])
        }));
        assert!(attempt.is_err());
        assert_eq!(exec.run(vec![|| 7usize]), vec![7]);
    }

    #[test]
    fn dropping_an_unfinished_group_drains_it() {
        let exec = Executor::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let mut group = exec.group::<()>();
            for _ in 0..32 {
                let c = Arc::clone(&counter);
                group.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Dropped without wait(): must still run everything.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn stats_account_for_every_dispatched_task() {
        let exec = Executor::new(4);
        let out = exec.run((0..120usize).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out.len(), 120);
        let stats = exec.stats();
        assert_eq!(stats.per_worker.len(), 3, "workers-1 deques");
        // Every queued job was popped exactly once, by its owner, a
        // stealing worker, or the helping submitter.
        assert_eq!(stats.total_own_pops() + stats.total_steals(), 120);
        assert!(stats.queue_hwm() >= 1);

        // Serial executors queue nothing and report no workers.
        let serial = Executor::serial();
        serial.run(vec![|| 1usize, || 2usize]);
        let s = serial.stats();
        assert!(s.per_worker.is_empty());
        assert_eq!(s.total_steals(), 0);
    }

    #[test]
    fn many_more_tasks_than_workers() {
        let exec = Executor::new(2);
        let out = exec.run((0..500usize).map(|i| move || i % 7).collect::<Vec<_>>());
        assert_eq!(out.len(), 500);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i % 7));
    }
}
