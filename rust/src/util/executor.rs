//! Persistent work-stealing executor — the one scheduler behind every
//! parallel site in the crate.
//!
//! Before PR 5 each parallel site (batched cost-model evaluation, session
//! repeats, `rcc serve --tune` model fleets) spawned and joined fresh
//! scoped threads per call, so MCTS workers went cold between iterations
//! and nested sites (repeats × `eval_batch` × models) multiplied into
//! `workers²` OS threads with no global view. The [`Executor`] replaces
//! all of that with one long-lived pool:
//!
//! - **Persistent workers.** `Executor::new(workers)` spawns
//!   `workers - 1` long-lived threads (the submitting thread is the
//!   remaining worker — see *helping* below). They stay hot for the
//!   lifetime of the executor instead of being re-created per batch.
//! - **Per-worker deques with stealing.** Submitted tasks land round-robin
//!   on per-worker deques; a worker pops its own deque newest-first (its
//!   own nested subtasks run soonest) and steals oldest-first from the
//!   others when idle, so an imbalanced batch never strands cores.
//! - **Deterministic fold.** Work is submitted in *task groups*
//!   ([`Executor::run`] / [`Executor::group`]): every task's output lands
//!   in a result slot chosen by submission index, never by completion
//!   order. Callers fix all order-sensitive state (measurement seeds,
//!   sample numbers) at plan time, so the scheduler only ever changes
//!   wall-clock — the PR 2/3 determinism contract (`workers` never
//!   changes results; `workers = 1` is the exact serial path, inline, no
//!   threads) survives verbatim.
//! - **Nesting without oversubscription.** A task running on a worker may
//!   submit its own group: while a group is unfinished, its submitter
//!   *helps* — it pops and runs queued tasks (its own group's or any
//!   other's) instead of blocking. Total concurrency therefore stays at
//!   `workers` no matter how deeply session repeats, evaluation batches
//!   and model fleets nest, and a waiting submitter can never deadlock
//!   the pool (every waiter is also an executor).
//! - **Panic propagation.** A panicking task marks its group; the
//!   submitter re-raises the payload after the group drains. A panic
//!   fails the submitting group — it never hangs the executor or poisons
//!   the worker threads (workers run every task under `catch_unwind`).
//! - **Two-level priority.** Every task group carries a [`Priority`]:
//!   `High` (latency-sensitive serve traffic) or `Low` (background
//!   tuning — the default, so every pre-existing call site keeps its
//!   behavior). Each worker deque is split into a high and a low lane;
//!   *every* dequeue site — own pop, worker steal, helping-submitter
//!   steal — drains queued high jobs before touching a low one, so serve
//!   traffic preempts background tuning at dequeue/steal time. A helper
//!   waiting on a *high* group steals only high jobs (it must not adopt
//!   long background work while its own latency-sensitive tasks run;
//!   its own queued jobs are high, so the restriction never starves it).
//!   Priorities reorder wall-clock execution only: results still land by
//!   submission index, so the determinism contract is untouched.
//!
//! # Safety
//!
//! Group tasks may borrow the submitter's stack (`&dyn CostModel`,
//! slices, caches). Internally each task is boxed and its lifetime erased
//! to `'static` before it is queued — sound because a [`TaskGroup`] never
//! lets those borrows outlive it: both [`TaskGroup::wait`] and its `Drop`
//! run the group to completion (executing tasks on the calling thread if
//! need be) before returning. The one obligation on callers inside this
//! crate: never `mem::forget` a `TaskGroup`.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs;

/// A queued task with its lifetime erased (see module-level Safety notes).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Scheduling class of a task group. `High` preempts `Low` at every
/// dequeue and steal site (see the module docs); `Low` is the default so
/// existing call sites — batch evaluation, session repeats, tuning fleets
/// — stay background work without changes. Priorities never change
/// results, only wall-clock order: outputs fold by submission index.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground work (serve traffic).
    High,
    /// Throughput-oriented background work (tuning, batched evaluation).
    #[default]
    Low,
}

/// One worker's queue, split into a per-priority lane pair. Depth and
/// high-water telemetry count both lanes together (the deque identity is
/// what matters for stealing, not the lane).
struct Lanes {
    high: VecDeque<Job>,
    low: VecDeque<Job>,
}

impl Lanes {
    fn new() -> Lanes {
        Lanes { high: VecDeque::new(), low: VecDeque::new() }
    }

    fn len(&self) -> usize {
        self.high.len() + self.low.len()
    }

    fn lane_mut(&mut self, prio: Priority) -> &mut VecDeque<Job> {
        match prio {
            Priority::High => &mut self.high,
            Priority::Low => &mut self.low,
        }
    }
}

/// Always-on per-deque scheduling counters (relaxed atomics bumped at
/// sites that already hold the deque mutex — cheap enough to never gate).
/// Recording them cannot change scheduling decisions or task results;
/// they are strictly write-only telemetry.
struct DequeStats {
    own_pops: AtomicU64,
    steals: AtomicU64,
    idle_wakeups: AtomicU64,
    queue_hwm: AtomicU64,
}

impl DequeStats {
    fn new() -> DequeStats {
        DequeStats {
            own_pops: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            idle_wakeups: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
        }
    }
}

/// Snapshot of one worker's scheduling counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Tasks this worker popped from its own deque (newest-first).
    pub own_pops: u64,
    /// Tasks this worker stole from other workers' deques.
    pub steals: u64,
    /// Times this worker woke from an idle park.
    pub idle_wakeups: u64,
    /// High-water mark of this worker's deque depth.
    pub queue_hwm: u64,
}

/// Snapshot of an executor's scheduling counters ([`Executor::stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// One entry per worker thread (empty for the serial executor).
    pub per_worker: Vec<WorkerStats>,
    /// Steals performed by helping submitters (threads blocked on a
    /// group running queued work instead of sleeping).
    pub help_steals: u64,
}

impl ExecutorStats {
    pub fn total_own_pops(&self) -> u64 {
        self.per_worker.iter().map(|w| w.own_pops).sum()
    }

    /// Worker steals plus helping-submitter steals.
    pub fn total_steals(&self) -> u64 {
        self.per_worker.iter().map(|w| w.steals).sum::<u64>() + self.help_steals
    }

    pub fn total_idle_wakeups(&self) -> u64 {
        self.per_worker.iter().map(|w| w.idle_wakeups).sum()
    }

    /// Deepest any single deque ever got.
    pub fn queue_hwm(&self) -> u64 {
        self.per_worker.iter().map(|w| w.queue_hwm).max().unwrap_or(0)
    }
}

/// State shared between the executor handle, its worker threads and every
/// task group (groups hold their own `Arc`, so a group can finish — by
/// helping — even while the executor itself is being dropped).
struct Shared {
    /// One two-lane deque per worker thread; submitters distribute
    /// round-robin, priority picks the lane.
    deques: Vec<Mutex<Lanes>>,
    /// Queued-but-unclaimed jobs across both lanes (wakes sleeping
    /// workers cheaply).
    pending: AtomicUsize,
    /// Queued-but-unclaimed *high* jobs: lets the hot all-low path skip
    /// the cross-deque high-lane scan with one relaxed-ish load.
    pending_high: AtomicUsize,
    /// Group submitters currently parked on `done_cv` — lets the per-task
    /// completion path skip the global lock entirely when nobody waits.
    /// The waiter/completion handshake is SeqCst (Dekker-style): a waiter
    /// registers *then* re-checks its counter under `sync`; a completion
    /// decrements the counter *then* loads `waiters` — so one of them
    /// always sees the other.
    waiters: AtomicUsize,
    /// Round-robin submission cursor.
    cursor: AtomicUsize,
    shutdown: AtomicBool,
    /// Sleep coordination. Two condvars under one mutex so wakeups are
    /// targeted: a push wakes exactly one idle worker (`work_cv`,
    /// `notify_one` — no thundering herd racing for one job), a
    /// completion wakes only group waiters (`done_cv`; there are at most
    /// a handful). Sleepers re-check their counter under `sync` before
    /// waiting, so notifications cannot be lost; the wait timeouts are
    /// backstops only.
    sync: Mutex<()>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Per-deque telemetry, parallel to `deques` (see [`DequeStats`]).
    stats: Vec<DequeStats>,
    /// Steals by helping submitters (they have no home deque).
    help_steals: AtomicU64,
}

impl Shared {
    fn push(&self, job: Job, prio: Priority) {
        debug_assert!(!self.deques.is_empty(), "serial executors never queue");
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.deques.len();
        let depth = {
            let mut q = self.deques[i].lock().unwrap();
            q.lane_mut(prio).push_back(job);
            q.len() as u64
        };
        self.stats[i].queue_hwm.fetch_max(depth, Ordering::Relaxed);
        obs::metrics::exec_queue_depth(depth);
        if prio == Priority::High {
            self.pending_high.fetch_add(1, Ordering::Release);
        }
        self.pending.fetch_add(1, Ordering::Release);
        let _g = self.sync.lock().unwrap();
        self.work_cv.notify_one();
    }

    /// A queued job was taken off a deque: maintain the pending counters.
    fn claim(&self, prio: Priority) {
        self.pending.fetch_sub(1, Ordering::AcqRel);
        if prio == Priority::High {
            self.pending_high.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Worker pop — serve preempts tune at the dequeue site: every queued
    /// high job (own newest-first, then stolen oldest-first) runs before
    /// any low job is dequeued. Within a lane the order is unchanged from
    /// the single-lane executor: own deque newest-first, steal
    /// oldest-first. The `pending_high` guard keeps the all-low hot path
    /// at one extra atomic load instead of a cross-deque scan.
    fn pop(&self, home: usize) -> Option<Job> {
        let n = self.deques.len();
        if n == 0 {
            return None;
        }
        let home = home % n;
        if self.pending_high.load(Ordering::Acquire) > 0 {
            if let Some(j) = self.deques[home].lock().unwrap().high.pop_back() {
                self.claim(Priority::High);
                self.stats[home].own_pops.fetch_add(1, Ordering::Relaxed);
                obs::metrics::exec_own_pop();
                return Some(j);
            }
            for k in 1..n {
                if let Some(j) = self.deques[(home + k) % n].lock().unwrap().high.pop_front() {
                    self.claim(Priority::High);
                    self.stats[home].steals.fetch_add(1, Ordering::Relaxed);
                    obs::metrics::exec_steal();
                    return Some(j);
                }
            }
        }
        if let Some(j) = self.deques[home].lock().unwrap().low.pop_back() {
            self.claim(Priority::Low);
            self.stats[home].own_pops.fetch_add(1, Ordering::Relaxed);
            obs::metrics::exec_own_pop();
            return Some(j);
        }
        for k in 1..n {
            if let Some(j) = self.deques[(home + k) % n].lock().unwrap().low.pop_front() {
                self.claim(Priority::Low);
                self.stats[home].steals.fetch_add(1, Ordering::Relaxed);
                obs::metrics::exec_steal();
                return Some(j);
            }
        }
        None
    }

    /// Steal for a helping submitter (oldest-first across all deques,
    /// high lane first). `floor` is the priority of the group the helper
    /// is waiting on: a submitter of a *high* group steals only high jobs
    /// — adopting a long-running background task while its own
    /// latency-sensitive tasks sit queued would be priority inversion by
    /// helping. Its own queued jobs are high, so the restriction can
    /// never starve it (it parks briefly only while they are in flight).
    fn steal(&self, floor: Priority) -> Option<Job> {
        if self.pending_high.load(Ordering::Acquire) > 0 {
            for q in &self.deques {
                if let Some(j) = q.lock().unwrap().high.pop_front() {
                    self.claim(Priority::High);
                    self.help_steals.fetch_add(1, Ordering::Relaxed);
                    obs::metrics::exec_help_steal();
                    return Some(j);
                }
            }
        }
        if floor == Priority::High {
            return None;
        }
        for q in &self.deques {
            if let Some(j) = q.lock().unwrap().low.pop_front() {
                self.claim(Priority::Low);
                self.help_steals.fetch_add(1, Ordering::Relaxed);
                obs::metrics::exec_help_steal();
                return Some(j);
            }
        }
        None
    }

    /// A task finished: wake any group waiter to re-check its counter.
    /// Lock-free in the common no-waiter case (see `waiters`).
    fn notify_done(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        let _g = self.sync.lock().unwrap();
        self.done_cv.notify_all();
    }

    /// Shutdown / teardown: wake everything.
    fn notify_all(&self) {
        let _g = self.sync.lock().unwrap();
        self.work_cv.notify_all();
        self.done_cv.notify_all();
    }
}

fn worker_loop(shared: Arc<Shared>, home: usize) {
    loop {
        if let Some(job) = shared.pop(home) {
            job(); // the job's epilogue notifies its waiting group itself
            continue;
        }
        let g = shared.sync.lock().unwrap();
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        if shared.pending.load(Ordering::Acquire) == 0 {
            // Timeout is a backstop only; pushes notify under `sync`.
            let _ = shared.work_cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
            shared.stats[home].idle_wakeups.fetch_add(1, Ordering::Relaxed);
            obs::metrics::exec_idle_wakeup();
        }
    }
}

/// Per-group completion state shared with every queued task of the group.
struct GroupCore {
    remaining: AtomicUsize,
    /// First panic payload from any task of this group.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// The crate-wide persistent executor. Construct once per session (or
/// process) with [`Executor::new`] and share the `Arc` across every
/// parallel site; see the module docs for the scheduling model.
pub struct Executor {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("workers", &self.workers).finish()
    }
}

impl Executor {
    /// An executor with `workers` total parallelism: `workers - 1`
    /// persistent threads plus the submitting thread (which helps while
    /// waiting). `workers <= 1` spawns nothing — every group runs inline,
    /// the exact serial path.
    pub fn new(workers: usize) -> Arc<Executor> {
        let workers = workers.max(1);
        let threads = workers - 1;
        let shared = Arc::new(Shared {
            deques: (0..threads).map(|_| Mutex::new(Lanes::new())).collect(),
            pending: AtomicUsize::new(0),
            pending_high: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            sync: Mutex::new(()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            stats: (0..threads).map(|_| DequeStats::new()).collect(),
            help_steals: AtomicU64::new(0),
        });
        let handles = (0..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rcc-exec-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawning executor worker thread")
            })
            .collect();
        Arc::new(Executor { shared, handles: Mutex::new(handles), workers })
    }

    /// The inline/serial executor (`workers = 1`): no threads, every task
    /// runs on the submitting thread in submission order.
    pub fn serial() -> Arc<Executor> {
        Executor::new(1)
    }

    /// Configured total parallelism (threads + the helping submitter).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether groups run inline on the submitter (no worker threads).
    pub fn is_serial(&self) -> bool {
        self.workers <= 1
    }

    /// Snapshot this executor's scheduling counters: per-worker own-pops,
    /// steals, idle wakeups and queue-depth high-water marks, plus steals
    /// by helping submitters. Counters are always on (recording them never
    /// affects scheduling or results) and only ever grow, so deltas of two
    /// snapshots attribute work to a window.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            per_worker: self
                .shared
                .stats
                .iter()
                .map(|s| WorkerStats {
                    own_pops: s.own_pops.load(Ordering::Relaxed),
                    steals: s.steals.load(Ordering::Relaxed),
                    idle_wakeups: s.idle_wakeups.load(Ordering::Relaxed),
                    queue_hwm: s.queue_hwm.load(Ordering::Relaxed),
                })
                .collect(),
            help_steals: self.shared.help_steals.load(Ordering::Relaxed),
        }
    }

    /// An incremental task group: submit tasks one at a time (they start
    /// running once a second task arrives — see lazy first dispatch),
    /// then [`TaskGroup::wait`] for all results in submission order. This
    /// is how leaf-parallel MCTS overlaps leaf selection with measurement.
    ///
    /// Crate-private on purpose: a caller-owned group of borrowing tasks
    /// is only sound while the group is never leaked (`mem::forget`),
    /// which the compiler cannot enforce — in-crate call sites uphold it,
    /// external users get the sound [`Executor::run`] (which never hands
    /// the group out).
    pub(crate) fn group<'scope, T: Send + 'scope>(&self) -> TaskGroup<'scope, T> {
        self.group_with(Priority::Low)
    }

    /// [`Executor::group`] with an explicit [`Priority`]. High groups'
    /// tasks preempt queued low work at every dequeue site, and their
    /// waiting submitters help with high jobs only.
    pub(crate) fn group_with<'scope, T: Send + 'scope>(
        &self,
        prio: Priority,
    ) -> TaskGroup<'scope, T> {
        TaskGroup {
            shared: Arc::clone(&self.shared),
            serial: self.is_serial(),
            prio,
            slots: Vec::new(),
            core: Arc::new(GroupCore {
                remaining: AtomicUsize::new(0),
                panic: Mutex::new(None),
            }),
            deferred: None,
            _scope: PhantomData,
        }
    }

    /// Run a batch of tasks and return their outputs **by submission
    /// index** (never completion order). Blocks until every task
    /// finished, helping with queued work meanwhile; re-raises the first
    /// task panic after the group drains. Tasks run at [`Priority::Low`]
    /// (background); latency-sensitive callers use [`Executor::run_with`].
    pub fn run<'scope, T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'scope,
        F: FnOnce() -> T + Send + 'scope,
    {
        self.run_with(Priority::Low, tasks)
    }

    /// [`Executor::run`] at an explicit [`Priority`] — the serve plane
    /// submits per-tick batch work at `High` so it preempts background
    /// tuning sharing the same executor.
    pub fn run_with<'scope, T, F>(&self, prio: Priority, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'scope,
        F: FnOnce() -> T + Send + 'scope,
    {
        let mut group = self.group_with::<T>(prio);
        for t in tasks {
            group.submit(t);
        }
        group.wait()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// An in-flight task group (see [`Executor::group`]). Results land by
/// submission index. Dropping an unfinished group blocks until its tasks
/// drain (discarding results), so borrowed task inputs can never dangle.
pub struct TaskGroup<'scope, T: Send + 'scope> {
    shared: Arc<Shared>,
    serial: bool,
    /// Lane this group's tasks queue on, fixed at creation.
    prio: Priority,
    slots: Vec<Arc<Mutex<Option<T>>>>,
    core: Arc<GroupCore>,
    /// Lazy first dispatch: the first parallel task is held back until a
    /// second one arrives. A group that only ever gets one task (the
    /// default `eval_batch = 1` measurement path) then runs it inline at
    /// `wait`, with zero queue/wakeup traffic — the old single-job
    /// shortcut, preserved — while multi-task groups flush it on the
    /// second submit and stream from there.
    deferred: Option<Box<dyn FnOnce() + Send + 'scope>>,
    /// Invariant over `'scope`: tasks may borrow the submitter's stack.
    _scope: PhantomData<fn(&'scope ()) -> &'scope ()>,
}

impl<'scope, T: Send + 'scope> TaskGroup<'scope, T> {
    /// Number of tasks submitted so far.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Submit one task. On a serial executor it runs inline right here
    /// (panics propagate directly — the exact serial path); otherwise it
    /// is queued for the worker pool and runs concurrently with further
    /// submissions.
    pub fn submit<F>(&mut self, f: F)
    where
        F: FnOnce() -> T + Send + 'scope,
    {
        let slot = Arc::new(Mutex::new(None));
        self.slots.push(Arc::clone(&slot));
        if self.serial {
            *slot.lock().unwrap() = Some(f());
            return;
        }
        // Count before queueing: the job may finish before we return.
        self.core.remaining.fetch_add(1, Ordering::AcqRel);
        let core = Arc::clone(&self.core);
        let shared = Arc::clone(&self.shared);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            match panic::catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => *slot.lock().unwrap() = Some(v),
                Err(p) => {
                    core.panic.lock().unwrap().get_or_insert(p);
                }
            }
            // SeqCst so a concurrently-registering waiter and this
            // completion cannot miss each other (see `Shared::waiters`).
            core.remaining.fetch_sub(1, Ordering::SeqCst);
            shared.notify_done();
        });
        // Lazy first dispatch (see the `deferred` field): the group's
        // first task is held on the submitter until a second one proves
        // the group is worth fanning out.
        match self.deferred.take() {
            Some(prev) => {
                self.dispatch(prev);
                self.dispatch(job);
            }
            None if self.slots.len() == 1 => self.deferred = Some(job),
            None => self.dispatch(job),
        }
    }

    /// Queue one wrapped task on the worker pool.
    fn dispatch(&self, job: Box<dyn FnOnce() + Send + 'scope>) {
        // SAFETY: lifetime erasure only — same layout. The group never
        // outlives `'scope` with tasks still queued or running: `wait`
        // and `Drop` both run the group to completion first (module docs).
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        self.shared.push(job, self.prio);
    }

    /// Run queued work until every task of this group has finished.
    fn join(&mut self) {
        if let Some(job) = self.deferred.take() {
            job(); // single-task group: run inline, no executor traffic
        }
        while self.core.remaining.load(Ordering::Acquire) > 0 {
            // Help: run anything queued at our priority floor (this
            // group's tasks or another's — every waiter is also an
            // executor, so nesting can't deadlock and total concurrency
            // stays at `workers`; a high group's waiter helps with high
            // jobs only, see `Shared::steal`). The job's own epilogue
            // notifies whichever group it belongs to.
            if let Some(job) = self.shared.steal(self.prio) {
                job();
                continue;
            }
            // Nothing to steal: our tasks are in flight on other workers.
            // Register as a waiter *before* the final re-check, so a
            // completion that just decremented `remaining` either sees us
            // (and notifies under `sync`, which we hold until parked) or
            // happened early enough that our re-check sees zero.
            let g = self.shared.sync.lock().unwrap();
            self.shared.waiters.fetch_add(1, Ordering::SeqCst);
            if self.core.remaining.load(Ordering::SeqCst) > 0
                && self.shared.pending.load(Ordering::Acquire) == 0
            {
                let _ = self
                    .shared
                    .done_cv
                    .wait_timeout(g, Duration::from_millis(1))
                    .unwrap();
            }
            self.shared.waiters.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Block until every task finished (helping meanwhile) and return the
    /// results in submission order. Re-raises the first task panic.
    pub fn wait(mut self) -> Vec<T> {
        self.join();
        if let Some(p) = self.core.panic.lock().unwrap().take() {
            panic::resume_unwind(p);
        }
        std::mem::take(&mut self.slots)
            .into_iter()
            .map(|s| s.lock().unwrap().take().expect("task group slot filled"))
            .collect()
    }
}

impl<'scope, T: Send + 'scope> Drop for TaskGroup<'scope, T> {
    fn drop(&mut self) {
        // Run to completion even when abandoned (or unwinding), so tasks
        // borrowing the submitter's stack can never outlive it. Panic
        // payloads of an abandoned group are dropped, not re-raised.
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_by_submission_index_for_any_worker_count() {
        for workers in [1, 2, 3, 8] {
            let exec = Executor::new(workers);
            let tasks: Vec<_> = (0..23usize).map(|i| move || i * i).collect();
            let out = exec.run(tasks);
            assert_eq!(
                out,
                (0..23usize).map(|i| i * i).collect::<Vec<_>>(),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn serial_executor_runs_inline_in_order() {
        let exec = Executor::serial();
        assert!(exec.is_serial());
        let order = Mutex::new(Vec::new());
        let tasks: Vec<_> = (0..5usize)
            .map(|i| {
                let order = &order;
                move || {
                    order.lock().unwrap().push(i);
                    i
                }
            })
            .collect();
        let out = exec.run(tasks);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4], "strict submission order");
    }

    #[test]
    fn tasks_can_borrow_the_submitters_stack() {
        let exec = Executor::new(4);
        let data: Vec<u64> = (0..100).collect();
        let slice = &data;
        let tasks: Vec<_> = (0..10usize)
            .map(|i| move || slice[i * 10..(i + 1) * 10].iter().sum::<u64>())
            .collect();
        let out = exec.run(tasks);
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn nested_groups_share_one_pool_without_deadlock() {
        let exec = Executor::new(3);
        // 4 outer tasks × 6 inner tasks on a 3-wide pool: submitters must
        // help or this oversubscribed nest would starve.
        let exec_ref = &exec;
        let outer: Vec<_> = (0..4u64)
            .map(|i| {
                move || {
                    let inner: Vec<_> =
                        (0..6u64).map(|j| move || i * 100 + j).collect();
                    exec_ref.run(inner).into_iter().sum::<u64>()
                }
            })
            .collect();
        let out = exec.run(outer);
        let expect: Vec<u64> = (0..4u64).map(|i| (0..6u64).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn single_task_group_runs_inline_on_the_submitter() {
        // Lazy first dispatch: a group that only ever gets one task must
        // execute it on the calling thread (the old single-job shortcut),
        // not round-trip through the worker deques.
        let exec = Executor::new(4);
        let me = std::thread::current().id();
        let mut g = exec.group::<std::thread::ThreadId>();
        g.submit(|| std::thread::current().id());
        assert_eq!(g.wait(), vec![me], "lone task must run inline at wait");
    }

    #[test]
    fn incremental_group_overlaps_submission_and_execution() {
        let exec = Executor::new(4);
        let mut group = exec.group::<usize>();
        for i in 0..16usize {
            group.submit(move || i + 1);
        }
        assert_eq!(group.len(), 16);
        let out = group.wait();
        assert_eq!(out, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_fails_the_group_not_the_executor() {
        let exec = Executor::new(4);
        let exec_ref = &exec;
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("injected task failure")),
                Box::new(|| 3),
            ];
            exec_ref.run(tasks)
        }));
        assert!(attempt.is_err(), "group must re-raise the task panic");
        // The executor survives and keeps scheduling correctly.
        let out = exec.run((0..8usize).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panic_in_nested_group_propagates_to_the_outer_group() {
        let exec = Executor::new(4);
        let exec_ref = &exec;
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
            exec_ref.run(vec![move || {
                let inner: Vec<Box<dyn FnOnce() -> usize + Send>> =
                    vec![Box::new(|| panic!("inner failure"))];
                exec_ref.run(inner)
            }])
        }));
        assert!(attempt.is_err());
        assert_eq!(exec.run(vec![|| 7usize]), vec![7]);
    }

    #[test]
    fn dropping_an_unfinished_group_drains_it() {
        let exec = Executor::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let mut group = exec.group::<()>();
            for _ in 0..32 {
                let c = Arc::clone(&counter);
                group.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Dropped without wait(): must still run everything.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn stats_account_for_every_dispatched_task() {
        let exec = Executor::new(4);
        let out = exec.run((0..120usize).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out.len(), 120);
        let stats = exec.stats();
        assert_eq!(stats.per_worker.len(), 3, "workers-1 deques");
        // Every queued job was popped exactly once, by its owner, a
        // stealing worker, or the helping submitter.
        assert_eq!(stats.total_own_pops() + stats.total_steals(), 120);
        assert!(stats.queue_hwm() >= 1);

        // Serial executors queue nothing and report no workers.
        let serial = Executor::serial();
        serial.run(vec![|| 1usize, || 2usize]);
        let s = serial.stats();
        assert!(s.per_worker.is_empty());
        assert_eq!(s.total_steals(), 0);
    }

    #[test]
    fn many_more_tasks_than_workers() {
        let exec = Executor::new(2);
        let out = exec.run((0..500usize).map(|i| move || i % 7).collect::<Vec<_>>());
        assert_eq!(out.len(), 500);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i % 7));
    }

    /// A bare `Shared` with no worker threads: lets the dequeue policy be
    /// exercised deterministically, one pop at a time.
    fn bare_shared(n: usize) -> Arc<Shared> {
        Arc::new(Shared {
            deques: (0..n).map(|_| Mutex::new(Lanes::new())).collect(),
            pending: AtomicUsize::new(0),
            pending_high: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            sync: Mutex::new(()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            stats: (0..n).map(|_| DequeStats::new()).collect(),
            help_steals: AtomicU64::new(0),
        })
    }

    fn tagged(order: &Arc<Mutex<Vec<&'static str>>>, tag: &'static str) -> Job {
        let order = Arc::clone(order);
        Box::new(move || order.lock().unwrap().push(tag))
    }

    #[test]
    fn dequeue_prefers_the_high_lane_before_any_low_job() {
        // Single deque, single consumer: priority decides before recency
        // does. Own pops stay newest-first *within* a lane, but every
        // queued high job drains before the first low job is touched.
        let s = bare_shared(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        s.push(tagged(&order, "low0"), Priority::Low);
        s.push(tagged(&order, "low1"), Priority::Low);
        s.push(tagged(&order, "high0"), Priority::High);
        s.push(tagged(&order, "high1"), Priority::High);
        while let Some(j) = s.pop(0) {
            j();
        }
        assert_eq!(*order.lock().unwrap(), vec!["high1", "high0", "low1", "low0"]);
        assert_eq!(s.pending.load(Ordering::SeqCst), 0);
        assert_eq!(s.pending_high.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn high_group_helpers_steal_high_jobs_only() {
        let s = bare_shared(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        s.push(tagged(&order, "low0"), Priority::Low);
        s.push(tagged(&order, "high0"), Priority::High);
        s.push(tagged(&order, "high1"), Priority::High);
        // A high group's waiting submitter: high jobs oldest-first, and
        // never a low job — that would be priority inversion by helping.
        s.steal(Priority::High).expect("first high job")();
        s.steal(Priority::High).expect("second high job")();
        assert!(s.steal(Priority::High).is_none(), "low job must stay queued");
        // A low group's waiting submitter takes anything, high lane first.
        s.steal(Priority::Low).expect("remaining low job")();
        assert_eq!(*order.lock().unwrap(), vec!["high0", "high1", "low0"]);
        assert_eq!(s.pending.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn queued_high_jobs_run_before_queued_low_jobs_end_to_end() {
        // Serve-preempts-tune through the public group API: occupy the
        // sole worker thread with a gate task, queue a low group then a
        // high group, and drain single-consumer by helping. Every dequeue
        // prefers the high lane, so the recorded order is exact.
        let exec = Executor::new(2); // one worker thread, one deque
        let started = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        let mut blocker = exec.group::<()>();
        {
            let (s, r) = (Arc::clone(&started), Arc::clone(&release));
            blocker.submit(move || {
                s.store(true, Ordering::SeqCst);
                while !r.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            });
        }
        blocker.submit(|| {}); // flush the lazily-deferred gate task
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }

        let order = Arc::new(Mutex::new(Vec::<String>::new()));
        let mut low = exec.group::<()>();
        for i in 0..3 {
            let o = Arc::clone(&order);
            low.submit(move || o.lock().unwrap().push(format!("low{i}")));
        }
        let mut high = exec.group_with::<()>(Priority::High);
        for i in 0..3 {
            let o = Arc::clone(&order);
            high.submit(move || o.lock().unwrap().push(format!("high{i}")));
        }
        // The worker is parked on the gate, so this thread is the only
        // consumer: helping drains the high lane first, then the low one.
        high.wait();
        low.wait();
        release.store(true, Ordering::SeqCst);
        drop(blocker);
        assert_eq!(
            *order.lock().unwrap(),
            vec!["high0", "high1", "high2", "low0", "low1", "low2"],
            "all high jobs must dequeue before any queued low job"
        );
    }

    #[test]
    fn run_with_priorities_returns_results_by_index() {
        for workers in [1, 4] {
            let exec = Executor::new(workers);
            let high =
                exec.run_with(Priority::High, (0..10usize).map(|i| move || i * 3).collect::<Vec<_>>());
            assert_eq!(high, (0..10).map(|i| i * 3).collect::<Vec<_>>(), "workers={workers}");
            let low =
                exec.run_with(Priority::Low, (0..10usize).map(|i| move || i + 1).collect::<Vec<_>>());
            assert_eq!(low, (1..=10).collect::<Vec<_>>(), "workers={workers}");
        }
    }
}
