//! Utility substrate: RNG, statistics, JSON, CLI parsing, config files,
//! bench timing and the scoped worker pool. These stand in for the
//! rand/serde/clap/criterion crates, which are unavailable in this
//! offline environment.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod pvec;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use json::Json;
pub use rng::Pcg;
