//! Utility substrate: RNG, statistics, JSON, CLI parsing, config files,
//! bench timing and the persistent work-stealing executor. These stand in
//! for the rand/serde/clap/criterion/rayon crates, which are unavailable
//! in this offline environment.

pub mod bench;
pub mod cli;
pub mod executor;
pub mod faults;
pub mod json;
pub mod prop;
pub mod pvec;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use executor::Executor;
pub use json::Json;
pub use rng::Pcg;
