//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag --key value --key=value positional` layouts,
//! which is all the `rcc` binary needs.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("tune --workload llama3_attention --samples 600 --seed=7");
        assert_eq!(a.subcommand.as_deref(), Some("tune"));
        assert_eq!(a.opt("workload"), Some("llama3_attention"));
        assert_eq!(a.opt_usize("samples", 0), 600);
        assert_eq!(a.opt_u64("seed", 0), 7);
    }

    #[test]
    fn flags_vs_valued_options() {
        let a = parse("serve --verbose --port 8080 --dry-run");
        assert!(a.has_flag("verbose"));
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.opt("port"), Some("8080"));
    }

    #[test]
    fn positional_args() {
        let a = parse("table1 out.md extra");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.positional, vec!["out.md", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("tune");
        assert_eq!(a.opt_or("platform", "core_i9"), "core_i9");
        assert_eq!(a.opt_f64("c", 1.414), 1.414);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.subcommand.is_none());
    }
}
