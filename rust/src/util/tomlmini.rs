//! TOML-subset parser for the framework config system.
//!
//! Supports: `[section]` / `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.
//! Keys are flattened to dotted paths (`section.sub.key`). This covers
//! everything `configs/*.toml` uses; the real `toml` crate is unavailable
//! offline.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

// Hand-written Display/Error impls: proc-macro crates (thiserror) are kept
// out of the dependency tree so the crate builds in offline environments.
impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(hdr) = line.strip_prefix('[') {
                let hdr = hdr.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = hdr.trim().to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno + 1,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim()).ok_or_else(|| ParseError {
                line: lineno + 1,
                msg: format!("bad value {:?}", val.trim()),
            })?;
            doc.entries.insert(full_key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|x| x as usize)
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All string elements of an array value.
    pub fn get_str_list(&self, key: &str) -> Vec<String> {
        match self.get(key) {
            Some(Value::Arr(items)) => items
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
            _ => Vec::new(),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"')?;
        return Some(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']')?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Some(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

/// Split an array body on commas, ignoring commas inside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_types() {
        let doc = Doc::parse(
            r#"
# experiment config
name = "table1"
repeats = 20

[search]
strategy = "llm_mcts"
exploration_c = 1.4142
branching = 2
verbose = false

[search.llm]
model = "gpt4o_mini"
workloads = ["llama3_attention", "deepseek_moe"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name", ""), "table1");
        assert_eq!(doc.get_usize("repeats", 0), 20);
        assert_eq!(doc.get_str("search.strategy", ""), "llm_mcts");
        assert!((doc.get_f64("search.exploration_c", 0.0) - 1.4142).abs() < 1e-9);
        assert_eq!(doc.get_usize("search.branching", 0), 2);
        assert!(!doc.get_bool("search.verbose", true));
        assert_eq!(
            doc.get_str_list("search.llm.workloads"),
            vec!["llama3_attention", "deepseek_moe"]
        );
    }

    #[test]
    fn comments_in_strings() {
        let doc = Doc::parse(r##"note = "has # inside" # trailing"##).unwrap();
        assert_eq!(doc.get_str("note", ""), "has # inside");
    }

    #[test]
    fn empty_array() {
        let doc = Doc::parse("xs = []").unwrap();
        assert_eq!(doc.get("xs"), Some(&Value::Arr(vec![])));
    }

    #[test]
    fn bad_line_errors() {
        assert!(Doc::parse("just a line").is_err());
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("k = @@").is_err());
    }

    #[test]
    fn defaults_on_missing() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.get_str("missing", "d"), "d");
        assert_eq!(doc.get_f64("missing", 2.5), 2.5);
        assert!(doc.get_bool("missing", true));
    }

    #[test]
    fn float_and_int_coercion() {
        let doc = Doc::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.get_f64("a", 0.0), 3.0);
        assert_eq!(doc.get_f64("b", 0.0), 3.5);
        assert_eq!(doc.get("b").unwrap().as_i64(), None);
    }
}
