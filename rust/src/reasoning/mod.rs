//! The REASONING COMPILER's contribution: LLM-guided contextual proposal
//! generation for MCTS expansion (§3.1).
//!
//! Pipeline per expansion: [`prompt`] serializes the selected node, its
//! ancestor diffs, score trajectory and the available transformation set;
//! an [`engine::LlmEngine`] answers in the Appendix-A response format;
//! [`proposal`] parses, validates and grounds the answer (falling back to
//! the random policy when every proposal is invalid, Appendix G);
//! [`cost_tracker`] meters API spend (Appendix F). [`models`] defines the
//! six simulated model capability profiles (DESIGN.md §Substitutions).

pub mod cost_tracker;
pub mod engine;
pub mod models;
pub mod policy;
pub mod prompt;
pub mod proposal;

pub use cost_tracker::CostTracker;
pub use engine::{LlmEngine, LlmResponse, SimulatedLlm};
pub use models::ModelProfile;
pub use policy::LlmPolicy;
pub use prompt::PromptContext;
