//! The simulated reasoning engine.
//!
//! Plays the role of the paper's LLM: given the prompt context (program
//! text, transformation history, ancestor diffs, cost-model outputs), it
//! produces a *reasoned* transformation sequence plus a natural-language
//! rationale, emitted in the exact response format of Appendix A
//! ("Reasoning: ... / Transformations to apply: ...").
//!
//! The analysis consumes only information present in the prompt: the
//! current program structure, the platform header, the feature block and
//! the ancestor score trajectory. Model capability profiles gate how well
//! that information is used (`quality`, `context_use`) and inject malformed
//! proposals (`invalid_rate`) — reproducing the paper's model-choice,
//! trace-depth and fallback ablations through the same mechanisms the paper
//! varies. Swapping in a real API is one `LlmEngine` implementation.

use std::collections::HashSet;

use crate::cost::{access, platform::Platform, simulator, AnalysisCache};
use crate::schedule::{sampler, Schedule, Transform};
use crate::tir::program::{LoopKind, Program, Stage};
use crate::util::rng::Pcg;

use super::models::ModelProfile;
use super::prompt::{self, PromptContext};

/// A model response: the raw text (parsed downstream by
/// `super::proposal`) plus token accounting.
#[derive(Debug, Clone)]
pub struct LlmResponse {
    pub text: String,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

/// Anything that can answer an optimization prompt. The simulated engine is
/// the offline implementation; a real OpenAI/HF client would implement the
/// same trait.
pub trait LlmEngine: Send {
    fn complete(&mut self, ctx: &PromptContext) -> LlmResponse;
    fn profile(&self) -> &ModelProfile;
}

/// The offline reasoning engine.
pub struct SimulatedLlm {
    pub model: ModelProfile,
    rng: Pcg,
    /// Shared access-analysis memoization: the engine's bottleneck
    /// diagnosis and the prompt's feature block analyze the same stages the
    /// cost models just scored, so the tuner hands every engine the
    /// session-wide cache.
    analysis: AnalysisCache,
}

impl SimulatedLlm {
    pub fn new(model: ModelProfile, seed: u64) -> Self {
        SimulatedLlm {
            model,
            rng: Pcg::new(seed ^ 0x11AA_22BB),
            analysis: AnalysisCache::new(),
        }
    }

    /// Share a session-wide analysis cache (builder style).
    pub fn with_analysis(mut self, analysis: AnalysisCache) -> Self {
        self.analysis = analysis;
        self
    }
}

impl LlmEngine for SimulatedLlm {
    fn complete(&mut self, ctx: &PromptContext) -> LlmResponse {
        let prompt_text = prompt::render_with(ctx, Some(&self.analysis));
        let prompt_tokens = prompt::token_estimate(&prompt_text);

        // Does this round use the full contextual analysis?
        let informed = self.rng.gen_bool(self.model.quality);
        // Does it exploit the historical trace (score trend / avoidance)?
        let use_history = !ctx.ancestors.is_empty() && self.rng.gen_bool(self.model.context_use);

        let avoid = if use_history {
            history_avoid_set(ctx)
        } else {
            HashSet::new()
        };

        // Few-shot exemplars (transfer subsystem): an informed round that
        // also exploits context replays a proven transformation pattern
        // from a structurally similar workload instead of re-deriving one.
        // Gated on exemplars being present so prompt contexts without
        // transfer draw the exact rng sequence they always did.
        let exemplar_try = informed
            && !ctx.exemplars.is_empty()
            && self.rng.gen_bool(self.model.context_use);
        let (transforms, rationale) = if let Some(grounded) = exemplar_try
            .then(|| exemplar_proposals(ctx.node, ctx.exemplars, &mut self.rng))
            .flatten()
        {
            grounded
        } else if informed {
            informed_proposals(ctx.node, ctx.platform, &avoid, &self.analysis, &mut self.rng)
        } else {
            shallow_proposals(&ctx.node.current, &mut self.rng)
        };

        // Emit the response text; each proposal independently risks being
        // malformed per the model's invalid_rate (Appendix G).
        let mut rendered: Vec<String> = Vec::new();
        for t in transforms.iter().take(self.model.proposals_per_call) {
            if self.rng.gen_bool(self.model.invalid_rate) {
                rendered.push(corrupt_proposal(&mut self.rng));
            } else {
                rendered.push(render_transform(t));
            }
        }
        if rendered.is_empty() {
            // Engines always answer something.
            rendered.push(if self.rng.gen_bool(self.model.invalid_rate) {
                corrupt_proposal(&mut self.rng)
            } else {
                "Unroll".to_string()
            });
        }

        let text = format!(
            "Reasoning: {rationale}\nTransformations to apply: {}.",
            rendered.join(", ")
        );
        let completion_tokens =
            self.model.completion_tokens + prompt::token_estimate(&text) / 4;
        LlmResponse { text, prompt_tokens, completion_tokens }
    }

    fn profile(&self) -> &ModelProfile {
        &self.model
    }
}

/// Render a transform in the parameterized textual form the parser accepts.
pub fn render_transform(t: &Transform) -> String {
    match t {
        Transform::TileSize { stage, loop_idx, factor } => {
            format!("TileSize(stage={stage}, loop={loop_idx}, factor={factor})")
        }
        Transform::Reorder { stage, perm } => {
            let p: Vec<String> = perm.iter().map(|x| x.to_string()).collect();
            format!("Reorder(stage={stage}, perm=[{}])", p.join(", "))
        }
        Transform::Fuse { stage, loop_idx } => format!("Fuse(stage={stage}, loop={loop_idx})"),
        Transform::Parallel { stage, loop_idx } => {
            format!("Parallel(stage={stage}, loop={loop_idx})")
        }
        Transform::Vectorize { stage, loop_idx } => {
            format!("Vectorize(stage={stage}, loop={loop_idx})")
        }
        Transform::Unroll { stage, loop_idx } => {
            format!("Unroll(stage={stage}, loop={loop_idx})")
        }
        Transform::ComputeLocation { stage, depth } => {
            format!("ComputeLocation(stage={stage}, depth={depth})")
        }
        Transform::CacheWrite { stage } => format!("CacheWrite(stage={stage})"),
    }
}

/// A malformed proposal: either an unknown op or broken parameters.
fn corrupt_proposal(rng: &mut Pcg) -> String {
    const BAD: [&str; 6] = [
        "TileFusion",
        "LoopJam(stage=0)",
        "Vectorise(loop=j)",
        "TileSize(stage=, factor=abc)",
        "SplitK",
        "Reorder(perm=[banana])",
    ];
    BAD[rng.gen_range(BAD.len())].to_string()
}

/// Ground a proposal directly in a few-shot exemplar: pick one of the top
/// exemplars and replay the prefix of its (already target-rebased) trace
/// that is still legal at this node — at the root that is typically the
/// whole proven sequence, which is what makes transfer-warm LLM searches
/// sample-efficient. Returns `None` when nothing applies here (deep nodes
/// whose schedule state conflicts), letting the caller fall back to the
/// analytical path.
fn exemplar_proposals(
    node: &Schedule,
    exemplars: &[crate::transfer::Exemplar],
    rng: &mut Pcg,
) -> Option<(Vec<Transform>, String)> {
    let pick = rng.gen_range(exemplars.len().min(3));
    let ex = &exemplars[pick];
    let (_, applied) = node.apply_all(&ex.trace);
    if applied == 0 {
        return None;
    }
    Some((
        ex.trace[..applied].to_vec(),
        format!(
            "a structurally similar workload ({}) reached {:.2}x with this transformation \
             pattern; I replay its applicable prefix here",
            ex.workload, ex.speedup
        ),
    ))
}

/// Extract an avoid-set from the ancestor score trajectory: op kinds whose
/// introduction coincided with a score regression. Deeper history attributes
/// more transitions — the mechanism behind the Fig. 4b ablation.
fn history_avoid_set(ctx: &PromptContext) -> HashSet<&'static str> {
    let mut avoid = HashSet::new();
    // scores[0] = node, scores[i] = i-th ancestor. Walk transitions
    // ancestor[i] -> ancestor[i-1] -> node.
    let chain: Vec<&Schedule> = std::iter::once(ctx.node)
        .chain(ctx.ancestors.iter().copied())
        .collect();
    for i in (1..chain.len()).rev() {
        let newer = chain[i - 1];
        let older = chain[i];
        let (s_new, s_old) = (ctx.scores[i - 1], ctx.scores[i]);
        if s_new < s_old * 0.98 {
            for t in newer.trace.iter().skip(older.trace.len()) {
                avoid.insert(t.op_name());
            }
        }
    }
    avoid
}

/// Shallow proposal: plausible op names with weakly-grounded parameters —
/// what a small model produces without really reading the context.
fn shallow_proposals(program: &Program, rng: &mut Pcg) -> (Vec<Transform>, String) {
    let mut out = Vec::new();
    let mut cur = program.clone();
    let n = 1 + rng.gen_range(3);
    for _ in 0..n {
        if let Some(t) = sampler::random_transform(&cur, rng) {
            if let Ok(next) = t.apply(&cur) {
                cur = next;
                out.push(t);
            }
        }
    }
    (
        out,
        "The loops look large, so applying some tiling and annotations should help."
            .to_string(),
    )
}

/// The informed analysis: diagnose the dominant bottleneck of the worst
/// stage from the cost-model features and synthesize a transformation
/// sequence that addresses it, honoring the avoid-set from history. All
/// access analyses — the stage-selection sweep and every re-analysis after
/// a planned fix — go through the shared `analysis` cache, so the stages
/// the cost models just scored (and the repeats of this proposal round)
/// are never re-analyzed.
pub fn informed_proposals(
    node: &Schedule,
    platform: &Platform,
    avoid: &HashSet<&'static str>,
    analysis: &AnalysisCache,
    rng: &mut Pcg,
) -> (Vec<Transform>, String) {
    let program = &node.current;
    // Target the stage dominating latency.
    let (si, _) = program
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let a = analysis.analyze(program, s);
            (i, simulator::stage_latency(&a, platform))
        })
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();

    // Build candidate fixes in priority order; skip avoided kinds.
    let mut scratch = program.clone();
    let mut seq: Vec<Transform> = Vec::new();
    let mut notes: Vec<String> = Vec::new();

    let push = |scratch: &mut Program, seq: &mut Vec<Transform>, t: Transform| -> bool {
        match t.apply(scratch) {
            Ok(next) => {
                *scratch = next;
                seq.push(t);
                true
            }
            Err(_) => false,
        }
    };

    // Re-analyze helper: one shared-cache closure for every step below (the
    // selection sweep above already populated the entry for `scratch`'s
    // starting state, and steps whose plan did not apply hit it again).
    let analyze = |p: &Program| analysis.analyze(p, &p.stages[si]);

    // --- 1. parallelism -----------------------------------------------------
    let a0 = analyze(&scratch);
    if !avoid.contains("Parallel")
        && (a0.parallel_extent as f64) < platform.cores as f64
        && a0.total_iters > 1 << 14
    {
        if let Some(ts) = plan_parallel(&scratch.stages[si], si, platform) {
            for t in ts {
                push(&mut scratch, &mut seq, t);
            }
            notes.push(format!(
                "the nest exposes no parallelism for {} cores, so I parallelize the outer spatial loops",
                platform.cores
            ));
        }
    }

    // --- 2. vectorization ----------------------------------------------------
    let a1 = analyze(&scratch);
    if !avoid.contains("Vectorize") && a1.vector_extent.is_none() {
        if let Some(ts) = plan_vectorize(&scratch, si, platform, rng) {
            for t in ts {
                push(&mut scratch, &mut seq, t);
            }
            notes.push(format!(
                "the innermost loop is not SIMD-vectorized; I move a contiguous spatial loop inside and vectorize it {}-wide",
                platform.simd_lanes
            ));
        }
    }

    // --- 3. cache tiling -------------------------------------------------
    let a2 = analyze(&scratch);
    let cold = a2.footprint_bytes[0] as f64;
    let dram = access::traffic_bytes(&a2, platform.l3_bytes as i64, 1.0);
    let l2t = access::traffic_bytes(&a2, platform.l1d_bytes as i64, 1.0);
    if !avoid.contains("TileSize") && seq.len() < 5 && (dram / cold.max(1.0) > 2.5 || l2t / cold.max(1.0) > 16.0)
    {
        if let Some(ts) = plan_cache_tiling(&scratch, si, platform, rng) {
            for t in ts {
                push(&mut scratch, &mut seq, t);
            }
            notes.push(
                "memory traffic is amplified well beyond compulsory misses; I tile the large spatial and reduction loops so the working tile fits cache and reorder for reuse"
                    .to_string(),
            );
        }
    }

    // --- 4. accumulation chains / unroll -------------------------------------
    let a3 = analyze(&scratch);
    // Target the register-tile cap (64 chains): below that, the FMA
    // latency bound dominates the issue bound.
    if !avoid.contains("Unroll") && a3.chains < 48 && seq.len() < 6 {
        if let Some(ts) = plan_unroll(&scratch, si) {
            for t in ts {
                push(&mut scratch, &mut seq, t);
            }
            notes.push(
                "few independent accumulation chains limit FMA pipelining; unrolling a small register tile breaks the dependence"
                    .to_string(),
            );
        }
    }

    // --- 5. write-back locality ----------------------------------------------
    let a4 = analyze(&scratch);
    let store_elems = a4
        .accesses
        .iter()
        .find(|acc| acc.is_store)
        .map(|acc| acc.elems_at_depth[0])
        .unwrap_or(1);
    if !avoid.contains("CacheWrite")
        && !scratch.stages[si].cache_write
        && a4.writebacks > store_elems * 2
        && seq.len() < 7
    {
        if push(&mut scratch, &mut seq, Transform::CacheWrite { stage: si }) {
            let depth = scratch.stages[si].loops.len() / 2;
            if depth > 0 {
                push(
                    &mut scratch,
                    &mut seq,
                    Transform::ComputeLocation { stage: si, depth },
                );
            }
            notes.push(
                "accumulation is repeatedly interrupted; a local write cache with a hoisted compute location removes the spills"
                    .to_string(),
            );
        }
    }

    if seq.is_empty() {
        // Everything looks structurally healthy: micro-tune (re-tile or
        // unroll something small) instead of doing nothing.
        let (ts, note) = shallow_proposals(&scratch, rng);
        return (
            ts,
            format!("the schedule already has parallel, vector and tiled structure; {note}"),
        );
    }

    (seq, notes.join("; "))
}

/// Plan a parallelization prefix: tile the *largest* spatial loop into
/// a few-times-cores chunks, hoist the chunk loop to the front and mark it
/// parallel. Hoisting the widest spatial dimension outermost doubles as a
/// streaming-order fix: the biggest buffer is swept once while the small
/// operands stay cache-resident inside each chunk.
fn plan_parallel(stage: &Stage, si: usize, platform: &Platform) -> Option<Vec<Transform>> {
    let n = stage.loops.len();
    let prefix = stage
        .loops
        .iter()
        .take_while(|l| l.kind == LoopKind::Parallel)
        .count();
    if prefix >= n {
        return None;
    }
    // Largest spatial serial loop.
    let cand = (0..n)
        .filter(|&i| !stage_is_reduction(stage, i) && stage.loops[i].kind == LoopKind::Serial)
        .max_by_key(|&i| stage.loops[i].extent)?;
    let extent = stage.loops[cand].extent;
    let cores = platform.cores as i64;

    let mut seq = Vec::new();
    let mut n_after = n;
    // Tile so the chunk count lands around 4-8x cores (good balance without
    // starving the inner tile).
    let target_chunks = (cores * 8).min(extent.max(1));
    if extent > target_chunks * 2 {
        let divs = sampler::divisors(extent);
        let want_inner = (extent / target_chunks).max(2);
        if let Some(f) = divs
            .iter()
            .copied()
            .filter(|&f| f >= want_inner / 2 && f <= want_inner * 4)
            .min_by_key(|&f| (f - want_inner).abs())
            .or_else(|| divs.iter().copied().min_by_key(|&f| (f - want_inner).abs()))
        {
            seq.push(Transform::TileSize { stage: si, loop_idx: cand, factor: f });
            n_after += 1;
        }
    }
    // Move the chunk loop to the front of the serial region.
    if cand != prefix {
        let mut perm: Vec<usize> = (0..n_after).filter(|&i| i != cand).collect();
        perm.insert(prefix, cand);
        seq.push(Transform::Reorder { stage: si, perm });
    }
    seq.push(Transform::Parallel { stage: si, loop_idx: prefix });
    Some(seq)
}

/// Plan vectorization: choose a spatial loop with contiguous access, tile it
/// to a SIMD-friendly width, move the inner tile innermost and vectorize.
fn plan_vectorize(
    program: &Program,
    si: usize,
    platform: &Platform,
    _rng: &mut Pcg,
) -> Option<Vec<Transform>> {
    let stage = &program.stages[si];
    let n = stage.loops.len();
    // Score candidate loops by contiguity (prefer store-contiguous).
    let strides = loop_access_strides(program, stage);
    let mut best: Option<(usize, i64)> = None; // (loop idx, extent)
    for li in 0..n {
        if stage_is_reduction(stage, li) || stage.loops[li].kind != LoopKind::Serial {
            continue;
        }
        let contiguous = strides[li].iter().any(|&s| s == 1);
        let no_bad_store = strides[li].last().map(|&s| s <= 1).unwrap_or(true);
        if contiguous && no_bad_store {
            let e = stage.loops[li].extent;
            if best.map(|(_, be)| e > be).unwrap_or(true) {
                best = Some((li, e));
            }
        }
    }
    let (li, extent) = best?;
    let lanes = platform.simd_lanes as i64;
    let mut seq = Vec::new();
    let mut inner_idx = li;
    let mut inner_extent = extent;
    if extent > 4 * lanes {
        // Tile to a SIMD-friendly inner width.
        let divs = sampler::divisors(extent);
        let factor = divs
            .iter()
            .copied()
            .filter(|&f| f >= lanes && f <= 4 * lanes)
            .min_by_key(|&f| (f - 2 * lanes).abs())
            .or_else(|| divs.iter().copied().filter(|&f| f <= 64).max())?;
        seq.push(Transform::TileSize { stage: si, loop_idx: li, factor });
        inner_idx = li + 1;
        inner_extent = factor;
    }
    if inner_extent > 64 {
        return None;
    }
    let n_after = if seq.is_empty() { n } else { n + 1 };
    if inner_idx != n_after - 1 {
        // Move the inner tile innermost.
        let mut perm: Vec<usize> = (0..n_after).filter(|&i| i != inner_idx).collect();
        perm.push(inner_idx);
        seq.push(Transform::Reorder { stage: si, perm });
    }
    seq.push(Transform::Vectorize { stage: si, loop_idx: n_after - 1 });
    Some(seq)
}

/// Plan cache tiling: tile the largest reduction loop and the largest
/// non-vectorized spatial loop, then order tiles for reuse.
fn plan_cache_tiling(
    program: &Program,
    si: usize,
    platform: &Platform,
    _rng: &mut Pcg,
) -> Option<Vec<Transform>> {
    let stage = &program.stages[si];
    let n = stage.loops.len();
    let serial_big = |li: usize| stage.loops[li].kind == LoopKind::Serial && stage.loops[li].extent >= 32;
    let red = (0..n)
        .filter(|&i| stage_is_reduction(stage, i) && serial_big(i))
        .max_by_key(|&i| stage.loops[i].extent);
    let spa = (0..n)
        .filter(|&i| !stage_is_reduction(stage, i) && serial_big(i))
        .max_by_key(|&i| stage.loops[i].extent);

    // Pick tile factors so one tile of each streamed buffer ~ fits L2/4.
    let pick_factor = |extent: i64, target: i64| -> Option<i64> {
        let divs = sampler::divisors(extent);
        divs.iter()
            .copied()
            .filter(|&f| f <= target.max(4))
            .max()
            .or_else(|| divs.first().copied())
    };
    let target = ((platform.l2_bytes as i64 / 4 / 4).max(64) as f64).sqrt() as i64;

    let mut seq = Vec::new();
    let mut scratch = program.clone();
    let mut tiled_any = false;
    // Tile the reduction loop first (indices of later loops shift by 1).
    if let Some(rk) = red {
        if let Some(f) = pick_factor(stage.loops[rk].extent, target) {
            let t = Transform::TileSize { stage: si, loop_idx: rk, factor: f };
            if let Ok(next) = t.apply(&scratch) {
                scratch = next;
                seq.push(t);
                tiled_any = true;
            }
        }
    }
    if let Some(sk0) = spa {
        // Recompute index in the scratch program (shifted if after the split).
        let sk = match red {
            Some(rk) if sk0 > rk && tiled_any => sk0 + 1,
            _ => sk0,
        };
        let extent = scratch.stages[si].loops.get(sk)?.extent;
        if extent >= 32 {
            if let Some(f) = pick_factor(extent, target) {
                let t = Transform::TileSize { stage: si, loop_idx: sk, factor: f };
                if let Ok(next) = t.apply(&scratch) {
                    scratch = next;
                    seq.push(t);
                    tiled_any = true;
                }
            }
        }
    }
    if !tiled_any {
        return None;
    }
    // Reorder: parallel prefix, then outer tiles/spatial, then reduction
    // outers, then the inner tiles, vectorized loop pinned last.
    let st = &scratch.stages[si];
    let m = st.loops.len();
    let mut front: Vec<usize> = Vec::new();
    let mut mids: Vec<usize> = Vec::new();
    let mut inners: Vec<usize> = Vec::new();
    let mut last: Vec<usize> = Vec::new();
    for i in 0..m {
        match st.loops[i].kind {
            LoopKind::Parallel => front.push(i),
            LoopKind::Vectorized => last.push(i),
            _ => {
                if st.loops[i].extent <= target.max(64) && st.loops[i].name.ends_with("_1") {
                    inners.push(i);
                } else {
                    mids.push(i);
                }
            }
        }
    }
    let mut perm = front;
    perm.extend(mids);
    perm.extend(inners);
    perm.extend(last);
    if perm.iter().enumerate().any(|(i, &p)| i != p) {
        seq.push(Transform::Reorder { stage: si, perm });
    }
    Some(seq)
}

/// Plan a register tile: unroll small loops adjacent to the innermost
/// position (spatial loops multiply independent accumulators directly;
/// unrolled reduction loops let the backend reassociate), creating one
/// from a larger loop when none exists.
fn plan_unroll(program: &Program, si: usize) -> Option<Vec<Transform>> {
    let stage = &program.stages[si];
    let n = stage.loops.len();
    let mut seq = Vec::new();
    // Unroll up to two nearest-to-innermost small serial loops.
    for li in (0..n).rev() {
        let l = &stage.loops[li];
        if l.kind == LoopKind::Serial && l.extent >= 2 && l.extent <= 16 {
            seq.push(Transform::Unroll { stage: si, loop_idx: li });
            if seq.len() == 2 {
                return Some(seq);
            }
        }
    }
    if !seq.is_empty() {
        return Some(seq);
    }
    // No small loop: carve a register tile out of a spatial loop first,
    // falling back to a reduction loop (reassociation still helps).
    for spatial_first in [true, false] {
        for li in (0..n).rev() {
            let l = &stage.loops[li];
            if l.kind == LoopKind::Serial
                && stage_is_reduction(stage, li) != spatial_first
                && l.extent % 4 == 0
                && l.extent > 16
            {
                return Some(vec![
                    Transform::TileSize { stage: si, loop_idx: li, factor: 4 },
                    Transform::Unroll { stage: si, loop_idx: li + 1 },
                ]);
            }
        }
    }
    None
}

fn stage_is_reduction(stage: &Stage, li: usize) -> bool {
    stage.loop_is_reduction(li)
}

/// Stride of each access's flattened index w.r.t. each loop (elements).
fn loop_access_strides(program: &Program, stage: &Stage) -> Vec<Vec<i64>> {
    let mut loads = Vec::new();
    stage.block.rhs.loads(&mut loads);
    let mut accesses: Vec<(usize, Vec<crate::tir::LinIdx>)> = loads
        .into_iter()
        .map(|(b, idx)| (b, idx.to_vec()))
        .collect();
    accesses.push((stage.block.out, stage.block.out_idx.clone()));

    let env0 = vec![0i64; stage.var_extents.len()];
    (0..stage.loops.len())
        .map(|li| {
            let mut env1 = env0.clone();
            env1[stage.loops[li].var] = 1;
            let axis_delta: Vec<i64> = stage
                .axis_exprs
                .iter()
                .map(|e| e.eval(&env1) - e.eval(&env0))
                .collect();
            accesses
                .iter()
                .map(|(b, idx)| {
                    let strides = program.buffers[*b].strides();
                    idx.iter()
                        .enumerate()
                        .map(|(dim, ix)| {
                            let d: i64 = ix.terms.iter().map(|&(a, k)| axis_delta[a] * k).sum();
                            d * strides[dim]
                        })
                        .sum::<i64>()
                        .abs()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Platform;
    use crate::tir::workload::WorkloadId;

    fn ctx_and_engine(model: ModelProfile) -> (Schedule, Platform, SimulatedLlm) {
        (
            Schedule::new(WorkloadId::DeepSeekMoe.build()),
            Platform::core_i9(),
            SimulatedLlm::new(model, 99),
        )
    }

    #[test]
    fn informed_proposals_apply_and_improve() {
        let node = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let plat = Platform::core_i9();
        let mut rng = Pcg::new(1);
        let cache = AnalysisCache::new();
        let (seq, rationale) =
            informed_proposals(&node, &plat, &HashSet::new(), &cache, &mut rng);
        assert!(!seq.is_empty());
        assert!(!rationale.is_empty());
        let (out, applied) = node.apply_all(&seq);
        assert_eq!(applied, seq.len(), "all informed steps must be legal");
        let before = simulator::simulate(&node.current, &plat, 0);
        let after = simulator::simulate(&out.current, &plat, 0);
        assert!(
            after < before,
            "informed proposal should improve: {after} vs {before}"
        );
    }

    #[test]
    fn informed_improves_all_workloads_all_platforms() {
        for w in WorkloadId::ALL {
            for plat in Platform::all() {
                let node = Schedule::new(w.build());
                let mut rng = Pcg::new(7);
                let cache = AnalysisCache::new();
                let (seq, _) =
                    informed_proposals(&node, &plat, &HashSet::new(), &cache, &mut rng);
                let (out, _) = node.apply_all(&seq);
                let before = simulator::simulate(&node.current, &plat, 0);
                let after = simulator::simulate(&out.current, &plat, 0);
                assert!(
                    after < before,
                    "{} on {}: {after} vs {before}",
                    w.name(),
                    plat.name
                );
            }
        }
    }

    #[test]
    fn response_format_matches_appendix() {
        let (node, plat, mut engine) = ctx_and_engine(ModelProfile::gpt4o_mini());
        let ctx = PromptContext {
            node: &node,
            ancestors: vec![],
            scores: vec![1.0],
            platform: &plat,
            exemplars: &[],
        };
        let r = engine.complete(&ctx);
        assert!(r.text.starts_with("Reasoning: "), "{}", r.text);
        assert!(r.text.contains("Transformations to apply: "), "{}", r.text);
        assert!(r.prompt_tokens > 100);
        assert!(r.completion_tokens > 0);
    }

    #[test]
    fn weak_model_emits_invalid_sometimes() {
        let (node, plat, mut engine) = ctx_and_engine(ModelProfile::deepseek_distill_7b());
        let mut saw_bad = false;
        for _ in 0..40 {
            let ctx = PromptContext {
                node: &node,
                ancestors: vec![],
                scores: vec![1.0],
                platform: &plat,
                exemplars: &[],
            };
            let r = engine.complete(&ctx);
            if r.text.contains("TileFusion")
                || r.text.contains("LoopJam")
                || r.text.contains("Vectorise")
                || r.text.contains("SplitK")
                || r.text.contains("banana")
                || r.text.contains("factor=abc")
            {
                saw_bad = true;
                break;
            }
        }
        assert!(saw_bad, "7B model should emit malformed proposals");
    }

    #[test]
    fn strong_model_never_invalid() {
        let (node, plat, mut engine) = ctx_and_engine(ModelProfile::gpt4o_mini());
        for _ in 0..40 {
            let ctx = PromptContext {
                node: &node,
                ancestors: vec![],
                scores: vec![1.0],
                platform: &plat,
                exemplars: &[],
            };
            let r = engine.complete(&ctx);
            assert!(!r.text.contains("TileFusion"));
            assert!(!r.text.contains("banana"));
        }
    }

    #[test]
    fn exemplars_ground_proposals_in_proven_traces() {
        use crate::transfer::Exemplar;
        let node = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let exemplars = vec![Exemplar {
            workload: "llama4_mlp".to_string(),
            speedup: 4.0,
            distance: 0.8,
            trace: vec![
                Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 },
                Transform::Parallel { stage: 0, loop_idx: 0 },
            ],
            rendered: "  1. TileSize(...)\n  2. Parallel(...)".to_string(),
        }];
        // The grounding helper replays the full trace at the root.
        let mut rng = Pcg::new(3);
        let (seq, why) = exemplar_proposals(&node, &exemplars, &mut rng).unwrap();
        assert_eq!(seq, exemplars[0].trace);
        assert!(why.contains("llama4_mlp"));
        // When no prefix of the exemplar trace applies, the helper declines
        // and the engine falls back to its analytical path.
        let bad = vec![Exemplar {
            workload: "x".to_string(),
            speedup: 2.0,
            distance: 0.1,
            trace: vec![Transform::CacheWrite { stage: 9 }],
            rendered: String::new(),
        }];
        let mut rng2 = Pcg::new(3);
        assert!(exemplar_proposals(&node, &bad, &mut rng2).is_none());

        // End to end: a strong model with exemplars eventually emits the
        // exemplar's parameterized steps in its response text.
        let plat = Platform::core_i9();
        let mut engine = SimulatedLlm::new(ModelProfile::gpt4o_mini(), 11);
        let mut saw_exemplar_reasoning = false;
        for _ in 0..40 {
            let ctx = PromptContext {
                node: &node,
                ancestors: vec![],
                scores: vec![1.0],
                platform: &plat,
                exemplars: &exemplars,
            };
            let r = engine.complete(&ctx);
            assert!(r.text.contains("Transformations to apply:"));
            if r.text.contains("structurally similar workload") {
                saw_exemplar_reasoning = true;
                assert!(r.text.contains("TileSize(stage=0, loop=1, factor=64)"));
                break;
            }
        }
        assert!(
            saw_exemplar_reasoning,
            "gpt4o-mini (quality 0.9+, context_use high) must use exemplars within 40 rounds"
        );
    }

    #[test]
    fn avoid_set_built_from_regressions() {
        let base = Schedule::new(WorkloadId::Llama4Mlp.build());
        let child = base
            .apply(Transform::Unroll { stage: 0, loop_idx: 0 })
            .unwrap();
        let plat = Platform::core_i9();
        // Child scored worse than parent -> Unroll lands in the avoid set.
        let ctx = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![0.5, 1.0],
            platform: &plat,
            exemplars: &[],
        };
        let avoid = history_avoid_set(&ctx);
        assert!(avoid.contains("Unroll"));
        // Improvement -> nothing avoided.
        let ctx2 = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![1.5, 1.0],
            platform: &plat,
            exemplars: &[],
        };
        assert!(history_avoid_set(&ctx2).is_empty());
    }

    #[test]
    fn render_transform_roundtrip_format() {
        let t = Transform::TileSize { stage: 0, loop_idx: 2, factor: 16 };
        assert_eq!(render_transform(&t), "TileSize(stage=0, loop=2, factor=16)");
        let r = Transform::Reorder { stage: 1, perm: vec![2, 0, 1] };
        assert_eq!(render_transform(&r), "Reorder(stage=1, perm=[2, 0, 1])");
    }
}
