//! Proposal parsing, validation and grounding (§3.1 "Transformation
//! proposal and validation", Appendix G).
//!
//! The LLM answers in free text; the compiler extracts the
//! "Transformations to apply:" list, validates each item against the known
//! transformation set, grounds under-specified items (bare op names) with
//! concrete parameters, and — when *all* items are invalid — falls back to
//! the non-LLM expansion policy. Fallback occurrences are counted for
//! Table 8.

use crate::schedule::{sampler, Transform};
use crate::tir::Program;
use crate::util::rng::Pcg;

/// Outcome of parsing one proposal item.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Parsed {
    /// Fully-parameterized valid transform.
    Valid(Transform),
    /// Recognized op name without (complete) parameters — grounded later.
    Bare(&'static str),
    /// Unrecognized or malformed.
    Invalid(String),
}

/// Parse the "Transformations to apply:" list out of a model response.
pub fn parse_response(text: &str) -> Vec<Parsed> {
    let Some(line) = text
        .lines()
        .find(|l| l.trim_start().starts_with("Transformations to apply:"))
    else {
        return Vec::new();
    };
    let list = line
        .trim_start()
        .trim_start_matches("Transformations to apply:")
        .trim()
        .trim_end_matches('.');
    split_items(list).into_iter().map(|s| parse_item(s.trim())).collect()
}

/// Split on top-level commas (commas inside `[...]` or `(...)` don't count).
fn split_items(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth -= 1,
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

fn parse_item(item: &str) -> Parsed {
    if item.is_empty() {
        return Parsed::Invalid(String::new());
    }
    let (name, args) = match item.split_once('(') {
        Some((n, rest)) => (n.trim(), Some(rest.trim_end_matches(')'))),
        None => (item.trim(), None),
    };
    let Some(canonical) = Transform::OP_NAMES.iter().find(|&&op| op == name) else {
        return Parsed::Invalid(item.to_string());
    };
    let Some(args) = args else {
        return Parsed::Bare(canonical);
    };
    match parse_args(canonical, args) {
        Some(t) => Parsed::Valid(t),
        // Recognized name with broken params: still salvageable as bare
        // (the framework re-grounds the parameters).
        None => Parsed::Bare(canonical),
    }
}

fn parse_args(op: &str, args: &str) -> Option<Transform> {
    let mut stage = None;
    let mut loop_idx = None;
    let mut factor = None;
    let mut depth = None;
    let mut perm: Option<Vec<usize>> = None;
    for part in split_items(args) {
        let (k, v) = part.split_once('=')?;
        let (k, v) = (k.trim(), v.trim());
        match k {
            "stage" => stage = v.parse::<usize>().ok(),
            "loop" | "loop_idx" => loop_idx = v.parse::<usize>().ok(),
            "factor" => factor = v.parse::<i64>().ok(),
            "depth" => depth = v.parse::<usize>().ok(),
            "perm" => {
                let inner = v.trim_start_matches('[').trim_end_matches(']');
                let parsed: Result<Vec<usize>, _> = inner
                    .split(',')
                    .map(|x| x.trim().parse::<usize>())
                    .collect();
                perm = parsed.ok();
            }
            _ => return None,
        }
        // Any unparsable required field surfaces as None below.
        if k == "stage" && stage.is_none() {
            return None;
        }
    }
    let s = stage?;
    Some(match op {
        "TileSize" => Transform::TileSize { stage: s, loop_idx: loop_idx?, factor: factor? },
        "Reorder" => Transform::Reorder { stage: s, perm: perm? },
        "Fuse" => Transform::Fuse { stage: s, loop_idx: loop_idx? },
        "Parallel" => Transform::Parallel { stage: s, loop_idx: loop_idx? },
        "Vectorize" => Transform::Vectorize { stage: s, loop_idx: loop_idx? },
        "Unroll" => Transform::Unroll { stage: s, loop_idx: loop_idx? },
        "ComputeLocation" => Transform::ComputeLocation { stage: s, depth: depth? },
        "CacheWrite" => Transform::CacheWrite { stage: s },
        _ => return None,
    })
}

/// Ground a bare op name into a concrete transform legal for `program`
/// (the framework samples parameters, as MetaSchedule does for
/// under-specified instructions).
pub fn ground(op: &str, program: &Program, rng: &mut Pcg) -> Option<Transform> {
    let candidates: Vec<Transform> = sampler::legal_transforms(program, rng)
        .into_iter()
        .filter(|t| t.op_name() == op)
        .collect();
    if candidates.is_empty() {
        return None;
    }
    Some(rng.choose(&candidates).clone())
}

/// Count a parsed list by outcome — the audit plane's per-call
/// attribution triple (valid / bare-needs-grounding / invalid).
pub fn classify(parsed: &[Parsed]) -> (u64, u64, u64) {
    let (mut valid, mut bare, mut invalid) = (0u64, 0u64, 0u64);
    for p in parsed {
        match p {
            Parsed::Valid(_) => valid += 1,
            Parsed::Bare(_) => bare += 1,
            Parsed::Invalid(_) => invalid += 1,
        }
    }
    (valid, bare, invalid)
}

/// Statistics for Table 8: expansions vs all-invalid fallbacks.
#[derive(Debug, Clone, Default)]
pub struct FallbackStats {
    pub expansions: u64,
    pub fallbacks: u64,
    pub proposals_seen: u64,
    pub proposals_invalid: u64,
}

impl FallbackStats {
    pub fn fallback_rate(&self) -> f64 {
        if self.expansions == 0 {
            0.0
        } else {
            self.fallbacks as f64 / self.expansions as f64
        }
    }
}

/// Resolve a parsed proposal list into an applicable transform sequence.
/// Invalid items are discarded; bare items are grounded. Returns the
/// sequence plus whether this expansion was a total fallback (no usable
/// proposal at all).
pub fn resolve(
    parsed: &[Parsed],
    program: &Program,
    rng: &mut Pcg,
    stats: &mut FallbackStats,
) -> (Vec<Transform>, bool) {
    stats.expansions += 1;
    let mut out = Vec::new();
    for p in parsed {
        stats.proposals_seen += 1;
        match p {
            Parsed::Valid(t) => out.push(t.clone()),
            Parsed::Bare(op) => {
                if let Some(t) = ground(op, program, rng) {
                    out.push(t);
                } else {
                    stats.proposals_invalid += 1;
                }
            }
            Parsed::Invalid(_) => stats.proposals_invalid += 1,
        }
    }
    let fallback = out.is_empty();
    if fallback {
        stats.fallbacks += 1;
    }
    (out, fallback)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload::WorkloadId;

    #[test]
    fn parses_parameterized_list() {
        let text = "Reasoning: tile then vectorize.\n\
                    Transformations to apply: TileSize(stage=0, loop=1, factor=64), \
                    Reorder(stage=0, perm=[0, 1, 3, 2]), Vectorize(stage=0, loop=3).";
        let parsed = parse_response(text);
        assert_eq!(parsed.len(), 3);
        assert_eq!(
            parsed[0],
            Parsed::Valid(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
        );
        assert_eq!(
            parsed[1],
            Parsed::Valid(Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2] })
        );
    }

    #[test]
    fn parses_bare_names_like_paper_example() {
        // The Appendix-A example answer: "TileSize, TileSize, Unroll."
        let text = "Reasoning: ...\nTransformations to apply: TileSize, TileSize, Unroll.";
        let parsed = parse_response(text);
        assert_eq!(
            parsed,
            vec![
                Parsed::Bare("TileSize"),
                Parsed::Bare("TileSize"),
                Parsed::Bare("Unroll")
            ]
        );
    }

    #[test]
    fn flags_unknown_ops() {
        let text = "Transformations to apply: TileFusion, LoopJam(stage=0), Parallel.";
        let parsed = parse_response(text);
        assert!(matches!(parsed[0], Parsed::Invalid(_)));
        assert!(matches!(parsed[1], Parsed::Invalid(_)));
        assert_eq!(parsed[2], Parsed::Bare("Parallel"));
    }

    #[test]
    fn malformed_params_degrade_to_bare() {
        let text = "Transformations to apply: TileSize(stage=, factor=abc).";
        let parsed = parse_response(text);
        assert_eq!(parsed, vec![Parsed::Bare("TileSize")]);
    }

    #[test]
    fn missing_list_is_empty() {
        assert!(parse_response("Reasoning: I have no idea.").is_empty());
    }

    #[test]
    fn grounding_produces_legal_transforms() {
        let p = WorkloadId::DeepSeekMoe.build_test();
        let mut rng = Pcg::new(3);
        for op in ["TileSize", "Parallel", "Unroll", "CacheWrite"] {
            let t = ground(op, &p, &mut rng).unwrap_or_else(|| panic!("{op} ungroundable"));
            assert_eq!(t.op_name(), op);
            t.apply(&p).unwrap();
        }
    }

    #[test]
    fn resolve_counts_fallbacks() {
        let p = WorkloadId::Llama4Mlp.build_test();
        let mut rng = Pcg::new(4);
        let mut stats = FallbackStats::default();
        // All invalid -> fallback.
        let parsed = vec![
            Parsed::Invalid("TileFusion".into()),
            Parsed::Invalid("banana".into()),
        ];
        let (seq, fb) = resolve(&parsed, &p, &mut rng, &mut stats);
        assert!(seq.is_empty());
        assert!(fb);
        // One valid among invalid -> no fallback.
        let parsed = vec![
            Parsed::Invalid("junk".into()),
            Parsed::Bare("Parallel"),
        ];
        let (seq, fb) = resolve(&parsed, &p, &mut rng, &mut stats);
        assert_eq!(seq.len(), 1);
        assert!(!fb);
        assert_eq!(stats.expansions, 2);
        assert_eq!(stats.fallbacks, 1);
        assert!((stats.fallback_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn split_items_respects_brackets() {
        let items = split_items("Reorder(stage=0, perm=[2, 0, 1]), Unroll");
        assert_eq!(items.len(), 2);
        assert!(items[0].contains("perm=[2, 0, 1]"));
    }
}
