//! The LLM-guided MCTS proposal policy: glue between the search engine and
//! the reasoning pipeline (prompt → LLM → parse → validate → ground →
//! fallback), with cost and fallback accounting.

use crate::obs;
use crate::schedule::Transform;
use crate::search::common::{ProposalContext, ProposalPolicy};
use crate::transfer::Exemplar;
use crate::util::faults;
use crate::util::rng::Pcg;

use crate::util::json::{num, s, Json};

use super::cost_tracker::CostTracker;
use super::engine::{LlmEngine, LlmResponse};
use super::proposal::{self, FallbackStats};
use super::prompt::{self, PromptContext};

/// Attempts per LLM call before degrading to the sampler fallback.
pub const MAX_LLM_ATTEMPTS: u64 = 3;

/// Deterministic exponential backoff schedule: 25ms, 50ms, 100ms... The
/// delay is *recorded* (CostTracker::backoff_ms) rather than slept,
/// since the stock engines are simulated; a remote engine adapter would
/// sleep it before re-calling.
pub fn backoff_ms(attempt: u64) -> u64 {
    25u64 << attempt.min(6)
}

/// ProposalPolicy backed by an [`LlmEngine`].
pub struct LlmPolicy<E: LlmEngine> {
    pub engine: E,
    pub costs: CostTracker,
    pub fallbacks: FallbackStats,
    /// Maximum ancestors included in the prompt (2 = parent+grandparent;
    /// 3 adds the great-grandparent — the Fig. 4b ablation).
    pub history_depth: usize,
    /// Few-shot exemplars from the transfer subsystem, embedded in every
    /// prompt of this policy's session (empty = no transfer context).
    pub exemplars: Vec<Exemplar>,
    rng: Pcg,
    /// Most recent raw responses, for logging/inspection (bounded).
    pub transcript: Vec<String>,
    pub log_transcript: bool,
    /// Serial call index; with the policy seed it keys the fault rolls,
    /// so an injected failure schedule is fixed at plan time and
    /// independent of worker count (propose() is serial per search).
    calls_made: u64,
    fault_salt: u64,
}

impl<E: LlmEngine> LlmPolicy<E> {
    pub fn new(engine: E, history_depth: usize, seed: u64) -> Self {
        LlmPolicy {
            engine,
            costs: CostTracker::default(),
            fallbacks: FallbackStats::default(),
            history_depth,
            exemplars: Vec::new(),
            rng: Pcg::new(seed ^ 0x9D_0F_FE),
            transcript: Vec::new(),
            log_transcript: false,
            calls_made: 0,
            fault_salt: seed,
        }
    }

    /// One engine call under the retry policy. `None` = every attempt
    /// failed (injected error or timeout) and the call degrades to the
    /// sampler fallback. With no fault plan armed this is exactly one
    /// `engine.complete` and nothing else.
    fn complete_with_retries(&mut self, prompt_ctx: &PromptContext) -> Option<LlmResponse> {
        let call = self.calls_made;
        self.calls_made += 1;
        for attempt in 0..MAX_LLM_ATTEMPTS {
            let token = self.fault_salt ^ (call * 8 + attempt);
            match faults::llm_fault(token) {
                None => return Some(self.engine.complete(prompt_ctx)),
                Some(kind) => {
                    self.costs.retries += 1;
                    self.costs.backoff_ms += backoff_ms(attempt);
                    obs::instant2(
                        obs::EventKind::LlmRetry,
                        attempt,
                        (kind == faults::LlmFault::Timeout) as u64,
                    );
                }
            }
        }
        self.costs.degraded += 1;
        obs::instant(obs::EventKind::LlmDegrade, call);
        None
    }

    /// Attach transfer-tuning exemplars (builder style).
    pub fn with_exemplars(mut self, exemplars: Vec<Exemplar>) -> Self {
        self.exemplars = exemplars;
        self
    }
}

impl<E: LlmEngine> ProposalPolicy for LlmPolicy<E> {
    fn propose(&mut self, ctx: &ProposalContext) -> Vec<Transform> {
        let prompt_ctx = PromptContext {
            node: ctx.node,
            ancestors: ctx
                .ancestors
                .iter()
                .copied()
                .take(self.history_depth)
                .collect(),
            scores: ctx
                .scores
                .iter()
                .copied()
                .take(self.history_depth + 1)
                .collect(),
            platform: ctx.platform,
            exemplars: &self.exemplars,
        };
        // The span mirrors CostTracker: arg = prompt tokens metered for this
        // call, arg2 = transforms the proposal resolved to.
        let mut llm_span = obs::span(obs::EventKind::LlmCall, 0);
        let call_index = self.calls_made;
        let retries_before = self.costs.retries;
        // A degraded call (every retry failed) parses as an empty proposal
        // list, which `resolve` counts as a fallback — the same sampler
        // path a weak model's all-invalid answer takes, so the session
        // keeps searching instead of erroring.
        let mut degraded = false;
        let (parsed, prompt_tokens) = match self.complete_with_retries(&prompt_ctx) {
            Some(response) => {
                self.costs
                    .record(response.prompt_tokens, response.completion_tokens);
                if self.log_transcript && self.transcript.len() < 64 {
                    self.transcript.push(response.text.clone());
                }
                (proposal::parse_response(&response.text), response.prompt_tokens)
            }
            None => {
                degraded = true;
                (Vec::new(), 0)
            }
        };
        let (seq, fallback) = proposal::resolve(
            &parsed,
            &ctx.node.current,
            &mut self.rng,
            &mut self.fallbacks,
        );
        self.costs.proposals_offered += parsed.len() as u64;
        self.costs.proposals_accepted += seq.len() as u64;
        llm_span.set_args(prompt_tokens, seq.len() as u64);
        // Audit: per-call proposal attribution. The context hash is only
        // computed when armed — prompt rendering is pure, so the disarmed
        // path stays one atomic load.
        if obs::audit::armed() {
            let (valid, bare, invalid) = proposal::classify(&parsed);
            let ctx_hash = obs::audit::fingerprint(&prompt::render(&prompt_ctx));
            let mut r = obs::audit::record("llm", self.fault_salt);
            r.set("call", num(call_index as f64))
                .set("ctx", s(&format!("{ctx_hash:016x}")))
                .set("step", num(ctx.step as f64))
                .set("offered", num(parsed.len() as f64))
                .set("valid", num(valid as f64))
                .set("bare", num(bare as f64))
                .set("invalid", num(invalid as f64))
                .set("expanded", num(seq.len() as f64))
                .set("fallback", Json::Bool(fallback))
                .set("retries", num((self.costs.retries - retries_before) as f64))
                .set("degraded", Json::Bool(degraded));
            obs::audit::emit(r);
        }
        // On total fallback `seq` is empty; the MCTS loop then expands with
        // the default random policy (Appendix G) — uninterrupted search.
        seq
    }

    fn name(&self) -> String {
        format!("llm:{}", self.engine.profile().name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Platform;
    use crate::reasoning::engine::SimulatedLlm;
    use crate::reasoning::models::ModelProfile;
    use crate::schedule::Schedule;
    use crate::tir::workload::WorkloadId;

    fn propose_n(model: ModelProfile, n: usize) -> (LlmPolicy<SimulatedLlm>, usize) {
        let engine = SimulatedLlm::new(model, 5);
        let mut policy = LlmPolicy::new(engine, 2, 5);
        let plat = Platform::core_i9();
        let node = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let mut nonempty = 0;
        for step in 0..n {
            let ctx = ProposalContext {
                node: &node,
                ancestors: vec![],
                scores: vec![1.0],
                platform: &plat,
                step,
            };
            if !policy.propose(&ctx).is_empty() {
                nonempty += 1;
            }
        }
        (policy, nonempty)
    }

    #[test]
    fn proposals_apply_and_costs_accumulate() {
        let (policy, nonempty) = propose_n(ModelProfile::gpt4o_mini(), 10);
        assert_eq!(nonempty, 10, "gpt4o-mini should never fully fall back");
        assert_eq!(policy.costs.calls, 10);
        assert!(policy.costs.prompt_tokens > 1000);
        assert_eq!(policy.fallbacks.fallbacks, 0);
    }

    #[test]
    fn weak_model_falls_back_at_table8_rate() {
        let (policy, _) = propose_n(ModelProfile::deepseek_distill_7b(), 300);
        let rate = policy.fallbacks.fallback_rate();
        // Table 8: 17.2%; allow generous tolerance on 300 draws.
        assert!(
            (0.08..0.30).contains(&rate),
            "7B fallback rate {rate} out of expected band"
        );
    }

    #[test]
    fn policy_name_includes_model() {
        let engine = SimulatedLlm::new(ModelProfile::llama33_70b(), 1);
        let policy = LlmPolicy::new(engine, 2, 1);
        assert_eq!(policy.name(), "llm:llama33_70b");
    }
}
