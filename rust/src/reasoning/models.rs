//! Simulated LLM model profiles.
//!
//! No network access exists in this environment, so the paper's six API
//! models are replaced by capability profiles driving the simulated
//! reasoning engine (DESIGN.md §Substitutions). Each profile controls:
//!
//! - `quality` — probability that a proposal round uses the full contextual
//!   analysis rather than a shallow/plausible guess (the paper's "stronger
//!   models lead to faster convergence", Fig. 4a);
//! - `context_use` — probability the model exploits the *historical trace*
//!   portion of the prompt (deeper-context ablation, Fig. 4b);
//! - `invalid_rate` — per-proposal probability of emitting a malformed
//!   transformation, reproducing the fallback rates of Appendix G/Table 8;
//! - token pricing for the API-cost accounting of Appendix F/Table 7.

/// Capability + pricing profile of one proposal model.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: &'static str,
    pub display: &'static str,
    /// P(informed proposal round).
    pub quality: f64,
    /// P(historical context is exploited when present).
    pub context_use: f64,
    /// Per-proposal probability of a malformed transformation string.
    pub invalid_rate: f64,
    /// Proposals emitted per call.
    pub proposals_per_call: usize,
    /// USD per 1M prompt tokens.
    pub usd_per_m_prompt: f64,
    /// USD per 1M completion tokens.
    pub usd_per_m_completion: f64,
    /// Mean completion length in tokens (reasoning models ramble more).
    pub completion_tokens: u64,
}

impl ModelProfile {
    /// The six models of §4.3.1 / Appendix C, in Table-4 row order.
    pub fn all() -> Vec<ModelProfile> {
        vec![
            ModelProfile::gpt4o_mini(),
            ModelProfile::o1_mini(),
            ModelProfile::llama33_70b(),
            ModelProfile::deepseek_distill_32b(),
            ModelProfile::llama31_8b(),
            ModelProfile::deepseek_distill_7b(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        ModelProfile::all().into_iter().find(|m| m.name == name)
    }

    /// GPT-4o mini — the paper's main proposal model.
    pub fn gpt4o_mini() -> ModelProfile {
        ModelProfile {
            name: "gpt4o_mini",
            display: "GPT-4o mini",
            quality: 0.78,
            context_use: 0.90,
            invalid_rate: 0.0,
            proposals_per_call: 3,
            usd_per_m_prompt: 0.15,
            usd_per_m_completion: 0.60,
            completion_tokens: 420,
        }
    }

    /// OpenAI o1-mini — strongest late-stage optimizer, expensive.
    pub fn o1_mini() -> ModelProfile {
        ModelProfile {
            name: "o1_mini",
            display: "OpenAI o1-mini",
            quality: 0.74,
            context_use: 0.97,
            invalid_rate: 0.0,
            proposals_per_call: 3,
            usd_per_m_prompt: 1.10,
            usd_per_m_completion: 4.40,
            completion_tokens: 900, // hidden reasoning tokens billed
        }
    }

    /// Llama 3.3 70B Instruct — exceptional early sample efficiency.
    pub fn llama33_70b() -> ModelProfile {
        ModelProfile {
            name: "llama33_70b",
            display: "Llama3.3-Instruct (70B)",
            quality: 0.88,
            context_use: 0.92,
            invalid_rate: 0.093, // -> ~0.08% all-invalid fallback at 3/call
            proposals_per_call: 3,
            usd_per_m_prompt: 0.40,
            usd_per_m_completion: 0.40,
            completion_tokens: 450,
        }
    }

    /// DeepSeek-R1-Distill-Qwen 32B — gradual, strong long-horizon.
    pub fn deepseek_distill_32b() -> ModelProfile {
        ModelProfile {
            name: "ds_distill_32b",
            display: "DeepSeek-Distill-Qwen (32B)",
            quality: 0.70,
            context_use: 0.95,
            invalid_rate: 0.119, // -> ~0.17% fallback
            proposals_per_call: 3,
            usd_per_m_prompt: 0.30,
            usd_per_m_completion: 0.30,
            completion_tokens: 520,
        }
    }

    /// Llama 3.1 8B Instruct — small but still useful.
    pub fn llama31_8b() -> ModelProfile {
        ModelProfile {
            name: "llama31_8b",
            display: "Llama3.1-Instruct (8B)",
            quality: 0.52,
            context_use: 0.60,
            invalid_rate: 0.472, // -> ~10.5% fallback
            proposals_per_call: 3,
            usd_per_m_prompt: 0.06,
            usd_per_m_completion: 0.06,
            completion_tokens: 380,
        }
    }

    /// DeepSeek-R1-Distill-Qwen 7B.
    pub fn deepseek_distill_7b() -> ModelProfile {
        ModelProfile {
            name: "ds_distill_7b",
            display: "DeepSeek-Distill-Qwen (7B)",
            quality: 0.46,
            context_use: 0.55,
            invalid_rate: 0.556, // -> ~17.2% fallback
            proposals_per_call: 3,
            usd_per_m_prompt: 0.40,
            usd_per_m_completion: 0.40,
            completion_tokens: 400,
        }
    }

    /// Expected all-proposals-invalid fallback rate (Table 8's metric).
    pub fn expected_fallback_rate(&self) -> f64 {
        self.invalid_rate.powi(self.proposals_per_call as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models() {
        assert_eq!(ModelProfile::all().len(), 6);
        assert!(ModelProfile::by_name("gpt4o_mini").is_some());
        assert!(ModelProfile::by_name("gpt5").is_none());
    }

    #[test]
    fn fallback_rates_match_table8() {
        // Table 8: 0%, 0%, 0.08%, 0.17%, 10.50%, 17.20%.
        let targets = [0.0, 0.0, 0.0008, 0.0017, 0.105, 0.172];
        for (m, t) in ModelProfile::all().iter().zip(targets) {
            let got = m.expected_fallback_rate();
            assert!(
                (got - t).abs() < t * 0.15 + 1e-6,
                "{}: fallback {got} vs table {t}",
                m.name
            );
        }
    }

    #[test]
    fn quality_ordering_matches_paper() {
        // Larger/instruction-tuned models propose better (Fig. 4a).
        let q = |n: &str| ModelProfile::by_name(n).unwrap().quality;
        assert!(q("llama33_70b") > q("gpt4o_mini"));
        assert!(q("gpt4o_mini") > q("llama31_8b"));
        assert!(q("llama31_8b") > q("ds_distill_7b"));
    }

    #[test]
    fn o1_mini_is_most_expensive() {
        let all = ModelProfile::all();
        let o1 = all.iter().find(|m| m.name == "o1_mini").unwrap();
        for m in &all {
            if m.name != "o1_mini" {
                assert!(
                    o1.usd_per_m_completion * o1.completion_tokens as f64
                        > m.usd_per_m_completion * m.completion_tokens as f64
                );
            }
        }
    }
}
