//! API-cost accounting (Appendix F / Table 7).
//!
//! Every LLM call's prompt and completion token counts are metered against
//! the model's per-token prices, so `rcc table7` can report the USD cost of
//! each full experiment the way the paper does.

use super::models::ModelProfile;

#[derive(Debug, Clone, Default)]
pub struct CostTracker {
    /// Completed calls (a call that fails and is retried still counts
    /// once, when an attempt finally succeeds).
    pub calls: u64,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
    /// Failed attempts that were retried (errors + timeouts).
    pub retries: u64,
    /// Calls abandoned after exhausting retries and degraded to the
    /// sampler fallback path.
    pub degraded: u64,
    /// Deterministic backoff the retry policy scheduled, in ms (recorded,
    /// not slept against simulated engines).
    pub backoff_ms: u64,
    /// Proposal items the model offered across completed calls (parsed
    /// list lengths, before validation).
    pub proposals_offered: u64,
    /// Offered items that resolved to applicable transforms (valid as-is
    /// or grounded from a bare op name).
    pub proposals_accepted: u64,
}

impl CostTracker {
    pub fn record(&mut self, prompt_tokens: u64, completion_tokens: u64) {
        self.calls += 1;
        self.prompt_tokens += prompt_tokens;
        self.completion_tokens += completion_tokens;
    }

    /// Total cost in USD under a model's pricing.
    pub fn usd(&self, model: &ModelProfile) -> f64 {
        self.prompt_tokens as f64 * model.usd_per_m_prompt / 1e6
            + self.completion_tokens as f64 * model.usd_per_m_completion / 1e6
    }

    pub fn merge(&mut self, other: &CostTracker) {
        self.calls += other.calls;
        self.prompt_tokens += other.prompt_tokens;
        self.completion_tokens += other.completion_tokens;
        self.retries += other.retries;
        self.degraded += other.degraded;
        self.backoff_ms += other.backoff_ms;
        self.proposals_offered += other.proposals_offered;
        self.proposals_accepted += other.proposals_accepted;
    }

    /// Fraction of offered proposal items that resolved to applicable
    /// transforms (0 when the model offered nothing).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposals_offered == 0 {
            0.0
        } else {
            self.proposals_accepted as f64 / self.proposals_offered as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_prices() {
        let mut t = CostTracker::default();
        t.record(2000, 500);
        t.record(2000, 500);
        assert_eq!(t.calls, 2);
        assert_eq!(t.prompt_tokens, 4000);
        let m = ModelProfile::gpt4o_mini();
        // 4000 * 0.15/1M + 1000 * 0.60/1M = 0.0006 + 0.0006
        assert!((t.usd(&m) - 0.0012).abs() < 1e-9);
    }

    #[test]
    fn o1_costs_more_than_gpt4o_mini() {
        let mut t = CostTracker::default();
        t.record(100_000, 50_000);
        assert!(t.usd(&ModelProfile::o1_mini()) > t.usd(&ModelProfile::gpt4o_mini()) * 5.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = CostTracker::default();
        a.record(10, 20);
        let mut b = CostTracker::default();
        b.record(30, 40);
        a.merge(&b);
        assert_eq!(a.calls, 2);
        assert_eq!(a.prompt_tokens, 40);
        assert_eq!(a.completion_tokens, 60);
    }

    #[test]
    fn acceptance_rate_counts_resolved_proposals() {
        let mut t = CostTracker::default();
        assert_eq!(t.acceptance_rate(), 0.0);
        t.proposals_offered = 8;
        t.proposals_accepted = 6;
        assert!((t.acceptance_rate() - 0.75).abs() < 1e-12);
        let other = CostTracker { proposals_offered: 2, proposals_accepted: 0, ..CostTracker::default() };
        t.merge(&other);
        assert_eq!(t.proposals_offered, 10);
        assert!((t.acceptance_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_resilience_counters() {
        let mut a = CostTracker { retries: 2, degraded: 1, backoff_ms: 75, ..CostTracker::default() };
        let b = CostTracker { retries: 3, degraded: 0, backoff_ms: 25, ..CostTracker::default() };
        a.merge(&b);
        assert_eq!(a.retries, 5);
        assert_eq!(a.degraded, 1);
        assert_eq!(a.backoff_ms, 100);
        // Failed attempts never count as completed calls or tokens.
        assert_eq!(a.calls, 0);
        assert_eq!(a.prompt_tokens, 0);
    }
}
