//! Prompt construction (§3.1, Appendix A).
//!
//! Serializes the MCTS expansion context into the paper's prompt format:
//! the selected node's code, structural diffs against its ancestors (loop
//! shapes, tile decisions), predicted performance scores, the transformation
//! history, and the available transformation set. The simulated engine
//! consumes the structured [`PromptContext`]; the rendered text is what a
//! real API would receive (swap `LlmEngine` implementations to use one) and
//! is logged for inspection.

use crate::cost::{features, AnalysisCache, Platform};
use crate::schedule::{Schedule, Transform};
use crate::tir::printer;
use crate::transfer::{render_exemplar_block, Exemplar};

/// Structured prompt contents for one expansion step.
pub struct PromptContext<'a> {
    pub node: &'a Schedule,
    /// Nearest-first ancestors included per the history-depth config.
    pub ancestors: Vec<&'a Schedule>,
    /// Predicted scores aligned with [node, ancestors...] (higher better).
    pub scores: Vec<f64>,
    pub platform: &'a Platform,
    /// Few-shot exemplars from structurally similar workloads (the
    /// transfer subsystem's accumulated performance feedback); empty when
    /// transfer is disabled or the database has no similar records.
    pub exemplars: &'a [Exemplar],
}

impl<'a> PromptContext<'a> {
    /// History depth actually available (ancestor count).
    pub fn depth(&self) -> usize {
        self.ancestors.len()
    }
}

/// Render the full prompt text in the Appendix-A format.
pub fn render(ctx: &PromptContext) -> String {
    render_with(ctx, None)
}

/// [`render`] with the feature block's access analyses served from a shared
/// [`AnalysisCache`] (the reasoning engine passes its session cache, so
/// repeated prompt rendering on the same node re-analyzes nothing).
pub fn render_with(ctx: &PromptContext, analysis: Option<&AnalysisCache>) -> String {
    let mut out = String::new();
    out.push_str(
        "You are a code optimization assistant performing Monte Carlo Tree Search \
         (MCTS) on a given code to improve performance. Each code has a corresponding \
         history of transformations and predicted cost.\n\n",
    );
    out.push_str(&format!(
        "Target platform: {} ({} cores, {}-lane SIMD, {:.1} GHz, L1 {} KiB / L2 {} KiB / L3 {} MiB, DRAM {:.0} GB/s)\n\n",
        ctx.platform.display,
        ctx.platform.cores,
        ctx.platform.simd_lanes,
        ctx.platform.freq_ghz,
        ctx.platform.l1d_bytes >> 10,
        ctx.platform.l2_bytes >> 10,
        ctx.platform.l3_bytes >> 20,
        ctx.platform.dram_gbps,
    ));

    out.push_str("Code of the selected node:\n```python\n");
    out.push_str(&printer::print_program(&ctx.node.current));
    out.push_str("```\n\n");

    out.push_str("Applied transformation history of the selected node:\n");
    out.push_str(&ctx.node.render_trace());
    out.push('\n');

    out.push_str("\nHardware cost model analysis of the selected node:\n");
    let f = match analysis {
        Some(cache) => features::extract_cached(&ctx.node.current, ctx.platform, cache),
        None => features::extract(&ctx.node.current, ctx.platform),
    };
    out.push_str(&f.render());
    out.push('\n');

    // Ancestor diffs: loop shapes + score trajectory.
    let labels = ["parent", "grandparent", "great-grandparent"];
    for (i, anc) in ctx.ancestors.iter().enumerate() {
        let label = labels.get(i).copied().unwrap_or("ancestor");
        out.push_str(&format!("\nMain differences against the {label}:\nLoop shapes:\n"));
        for (si, stage) in ctx.node.current.stages.iter().enumerate() {
            let cur_sig = printer::loop_signature(stage);
            let anc_sig = anc
                .current
                .stages
                .get(si)
                .map(|s| printer::loop_signature(s))
                .unwrap_or_default();
            if cur_sig != anc_sig {
                out.push_str(&format!(
                    "  stage {}: current: {cur_sig}\n  stage {}: {label}:  {anc_sig}\n",
                    stage.name, stage.name
                ));
            }
        }
        let new_steps: Vec<&Transform> = ctx
            .node
            .trace
            .iter()
            .skip(anc.trace.len())
            .collect();
        if !new_steps.is_empty() {
            out.push_str("Transformations applied since:\n");
            for t in new_steps {
                out.push_str(&format!("  - {}\n", t.render(&ctx.node.current)));
            }
        }
    }

    out.push_str("\nPerformance estimates (higher is better):\n");
    let names = ["Current", "Parent", "Grandparent", "Great-grandparent"];
    for (i, s) in ctx.scores.iter().enumerate() {
        out.push_str(&format!(
            "  {}: {:.3}\n",
            names.get(i).copied().unwrap_or("Ancestor"),
            s
        ));
    }

    if !ctx.exemplars.is_empty() {
        out.push('\n');
        out.push_str(&render_exemplar_block(ctx.exemplars));
    }

    out.push_str(&format!(
        "\nAvailable transformations:\n{}\n",
        Transform::OP_NAMES.join(", ")
    ));
    out.push_str(
        "\nTask\nAnalyze the IR, trace, and predicted scores. Then propose a sequence of \
         transformations (you may repeat any) to potentially improve performance.\n\
         Output your reasoning and your suggested transformations.\n\
         For example, your answer should be in the following format:\n\
         Reasoning: This code still has large loop extents, so I'd tile it twice \
         differently, then unroll.\n\
         Transformations to apply: TileSize, TileSize, Unroll.\n",
    );
    out
}

/// Rough token count of a prompt (4 chars/token — the accounting the cost
/// tracker uses, Appendix F).
pub fn token_estimate(text: &str) -> u64 {
    (text.len() as u64).div_ceil(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload::WorkloadId;

    fn ctx_fixture() -> (Schedule, Schedule, Platform) {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let child = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
            .unwrap();
        (child, base, Platform::core_i9())
    }

    #[test]
    fn prompt_has_paper_sections() {
        let (child, base, plat) = ctx_fixture();
        let ctx = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![0.773, 0.313],
            platform: &plat,
            exemplars: &[],
        };
        let text = render(&ctx);
        assert!(text.contains("Monte Carlo Tree Search"));
        assert!(text.contains("@tvm.script.ir_module"));
        assert!(text.contains("Available transformations:"));
        assert!(text.contains("TileSize, Reorder, Fuse, Parallel"));
        assert!(text.contains("Performance estimates"));
        assert!(text.contains("Current: 0.773"));
        assert!(text.contains("Parent: 0.313"));
        assert!(text.contains("Transformations to apply:"));
        assert!(text.contains("differences against the parent"));
    }

    #[test]
    fn deeper_history_renders_more_sections() {
        let (child, base, plat) = ctx_fixture();
        let gchild = child
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        let ctx = PromptContext {
            node: &gchild,
            ancestors: vec![&child, &base],
            scores: vec![0.9, 0.773, 0.313],
            platform: &plat,
            exemplars: &[],
        };
        let text = render(&ctx);
        assert!(text.contains("differences against the parent"));
        assert!(text.contains("differences against the grandparent"));
        assert!(text.contains("Grandparent: 0.313"));
    }

    #[test]
    fn exemplar_block_rendered_when_present() {
        use crate::transfer::Exemplar;
        let (child, base, plat) = ctx_fixture();
        let exemplars = vec![Exemplar {
            workload: "llama4_mlp".to_string(),
            speedup: 3.5,
            distance: 1.0,
            trace: vec![Transform::Parallel { stage: 0, loop_idx: 0 }],
            rendered: "  1. Parallel(stage=moe, loop=t)".to_string(),
        }];
        let ctx = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![0.773, 0.313],
            platform: &plat,
            exemplars: &exemplars,
        };
        let text = render(&ctx);
        assert!(text.contains("few-shot exemplars"));
        assert!(text.contains("Exemplar 1: workload llama4_mlp reached 3.50x"));
        assert!(text.contains("Parallel(stage=moe, loop=t)"));
        // The exemplar block sits before the transformation list so the
        // model reads feedback before choosing actions.
        let ex_pos = text.find("few-shot exemplars").unwrap();
        let avail_pos = text.find("Available transformations").unwrap();
        assert!(ex_pos < avail_pos);
        // Without exemplars the section is absent.
        let bare = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![0.773, 0.313],
            platform: &plat,
            exemplars: &[],
        };
        assert!(!render(&bare).contains("few-shot exemplars"));
    }

    #[test]
    fn token_estimate_scales() {
        assert_eq!(token_estimate("abcd"), 1);
        assert_eq!(token_estimate("abcde"), 2);
        let (child, base, plat) = ctx_fixture();
        let ctx = PromptContext {
            node: &child,
            ancestors: vec![&base],
            scores: vec![1.0, 0.9],
            platform: &plat,
            exemplars: &[],
        };
        assert!(token_estimate(&render(&ctx)) > 300);
    }
}
