//! Schedule feature extraction.
//!
//! A compact numeric summary of a scheduled program, consumed by the
//! reasoning engine's program analysis (the "hardware cost model outputs"
//! that the paper serializes into prompts) and by diagnostics/reports.

use std::sync::Arc;

use crate::tir::program::{Program, Stage};

use super::access::{self, StageAnalysis};
use super::analysis::AnalysisCache;
use super::platform::Platform;

/// Features of one program variant on one platform. All ratios are in
/// [0, 1] unless noted.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Features {
    pub total_iters: f64,
    pub flops: f64,
    /// Explicit SIMD vectorization present on the innermost loop.
    pub vectorized: bool,
    /// Extent of the vectorized loop (0 if none).
    pub vector_extent: f64,
    /// Fraction of loads that are contiguous w.r.t. the innermost loop.
    pub contiguous_frac: f64,
    /// Any strided (gather) load under vectorization.
    pub has_gather: bool,
    /// Product of parallel-prefix extents.
    pub parallel_extent: f64,
    /// parallel_extent / cores, capped at 8 (oversubscription measure).
    pub parallel_utilization: f64,
    /// Independent accumulation chains in the innermost region.
    pub chains: f64,
    /// Product of unrolled loop extents.
    pub unrolled_product: f64,
    /// Loop bookkeeping iterations / total iterations.
    pub overhead_frac: f64,
    /// DRAM traffic / cold-miss (compulsory) traffic: 1.0 = perfect reuse.
    pub dram_amplification: f64,
    /// L2 traffic / cold traffic.
    pub l2_amplification: f64,
    /// Output writebacks / output elements.
    pub writeback_amplification: f64,
    /// Arithmetic intensity: flops / DRAM bytes.
    pub arithmetic_intensity: f64,
    /// Number of loops in the (first) stage nest.
    pub loop_count: f64,
    pub cache_write: bool,
    pub has_compute_location: bool,
}

/// Extract features for a program on a platform (aggregated over stages,
/// weighted by per-stage flops).
pub fn extract(program: &Program, platform: &Platform) -> Features {
    extract_impl(program, platform, |p, s| Arc::new(access::analyze(p, s)))
}

/// [`extract`] with per-stage analyses served from the shared
/// [`AnalysisCache`] — bit-identical results (the analysis is pure).
pub fn extract_cached(
    program: &Program,
    platform: &Platform,
    analysis: &AnalysisCache,
) -> Features {
    extract_impl(program, platform, |p, s| analysis.analyze(p, s))
}

fn extract_impl(
    program: &Program,
    platform: &Platform,
    analyze: impl Fn(&Program, &Stage) -> Arc<StageAnalysis>,
) -> Features {
    let mut f = Features::default();
    let mut total_flops = 0.0;
    for stage in &program.stages {
        let a = analyze(program, stage);
        let w = a.flops as f64;
        total_flops += w;

        let cold = a.footprint_bytes[0] as f64;
        let dram = access::traffic_bytes(&a, platform.l3_bytes as i64, 1.0);
        let l2 = access::traffic_bytes(&a, platform.l1d_bytes as i64, 1.0);
        let (contig, broadcast, strided) = access::innermost_contiguity(&a);
        let n_acc = (contig + broadcast + strided).max(1);

        f.total_iters += a.total_iters as f64;
        f.flops += w;
        if a.vector_extent.is_some() {
            f.vectorized = true;
            f.vector_extent = f.vector_extent.max(a.vector_extent.unwrap() as f64);
            if a
                .accesses
                .iter()
                .any(|acc| !acc.is_store && acc.innermost_stride > 1)
            {
                f.has_gather = true;
            }
        }
        f.contiguous_frac += w * (contig + broadcast) as f64 / n_acc as f64;
        f.parallel_extent += w * a.parallel_extent as f64;
        f.chains += w * a.chains as f64;
        f.unrolled_product += w * a.unrolled_product as f64;
        f.overhead_frac += w * (a.overhead_iters / a.total_iters.max(1) as f64).min(4.0);
        f.dram_amplification += w * (dram / cold.max(1.0));
        f.l2_amplification += w * (l2 / cold.max(1.0));
        let out_elems = a
            .accesses
            .iter()
            .find(|acc| acc.is_store)
            .map(|acc| acc.elems_at_depth[0] as f64)
            .unwrap_or(1.0);
        f.writeback_amplification += w * (a.writebacks as f64 / out_elems.max(1.0));
        f.arithmetic_intensity += w * (w / dram.max(1.0));
    }
    let tw = total_flops.max(1.0);
    f.contiguous_frac /= tw;
    f.parallel_extent /= tw;
    f.chains /= tw;
    f.unrolled_product /= tw;
    f.overhead_frac /= tw;
    f.dram_amplification /= tw;
    f.l2_amplification /= tw;
    f.writeback_amplification /= tw;
    f.arithmetic_intensity /= tw;
    f.parallel_utilization = (f.parallel_extent / platform.cores as f64).min(8.0);
    f.loop_count = program
        .stages
        .iter()
        .map(|s| s.loops.len())
        .max()
        .unwrap_or(0) as f64;
    f.cache_write = program.stages.iter().any(|s| s.cache_write);
    f.has_compute_location = program.stages.iter().any(|s| s.compute_at.is_some());
    f
}

impl Features {
    /// Render the features as the key/value block prompts embed
    /// ("hardware cost model outputs").
    pub fn render(&self) -> String {
        format!(
            "vectorized: {} (extent {})\n\
             contiguous load fraction: {:.2}\n\
             gather under vectorization: {}\n\
             parallel extent: {:.0} (utilization {:.2} of cores)\n\
             accumulation chains: {:.1}\n\
             unrolled product: {:.0}\n\
             loop overhead fraction: {:.3}\n\
             DRAM traffic amplification: {:.2}x cold\n\
             L2 traffic amplification: {:.2}x cold\n\
             writeback amplification: {:.2}x outputs\n\
             arithmetic intensity: {:.2} flop/byte\n\
             cache_write: {}, compute_location set: {}",
            self.vectorized,
            self.vector_extent,
            self.contiguous_frac,
            self.has_gather,
            self.parallel_extent,
            self.parallel_utilization,
            self.chains,
            self.unrolled_product,
            self.overhead_frac,
            self.dram_amplification,
            self.l2_amplification,
            self.writeback_amplification,
            self.arithmetic_intensity,
            self.cache_write,
            self.has_compute_location,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn naive_features_sane() {
        let p = WorkloadId::DeepSeekMoe.build();
        let f = extract(&p, &Platform::core_i9());
        assert!(!f.vectorized);
        assert_eq!(f.parallel_extent, 1.0);
        assert!(f.dram_amplification >= 1.0);
        assert!(f.arithmetic_intensity > 0.0);
        assert_eq!(f.loop_count, 3.0);
    }

    #[test]
    fn features_track_transforms() {
        let p = workload::moe_matmul("m", 16, 512, 512);
        let plat = Platform::core_i9();
        let base = extract(&p, &plat);

        let q = Transform::Parallel { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        let fq = extract(&q, &plat);
        assert_eq!(fq.parallel_extent, 16.0);
        assert!(fq.parallel_utilization > base.parallel_utilization);

        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 16 }.apply(&p).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2] }.apply(&q).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 3 }.apply(&q).unwrap();
        let fv = extract(&q, &plat);
        assert!(fv.vectorized);
        assert_eq!(fv.vector_extent, 16.0);
        assert!(fv.chains > base.chains);
    }

    #[test]
    fn tiling_lowers_dram_amplification() {
        let p = workload::moe_matmul("m", 64, 2048, 2048);
        let plat = Platform::xeon_e3(); // small caches: amplification visible
        let base = extract(&p, &plat);
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 }.apply(&p).unwrap();
        let q = Transform::TileSize { stage: 0, loop_idx: 3, factor: 64 }.apply(&q).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2, 4] }.apply(&q).unwrap();
        let tiled = extract(&q, &plat);
        assert!(
            tiled.dram_amplification <= base.dram_amplification,
            "tiled {} vs base {}",
            tiled.dram_amplification,
            base.dram_amplification
        );
    }

    #[test]
    fn cached_extraction_matches_uncached() {
        let cache = AnalysisCache::new();
        for w in WorkloadId::ALL {
            let p = w.build();
            let plat = Platform::core_i9();
            let plain = extract(&p, &plat);
            assert_eq!(plain, extract_cached(&p, &plat, &cache), "{}", w.name());
            // Second pass hits the cache and still agrees.
            assert_eq!(plain, extract_cached(&p, &plat, &cache), "{}", w.name());
        }
    }

    #[test]
    fn render_mentions_key_fields() {
        let p = WorkloadId::FluxConv.build_test();
        let text = extract(&p, &Platform::graviton2()).render();
        assert!(text.contains("vectorized"));
        assert!(text.contains("DRAM traffic amplification"));
        assert!(text.contains("parallel extent"));
    }
}
