//! Shared memoization of per-stage access analyses.
//!
//! `access::analyze` is the single most-repeated computation of the search
//! inner loop: the hardware simulator, the rollout surrogate, the feature
//! extractor and the reasoning engine all analyze the same `(program,
//! stage)` pairs — and the paper's 20-repeat measurement protocol
//! re-simulates every candidate under 20 seeds, multiplying each redundant
//! analysis by 20. [`AnalysisCache`] is the shared store all of those
//! callers route through, so a distinct stage structure is analyzed exactly
//! once per session.
//!
//! **Soundness.** The cache key combines the program's buffer-table hash
//! (kinds + shapes) with the stage's memoized structural hash
//! ([`crate::tir::Stage::struct_hash`]). `access::analyze` is a pure
//! function of exactly those inputs — buffer shapes/strides plus the
//! stage's axes, loops, axis expressions, block and annotations — with no
//! seed, platform or name dependence. Equal key ⇒ structurally identical
//! inputs ⇒ identical `StageAnalysis`, so cached and uncached evaluation
//! are **bit-identical**. The invalidation invariant is upstream: every
//! stage mutation goes through `Stage::cow_mut`, which clears the memoized
//! hash, so a mutated stage hashes to a new key and is re-analyzed.
//!
//! The store is sharded behind mutexes like `db::MeasureCache` so the
//! parallel evaluation pipeline and concurrent `rcc serve` tuners can share
//! one handle. Unlike `MeasureCache` — whose `clone()` deep-copies to keep
//! per-run *accounting* independent — `clone()` here shares storage:
//! analyses are pure values, so sharing them across runs, threads or
//! sessions cannot change any result.
//!
//! **Eviction is LRU, not clear-on-overflow** (PR-3 follow-up): each entry
//! carries a last-use stamp from a shared monotone tick; when a shard is
//! full, the coldest ~1/8 of its entries (by stamp, at least one) are
//! evicted in one batch before the insert — amortized O(1)-ish per miss
//! even at saturation, and recently-touched entries are never victims.
//! A long-lived serve session therefore keeps its hot working set instead
//! of periodically dropping everything it knows. Hit/miss accounting
//! ([`AnalysisCache::hits`]/[`AnalysisCache::misses`]) is kept on the
//! shared handle and — like the stored values — survives eviction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::tir::hash::{feed_buffers, StructHasher};
use crate::tir::program::{Program, Stage};

use super::access::{self, StageAnalysis};

/// Number of lock shards (mirrors `MeasureCache`).
const SHARDS: usize = 8;

/// Default per-shard entry bound. Analyses are ~1 KiB each, so the default
/// caps the cache around 16 K entries per shard for long-lived serve
/// sessions; eviction is correctness-free (entries are recomputable pure
/// values).
const MAX_SHARD_ENTRIES: usize = 1 << 14;

/// Entry value + last-use stamp (from the shared tick).
type Shard = HashMap<u64, (Arc<StageAnalysis>, u64)>;

#[derive(Debug)]
struct Inner {
    shards: [Mutex<Shard>; SHARDS],
    /// Monotone logical clock stamping every lookup (shared by all handles).
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Per-shard capacity; exceeding it evicts the LRU entry.
    cap_per_shard: usize,
}

/// Sharded (buffer-table hash, stage hash) → `Arc<StageAnalysis>` store
/// with per-shard LRU eviction.
#[derive(Debug)]
pub struct AnalysisCache {
    inner: Arc<Inner>,
}

impl Default for AnalysisCache {
    fn default() -> Self {
        AnalysisCache::with_capacity(MAX_SHARD_ENTRIES)
    }
}

impl Clone for AnalysisCache {
    /// Shares the underlying storage (see module docs for why this is safe
    /// here and deliberately different from `MeasureCache::clone`).
    fn clone(&self) -> Self {
        self.share()
    }
}

impl AnalysisCache {
    pub fn new() -> AnalysisCache {
        AnalysisCache::default()
    }

    /// A cache bounded to `cap_per_shard` entries per shard (so
    /// `cap_per_shard * 8` total). Exposed so tests — and memory-tight
    /// embedders — can exercise eviction without 16 K inserts per shard.
    pub fn with_capacity(cap_per_shard: usize) -> AnalysisCache {
        AnalysisCache {
            inner: Arc::new(Inner {
                shards: std::array::from_fn(|_| Mutex::new(Shard::new())),
                tick: AtomicU64::new(0),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                cap_per_shard: cap_per_shard.max(1),
            }),
        }
    }

    /// A second handle over the same storage (and the same accounting).
    pub fn share(&self) -> AnalysisCache {
        AnalysisCache { inner: Arc::clone(&self.inner) }
    }

    /// Cached entries across all shards.
    pub fn len(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from the store since creation (survives eviction —
    /// the counters live on the shared handle, not in the shards).
    pub fn hits(&self) -> u64 {
        self.inner.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to run `access::analyze`.
    pub fn misses(&self) -> u64 {
        self.inner.misses.load(Ordering::Relaxed)
    }

    /// The memoization key for one `(program, stage)` pair. The expensive
    /// per-stage part is memoized in the stage; the buffer feed is a few
    /// dozen integer ops.
    fn key(program: &Program, stage: &Stage) -> u64 {
        let mut h = StructHasher::new();
        h.tag(0xACCE55);
        feed_buffers(&mut h, &program.buffers);
        h.feed(stage.struct_hash());
        h.finish()
    }

    /// Analyze a stage through the cache: returns the memoized analysis
    /// when this stage structure (under these buffer shapes) has been seen,
    /// computing and storing it otherwise (batch-evicting the shard's
    /// least-recently-used entries when full). Bit-identical to calling
    /// [`access::analyze`] directly.
    pub fn analyze(&self, program: &Program, stage: &Stage) -> Arc<StageAnalysis> {
        let key = Self::key(program, stage);
        let shard = &self.inner.shards[(key % SHARDS as u64) as usize];
        let stamp = self.inner.tick.fetch_add(1, Ordering::Relaxed);
        if let Some(entry) = shard.lock().unwrap().get_mut(&key) {
            entry.1 = stamp;
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&entry.0);
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        // Compute outside the lock; a racing thread may duplicate the work
        // once, but both arrive at the identical pure value.
        let a = Arc::new(access::analyze(program, stage));
        let mut guard = shard.lock().unwrap();
        if guard.len() >= self.inner.cap_per_shard && !guard.contains_key(&key) {
            // Evict the coldest ~1/8 of the shard in one pass (at least
            // one entry). Batching keeps the scan off the per-miss hot
            // path at saturation — one O(n log n) sort buys cap/8
            // eviction-free inserts — while a constantly re-touched entry
            // (max stamp) still never ranks among the oldest.
            let mut by_age: Vec<(u64, u64)> =
                guard.iter().map(|(k, v)| (v.1, *k)).collect();
            by_age.sort_unstable();
            let evict = (self.inner.cap_per_shard / 8).max(1);
            for &(_, k) in by_age.iter().take(evict) {
                guard.remove(&k);
            }
        }
        guard.insert(key, (Arc::clone(&a), stamp));
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn hit_returns_shared_identical_analysis() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::DeepSeekMoe.build();
        let a = cache.analyze(&p, &p.stages[0]);
        let b = cache.analyze(&p, &p.stages[0]);
        assert!(Arc::ptr_eq(&a, &b), "second call must be a cache hit");
        assert_eq!(cache.len(), 1);
        // And the cached value equals a fresh uncached analysis, bit for bit.
        let fresh = access::analyze(&p, &p.stages[0]);
        assert_eq!(a.trips, fresh.trips);
        assert_eq!(a.footprint_bytes, fresh.footprint_bytes);
        assert_eq!(a.overhead_iters.to_bits(), fresh.overhead_iters.to_bits());
        assert_eq!(a.writebacks, fresh.writebacks);
    }

    #[test]
    fn mutation_misses_then_caches_new_structure() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::DeepSeekMoe.build();
        cache.analyze(&p, &p.stages[0]);
        let q = Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 }
            .apply(&p)
            .unwrap();
        let a = cache.analyze(&q, &q.stages[0]);
        assert_eq!(cache.len(), 2, "tiled stage is a distinct entry");
        let fresh = access::analyze(&q, &q.stages[0]);
        assert_eq!(a.trips, fresh.trips);
    }

    #[test]
    fn key_includes_buffer_shapes() {
        // Two structurally identical stages over different buffer shapes
        // must not share an entry (the analysis depends on shapes).
        let cache = AnalysisCache::new();
        let small = workload::moe_matmul("m", 4, 6, 8);
        let large = workload::moe_matmul("m", 8, 12, 16);
        cache.analyze(&small, &small.stages[0]);
        cache.analyze(&large, &large.stages[0]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn name_invariant_across_programs() {
        // Same structure under different names shares one entry — analyses
        // transfer exactly like fingerprints do.
        let cache = AnalysisCache::new();
        let a = workload::moe_matmul("alpha", 16, 64, 64);
        let b = workload::moe_matmul("beta", 16, 64, 64);
        let ra = cache.analyze(&a, &a.stages[0]);
        let rb = cache.analyze(&b, &b.stages[0]);
        assert!(Arc::ptr_eq(&ra, &rb));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn share_sees_other_handles_inserts() {
        let cache = AnalysisCache::new();
        let handle = cache.share();
        let p = WorkloadId::Llama4Mlp.build_test();
        cache.analyze(&p, &p.stages[0]);
        assert_eq!(handle.len(), 1);
        // clone() is a share, not a deep copy.
        let cloned = cache.clone();
        assert_eq!(cloned.len(), 1);
    }

    #[test]
    fn lru_eviction_bounds_size_and_keeps_hot_entries() {
        // Capacity 4 per shard; 64 distinct structures overflow every shard
        // several times over, but a constantly re-touched entry must never
        // be the LRU victim.
        let cache = AnalysisCache::with_capacity(4);
        let hot = workload::moe_matmul("hot", 4, 6, 8);
        let first = cache.analyze(&hot, &hot.stages[0]);
        for i in 0..64i64 {
            let p = workload::moe_matmul("cold", 4, 6, 16 + 2 * i);
            cache.analyze(&p, &p.stages[0]);
            // Touch the hot entry after every insert: its stamp stays the
            // newest in its shard, so eviction always picks something else.
            let again = cache.analyze(&hot, &hot.stages[0]);
            assert!(
                Arc::ptr_eq(&first, &again),
                "recently-used entry evicted at insert {i}"
            );
        }
        assert!(
            cache.len() <= 4 * 8,
            "LRU must bound the cache at capacity x shards, got {}",
            cache.len()
        );
    }

    #[test]
    fn hit_accounting_survives_eviction() {
        let cache = AnalysisCache::with_capacity(1);
        let p = workload::moe_matmul("p", 4, 6, 8);
        cache.analyze(&p, &p.stages[0]);
        cache.analyze(&p, &p.stages[0]);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // Overflow every shard with distinct structures (capacity 1 per
        // shard ⇒ each insert into an occupied shard evicts).
        let mut calls = 2;
        for i in 0..32i64 {
            let q = workload::moe_matmul("q", 4, 6, 16 + 2 * i);
            cache.analyze(&q, &q.stages[0]);
            calls += 1;
        }
        assert!(cache.len() <= 8, "capacity 1 x 8 shards");
        // The counters live on the handle, not in the evicted shards: every
        // call so far is accounted for, and they keep counting afterwards.
        assert_eq!(cache.hits() + cache.misses(), calls);
        let shared = cache.share();
        cache.analyze(&p, &p.stages[0]); // may hit or miss depending on eviction
        calls += 1;
        assert_eq!(
            shared.hits() + shared.misses(),
            calls,
            "accounting is shared across handles and survives eviction"
        );
        // A recomputed-after-eviction analysis still equals a fresh one.
        let a = cache.analyze(&p, &p.stages[0]);
        let fresh = access::analyze(&p, &p.stages[0]);
        assert_eq!(a.trips, fresh.trips);
        assert_eq!(a.footprint_bytes, fresh.footprint_bytes);
    }

    #[test]
    fn concurrent_analyze_is_safe() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::Llama3Attention.build_test();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let handle = cache.share();
                let p = &p;
                scope.spawn(move || {
                    for stage in &p.stages {
                        let a = handle.analyze(p, stage);
                        assert!(a.total_iters > 0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), p.stages.len());
    }
}
