//! Shared memoization of per-stage access analyses.
//!
//! `access::analyze` is the single most-repeated computation of the search
//! inner loop: the hardware simulator, the rollout surrogate, the feature
//! extractor and the reasoning engine all analyze the same `(program,
//! stage)` pairs — and the paper's 20-repeat measurement protocol
//! re-simulates every candidate under 20 seeds, multiplying each redundant
//! analysis by 20. [`AnalysisCache`] is the shared store all of those
//! callers route through, so a distinct stage structure is analyzed exactly
//! once per session.
//!
//! **Soundness.** The cache key combines the program's buffer-table hash
//! (kinds + shapes) with the stage's memoized structural hash
//! ([`crate::tir::Stage::struct_hash`]). `access::analyze` is a pure
//! function of exactly those inputs — buffer shapes/strides plus the
//! stage's axes, loops, axis expressions, block and annotations — with no
//! seed, platform or name dependence. Equal key ⇒ structurally identical
//! inputs ⇒ identical `StageAnalysis`, so cached and uncached evaluation
//! are **bit-identical**. The invalidation invariant is upstream: every
//! stage mutation goes through `Stage::cow_mut`, which clears the memoized
//! hash, so a mutated stage hashes to a new key and is re-analyzed.
//!
//! The store is sharded behind mutexes like `db::MeasureCache` so the
//! parallel evaluation pipeline and concurrent `rcc serve` tuners can share
//! one handle. Unlike `MeasureCache` — whose `clone()` deep-copies to keep
//! per-run *accounting* independent — `clone()` here shares storage:
//! analyses are pure values, so sharing them across runs, threads or
//! sessions cannot change any result.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::tir::hash::{feed_buffers, StructHasher};
use crate::tir::program::{Program, Stage};

use super::access::{self, StageAnalysis};

/// Number of lock shards (mirrors `MeasureCache`).
const SHARDS: usize = 8;

/// Per-shard entry bound. Analyses are ~1 KiB each; clearing a shard on
/// overflow bounds memory for long-lived serve sessions and is
/// correctness-free (entries are recomputable pure values).
const MAX_SHARD_ENTRIES: usize = 1 << 14;

type Shard = HashMap<u64, Arc<StageAnalysis>>;

/// Sharded (buffer-table hash, stage hash) → `Arc<StageAnalysis>` store.
#[derive(Debug)]
pub struct AnalysisCache {
    shards: Arc<[Mutex<Shard>; SHARDS]>,
}

impl Default for AnalysisCache {
    fn default() -> Self {
        AnalysisCache {
            shards: Arc::new(std::array::from_fn(|_| Mutex::new(Shard::new()))),
        }
    }
}

impl Clone for AnalysisCache {
    /// Shares the underlying storage (see module docs for why this is safe
    /// here and deliberately different from `MeasureCache::clone`).
    fn clone(&self) -> Self {
        self.share()
    }
}

impl AnalysisCache {
    pub fn new() -> AnalysisCache {
        AnalysisCache::default()
    }

    /// A second handle over the same storage.
    pub fn share(&self) -> AnalysisCache {
        AnalysisCache { shards: Arc::clone(&self.shards) }
    }

    /// Cached entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The memoization key for one `(program, stage)` pair. The expensive
    /// per-stage part is memoized in the stage; the buffer feed is a few
    /// dozen integer ops.
    fn key(program: &Program, stage: &Stage) -> u64 {
        let mut h = StructHasher::new();
        h.tag(0xACCE55);
        feed_buffers(&mut h, &program.buffers);
        h.feed(stage.struct_hash());
        h.finish()
    }

    /// Analyze a stage through the cache: returns the memoized analysis
    /// when this stage structure (under these buffer shapes) has been seen,
    /// computing and storing it otherwise. Bit-identical to calling
    /// [`access::analyze`] directly.
    pub fn analyze(&self, program: &Program, stage: &Stage) -> Arc<StageAnalysis> {
        let key = Self::key(program, stage);
        let shard = &self.shards[(key % SHARDS as u64) as usize];
        if let Some(a) = shard.lock().unwrap().get(&key) {
            return Arc::clone(a);
        }
        // Compute outside the lock; a racing thread may duplicate the work
        // once, but both arrive at the identical pure value.
        let a = Arc::new(access::analyze(program, stage));
        let mut guard = shard.lock().unwrap();
        if guard.len() >= MAX_SHARD_ENTRIES {
            guard.clear();
        }
        guard.insert(key, Arc::clone(&a));
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn hit_returns_shared_identical_analysis() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::DeepSeekMoe.build();
        let a = cache.analyze(&p, &p.stages[0]);
        let b = cache.analyze(&p, &p.stages[0]);
        assert!(Arc::ptr_eq(&a, &b), "second call must be a cache hit");
        assert_eq!(cache.len(), 1);
        // And the cached value equals a fresh uncached analysis, bit for bit.
        let fresh = access::analyze(&p, &p.stages[0]);
        assert_eq!(a.trips, fresh.trips);
        assert_eq!(a.footprint_bytes, fresh.footprint_bytes);
        assert_eq!(a.overhead_iters.to_bits(), fresh.overhead_iters.to_bits());
        assert_eq!(a.writebacks, fresh.writebacks);
    }

    #[test]
    fn mutation_misses_then_caches_new_structure() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::DeepSeekMoe.build();
        cache.analyze(&p, &p.stages[0]);
        let q = Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 }
            .apply(&p)
            .unwrap();
        let a = cache.analyze(&q, &q.stages[0]);
        assert_eq!(cache.len(), 2, "tiled stage is a distinct entry");
        let fresh = access::analyze(&q, &q.stages[0]);
        assert_eq!(a.trips, fresh.trips);
    }

    #[test]
    fn key_includes_buffer_shapes() {
        // Two structurally identical stages over different buffer shapes
        // must not share an entry (the analysis depends on shapes).
        let cache = AnalysisCache::new();
        let small = workload::moe_matmul("m", 4, 6, 8);
        let large = workload::moe_matmul("m", 8, 12, 16);
        cache.analyze(&small, &small.stages[0]);
        cache.analyze(&large, &large.stages[0]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn name_invariant_across_programs() {
        // Same structure under different names shares one entry — analyses
        // transfer exactly like fingerprints do.
        let cache = AnalysisCache::new();
        let a = workload::moe_matmul("alpha", 16, 64, 64);
        let b = workload::moe_matmul("beta", 16, 64, 64);
        let ra = cache.analyze(&a, &a.stages[0]);
        let rb = cache.analyze(&b, &b.stages[0]);
        assert!(Arc::ptr_eq(&ra, &rb));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn share_sees_other_handles_inserts() {
        let cache = AnalysisCache::new();
        let handle = cache.share();
        let p = WorkloadId::Llama4Mlp.build_test();
        cache.analyze(&p, &p.stages[0]);
        assert_eq!(handle.len(), 1);
        // clone() is a share, not a deep copy.
        let cloned = cache.clone();
        assert_eq!(cloned.len(), 1);
    }

    #[test]
    fn concurrent_analyze_is_safe() {
        let cache = AnalysisCache::new();
        let p = WorkloadId::Llama3Attention.build_test();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let handle = cache.share();
                let p = &p;
                scope.spawn(move || {
                    for stage in &p.stages {
                        let a = handle.analyze(p, stage);
                        assert!(a.total_iters > 0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), p.stages.len());
    }
}
