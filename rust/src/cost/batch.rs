//! Parallel batched cost-model evaluation.
//!
//! The search engines spend almost all of their wall-clock inside
//! `CostModel::latency` calls. Those calls are pure functions of
//! `(program, seed)`, so a batch of candidates can fan out across a worker
//! pool with no change in results: each job's seed is fixed by the caller
//! before the fan-out, and results come back in input order regardless of
//! thread scheduling. This is the evaluation-layer half of the parallel
//! pipeline; budget metering and cache consultation stay in
//! `search::common::Evaluator`, which plans a batch serially, calls
//! [`latency_batch`], then folds results back in deterministic order.

use crate::tir::Program;

use super::analytical::CostModel;

/// One batched evaluation job: a program variant and the measurement seed
/// it must be evaluated under (assigned by the caller, typically
/// `base_seed + sample_number` so parallel and serial execution agree).
pub struct LatencyJob<'a> {
    pub program: &'a Program,
    pub seed: u64,
}

/// Evaluate `jobs` on `model` across up to `workers` OS threads, returning
/// latencies in input order. `workers <= 1` (or a single job) runs inline
/// with no threads spawned — the exact serial path. Results are
/// bit-identical for every worker count because each job's seed is fixed
/// up front and `CostModel::latency` is deterministic per `(program, seed)`.
pub fn latency_batch(model: &dyn CostModel, jobs: &[LatencyJob<'_>], workers: usize) -> Vec<f64> {
    if workers <= 1 || jobs.len() <= 1 {
        return jobs.iter().map(|j| model.latency(j.program, j.seed)).collect();
    }
    let mut out = vec![0.0f64; jobs.len()];
    let mut work: Vec<(&LatencyJob, &mut f64)> = jobs.iter().zip(out.iter_mut()).collect();
    crate::util::pool::scoped_chunks(&mut work, workers, |batch| {
        for (job, slot) in batch.iter_mut() {
            **slot = model.latency(job.program, job.seed);
        }
    });
    drop(work);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform};
    use crate::schedule::{sampler, Schedule};
    use crate::tir::workload::WorkloadId;
    use crate::util::rng::Pcg;

    fn candidates(n: usize) -> Vec<Program> {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build_test());
        let mut rng = Pcg::new(11);
        (0..n)
            .map(|_| {
                let seq = sampler::random_sequence(&base.current, 3, &mut rng);
                base.apply_all(&seq).0.current
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let hw = HardwareModel::new(Platform::core_i9());
        let progs = candidates(23);
        let jobs: Vec<LatencyJob> = progs
            .iter()
            .enumerate()
            .map(|(i, p)| LatencyJob { program: p, seed: 1000 + i as u64 })
            .collect();
        let serial = latency_batch(&hw, &jobs, 1);
        for workers in [2, 4, 7] {
            assert_eq!(latency_batch(&hw, &jobs, workers), serial, "workers={workers}");
        }
    }

    #[test]
    fn handles_empty_and_oversized_pools() {
        let hw = HardwareModel::new(Platform::core_i9());
        assert!(latency_batch(&hw, &[], 4).is_empty());
        let progs = candidates(2);
        let jobs: Vec<LatencyJob> =
            progs.iter().map(|p| LatencyJob { program: p, seed: 5 }).collect();
        assert_eq!(latency_batch(&hw, &jobs, 64).len(), 2);
    }
}
