//! Parallel batched cost-model evaluation.
//!
//! The search engines spend almost all of their wall-clock inside
//! `CostModel::latency` calls. Those calls are pure functions of
//! `(program, seed)`, so a batch of candidates can fan out across the
//! crate's persistent executor with no change in results: each job's seed
//! is fixed by the caller before the fan-out, and results come back in
//! input order regardless of thread scheduling.
//!
//! [`latency_batch`] is the standalone form of that idea — a deterministic
//! parallel map over a cost model — used by embedders and the perf benches
//! (`benches/micro_hotpaths.rs` races it against spawn-per-batch scoped
//! threads). The production search pipeline does **not** route through it:
//! `search::common::BatchEvaluator` plans candidates serially (budget
//! metering, cache consultation, seed assignment) and streams its
//! hardware closures onto the executor directly, folding in plan order.

use crate::obs;
use crate::search::common::FAILED_MEASUREMENT;
use crate::tir::Program;
use crate::util::executor::Executor;
use crate::util::faults;

use super::analytical::CostModel;

/// One batched evaluation job: a program variant and the measurement seed
/// it must be evaluated under (assigned by the caller, typically
/// `base_seed + sample_number` so parallel and serial execution agree).
pub struct LatencyJob<'a> {
    pub program: &'a Program,
    pub seed: u64,
}

/// Evaluate `jobs` on `model` across the persistent executor, returning
/// latencies in input order. A serial executor (or a single job) runs
/// inline with no queueing — the exact serial path. Results are
/// bit-identical for every executor width because each job's seed is fixed
/// up front and `CostModel::latency` is deterministic per `(program, seed)`.
pub fn latency_batch(model: &dyn CostModel, jobs: &[LatencyJob<'_>], exec: &Executor) -> Vec<f64> {
    // Injected measurement faults (`util::faults`) are rolled serially here,
    // at plan time and keyed by each job's seed, so a fault schedule is
    // fixed before the fan-out and identical for every executor width — the
    // same contract the searchers' BatchEvaluator follows. A faulted job
    // returns [`FAILED_MEASUREMENT`] without touching the model. Stock runs
    // take the `!armed()` branch: one relaxed load, no per-job work.
    let faulted: Vec<bool> = if faults::armed() {
        jobs.iter()
            .enumerate()
            .map(|(i, j)| {
                let hit = faults::measure_fault(j.seed);
                if hit {
                    obs::instant(obs::EventKind::MeasureFail, i as u64);
                }
                hit
            })
            .collect()
    } else {
        Vec::new()
    };
    let fault_at = |i: usize| faulted.get(i).copied().unwrap_or(false);
    let out: Vec<f64> = if exec.is_serial() || jobs.len() <= 1 {
        jobs.iter()
            .enumerate()
            .map(|(i, j)| {
                if fault_at(i) {
                    return FAILED_MEASUREMENT;
                }
                let _sp = obs::span(obs::EventKind::Measure, i as u64);
                model.latency(j.program, j.seed)
            })
            .collect()
    } else {
        exec.run(
            jobs.iter()
                .enumerate()
                .map(|(i, j)| {
                    let failed = fault_at(i);
                    move || {
                        if failed {
                            return FAILED_MEASUREMENT;
                        }
                        let _sp = obs::span(obs::EventKind::Measure, i as u64);
                        model.latency(j.program, j.seed)
                    }
                })
                .collect(),
        )
    };
    // Audit: one record per measurement, emitted in input order after the
    // fan-out returns — worker threads never write the decision log.
    if obs::audit::armed() {
        use crate::util::json::{num, Json};
        for (i, (j, lat)) in jobs.iter().zip(out.iter()).enumerate() {
            let mut r = obs::audit::record("measure", j.seed);
            r.set("sample", num(i as f64));
            if lat.is_finite() {
                r.set("latency", num(*lat));
            } else {
                r.set("failed", Json::Bool(true));
            }
            obs::audit::emit(r);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{HardwareModel, Platform};
    use crate::schedule::{sampler, Schedule};
    use crate::tir::workload::WorkloadId;
    use crate::util::rng::Pcg;

    fn candidates(n: usize) -> Vec<Program> {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build_test());
        let mut rng = Pcg::new(11);
        (0..n)
            .map(|_| {
                let seq = sampler::random_sequence(&base.current, 3, &mut rng);
                base.apply_all(&seq).0.current
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let hw = HardwareModel::new(Platform::core_i9());
        let progs = candidates(23);
        let jobs: Vec<LatencyJob> = progs
            .iter()
            .enumerate()
            .map(|(i, p)| LatencyJob { program: p, seed: 1000 + i as u64 })
            .collect();
        let serial = latency_batch(&hw, &jobs, &Executor::serial());
        for workers in [2, 4, 7] {
            let exec = Executor::new(workers);
            assert_eq!(latency_batch(&hw, &jobs, &exec), serial, "workers={workers}");
        }
    }

    #[test]
    fn handles_empty_and_oversized_pools() {
        let hw = HardwareModel::new(Platform::core_i9());
        let exec = Executor::new(64);
        assert!(latency_batch(&hw, &[], &exec).is_empty());
        let progs = candidates(2);
        let jobs: Vec<LatencyJob> =
            progs.iter().map(|p| LatencyJob { program: p, seed: 5 }).collect();
        assert_eq!(latency_batch(&hw, &jobs, &exec).len(), 2);
    }
}
