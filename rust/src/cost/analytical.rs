//! The rollout surrogate f̂.
//!
//! The paper (following TVM/Ansor practice) never runs real hardware in the
//! MCTS inner loop: rollouts are scored by a learned, hardware-informed
//! cost model that is cheap and *imperfect*. This surrogate plays that
//! role: a coarse three-term roofline (compute, DRAM, loop overhead) over
//! the same access analysis, with multiplicative noise and systematic bias
//! (it ignores mid-level caches, register pressure and fork/join overhead),
//! so search sees a informative-but-noisy signal exactly as with a learned
//! XGBoost model.

use crate::tir::{Program, Stage};
use crate::util::rng::Pcg;

use super::access;
use super::analysis::AnalysisCache;
use super::platform::Platform;

/// Relative sigma of surrogate prediction error.
const SURROGATE_SIGMA: f64 = 0.12;

/// Predicted latency in seconds. Deterministic per (program, platform,
/// seed); the noise models learned-cost-model prediction error.
pub fn predict(program: &Program, platform: &Platform, seed: u64) -> f64 {
    predict_impl(program, seed, |p, s| stage_estimate(&access::analyze(p, s), platform))
}

/// [`predict`] with per-stage analyses served from the shared
/// [`AnalysisCache`] — bit-identical results (the analysis is pure).
pub fn predict_cached(
    program: &Program,
    platform: &Platform,
    seed: u64,
    analysis: &AnalysisCache,
) -> f64 {
    predict_impl(program, seed, |p, s| stage_estimate(&analysis.analyze(p, s), platform))
}

/// One summation loop shared by the cached and uncached paths, so the
/// bit-identity contract cannot drift between two hand-synchronized copies.
fn predict_impl(
    program: &Program,
    seed: u64,
    stage_cost: impl Fn(&Program, &Stage) -> f64,
) -> f64 {
    let mut total = 0.0;
    for stage in &program.stages {
        total += stage_cost(program, stage);
    }
    apply_noise(program, seed, total)
}

/// Multiplicative lognormal surrogate error, stable per (program, seed).
fn apply_noise(program: &Program, seed: u64, total: f64) -> f64 {
    let mut rng = Pcg::new(seed ^ struct_hash(program) ^ 0xA5A5_5A5A);
    let noise = (rng.gen_normal() * SURROGATE_SIGMA).exp();
    total * noise
}

fn stage_estimate(a: &access::StageAnalysis, p: &Platform) -> f64 {
    let freq_hz = p.freq_ghz * 1e9;
    // Compute: issue throughput only (ignores the latency/chain bound
    // beyond a crude penalty when no unroll/vector structure exists).
    let lanes = match a.vector_extent {
        Some(_) => p.simd_lanes as f64,
        None => (p.simd_lanes as f64 * 0.3).max(1.0),
    };
    let chain_penalty = if a.chains < 8 { 1.6 } else { 1.0 };
    let compute_cycles =
        a.flops as f64 / (lanes * p.fma_ports as f64 * 2.0) * chain_penalty;
    let overhead_cycles = a.overhead_iters;

    // Memory: DRAM term only (systematic bias: blind to L2/L3 behaviour).
    let dram_bytes = access::traffic_bytes(a, p.l3_bytes as i64, 1.6);
    let dram_s = dram_bytes / (p.dram_gbps * 1e9);

    let par = (a.parallel_extent.max(1) as f64).min(p.cores as f64);
    let compute_s = (compute_cycles + overhead_cycles) / freq_hz / par;

    compute_s.max(dram_s) + 0.15 * compute_s.min(dram_s)
}

fn struct_hash(program: &Program) -> u64 {
    let mut h: u64 = 0x51_7c_c1_b7_27_22_0a_95;
    for s in &program.stages {
        for l in &s.loops {
            h = h
                .rotate_left(7)
                .wrapping_mul(0x100000001b3)
                .wrapping_add(l.extent as u64 ^ ((l.kind as u64) << 32));
        }
    }
    h
}

/// Unified cost-model interface used by the search engines.
pub trait CostModel: Send + Sync {
    /// Estimated/measured latency in seconds for this program variant.
    fn latency(&self, program: &Program, seed: u64) -> f64;
    fn name(&self) -> &'static str;
}

/// The hardware simulator as a `CostModel` (the paper's `f`).
///
/// Owns a handle to an [`AnalysisCache`]; every `latency` call routes its
/// per-stage access analyses through it. Build with [`HardwareModel::new`]
/// (private cache) or [`HardwareModel::with_analysis`] to share one cache
/// across the models of a session (what the tuner does, so hardware,
/// surrogate and reasoning engine all reuse each other's analyses).
pub struct HardwareModel {
    pub platform: Platform,
    analysis: AnalysisCache,
}

impl HardwareModel {
    pub fn new(platform: Platform) -> HardwareModel {
        HardwareModel { platform, analysis: AnalysisCache::new() }
    }

    /// Share an existing analysis cache (session-wide memoization).
    pub fn with_analysis(platform: Platform, analysis: AnalysisCache) -> HardwareModel {
        HardwareModel { platform, analysis }
    }

    pub fn analysis(&self) -> &AnalysisCache {
        &self.analysis
    }
}

impl CostModel for HardwareModel {
    fn latency(&self, program: &Program, seed: u64) -> f64 {
        super::simulator::simulate_cached(program, &self.platform, seed, &self.analysis)
    }
    fn name(&self) -> &'static str {
        "hardware-sim"
    }
}

/// The analytical surrogate as a `CostModel` (the paper's f̂). Analysis
/// caching mirrors [`HardwareModel`].
pub struct SurrogateModel {
    pub platform: Platform,
    analysis: AnalysisCache,
}

impl SurrogateModel {
    pub fn new(platform: Platform) -> SurrogateModel {
        SurrogateModel { platform, analysis: AnalysisCache::new() }
    }

    /// Share an existing analysis cache (session-wide memoization).
    pub fn with_analysis(platform: Platform, analysis: AnalysisCache) -> SurrogateModel {
        SurrogateModel { platform, analysis }
    }

    pub fn analysis(&self) -> &AnalysisCache {
        &self.analysis
    }
}

impl CostModel for SurrogateModel {
    fn latency(&self, program: &Program, seed: u64) -> f64 {
        predict_cached(program, &self.platform, seed, &self.analysis)
    }
    fn name(&self) -> &'static str {
        "surrogate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::sampler;
    use crate::schedule::Schedule;
    use crate::tir::workload::WorkloadId;
    use crate::util::rng::Pcg;

    #[test]
    fn positive_and_deterministic() {
        let p = WorkloadId::DeepSeekMoe.build();
        let plat = Platform::core_i9();
        let a = predict(&p, &plat, 3);
        let b = predict(&p, &plat, 3);
        assert!(a > 0.0);
        assert_eq!(a, b);
        assert_ne!(a, predict(&p, &plat, 4));
    }

    #[test]
    fn rank_correlates_with_simulator() {
        // The surrogate must be directionally informative: over random
        // schedules, its ranking should positively correlate with f.
        let plat = Platform::core_i9();
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let mut rng = Pcg::new(42);
        let mut pairs = Vec::new();
        for _ in 0..30 {
            let seq = sampler::random_sequence(&base.current, 4, &mut rng);
            let (s, _) = base.apply_all(&seq);
            let f = super::super::simulator::simulate(&s.current, &plat, 0);
            let fhat = predict(&s.current, &plat, 1);
            pairs.push((f, fhat));
        }
        // Spearman-ish: count concordant pairs.
        let mut concordant = 0u32;
        let mut discordant = 0u32;
        for i in 0..pairs.len() {
            for j in (i + 1)..pairs.len() {
                let d = (pairs[i].0 - pairs[j].0) * (pairs[i].1 - pairs[j].1);
                if d > 0.0 {
                    concordant += 1;
                } else if d < 0.0 {
                    discordant += 1;
                }
            }
        }
        let tau = (concordant as f64 - discordant as f64)
            / (concordant + discordant).max(1) as f64;
        assert!(tau > 0.3, "surrogate uninformative: tau={tau}");
    }

    #[test]
    fn surrogate_diverges_from_simulator() {
        // It must NOT be the same function (otherwise rollouts are oracle).
        let p = WorkloadId::Llama4Mlp.build();
        let plat = Platform::xeon_e3();
        let f = super::super::simulator::simulate(&p, &plat, 0);
        let fhat = predict(&p, &plat, 1);
        assert!((f - fhat).abs() / f > 1e-3);
    }

    #[test]
    fn cost_model_trait_objects() {
        let p = WorkloadId::FluxConv.build_test();
        let hw: Box<dyn CostModel> = Box::new(HardwareModel::new(Platform::m2_pro()));
        let sg: Box<dyn CostModel> = Box::new(SurrogateModel::new(Platform::m2_pro()));
        assert!(hw.latency(&p, 0) > 0.0);
        assert!(sg.latency(&p, 1) > 0.0);
        assert_eq!(hw.name(), "hardware-sim");
    }

    #[test]
    fn cached_predict_bit_identical_and_models_match_free_functions() {
        let plat = Platform::core_i9();
        let cache = AnalysisCache::new();
        for w in WorkloadId::ALL {
            let p = w.build();
            let plain = predict(&p, &plat, 9);
            assert_eq!(
                plain.to_bits(),
                predict_cached(&p, &plat, 9, &cache).to_bits(),
                "{}",
                w.name()
            );
            // Models (which evaluate through their own caches) agree with
            // the free functions bit for bit.
            let hw = HardwareModel::new(plat.clone());
            assert_eq!(
                hw.latency(&p, 5).to_bits(),
                super::super::simulator::simulate(&p, &plat, 5).to_bits()
            );
            let sg = SurrogateModel::with_analysis(plat.clone(), cache.share());
            assert_eq!(sg.latency(&p, 9).to_bits(), plain.to_bits());
        }
    }
}
