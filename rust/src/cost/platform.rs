//! Hardware platform descriptors.
//!
//! The paper evaluates on five CPU platforms; the simulator is parameterized
//! by these descriptors so the same schedule lands at different points of
//! each platform's roofline, reproducing the cross-platform variance of
//! Table 1/2. Numbers are public-spec-sheet values (per-core caches are
//! per-core; L3 is the shared slice visible to one tuning process).

/// One CPU platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub display: &'static str,
    /// Physical cores available to the parallel runtime.
    pub cores: u32,
    /// f32 lanes per SIMD op (NEON=4, AVX2=8, AVX-512=16).
    pub simd_lanes: u32,
    /// Vector FMA pipes per core.
    pub fma_ports: u32,
    /// FMA result latency in cycles (length of the accumulation chain stall).
    pub fma_latency: f64,
    pub freq_ghz: f64,
    pub l1d_bytes: u64,
    pub l2_bytes: u64,
    /// Shared last-level cache.
    pub l3_bytes: u64,
    /// Per-core sustained bandwidths, GB/s.
    pub l2_gbps: f64,
    /// Shared L3 bandwidth, GB/s.
    pub l3_gbps: f64,
    /// Shared DRAM bandwidth, GB/s.
    pub dram_gbps: f64,
    /// Cost of entering/leaving a parallel region, microseconds.
    pub parallel_overhead_us: f64,
    /// Effective scalar ILP (independent scalar FMA chains the OoO core
    /// sustains without vectorization).
    pub scalar_ipc: f64,
}

impl Platform {
    /// The five evaluation platforms, in the paper's Table-1 order.
    pub fn all() -> Vec<Platform> {
        vec![
            Platform::graviton2(),
            Platform::epyc_7r13(),
            Platform::m2_pro(),
            Platform::core_i9(),
            Platform::xeon_e3(),
        ]
    }

    pub fn by_name(name: &str) -> Option<Platform> {
        Platform::all().into_iter().find(|p| p.name == name)
    }

    /// AWS Graviton2: 64x Neoverse-N1, NEON (4 f32 lanes), 2.5 GHz.
    pub fn graviton2() -> Platform {
        Platform {
            name: "graviton2",
            display: "Amazon Graviton2",
            cores: 64,
            simd_lanes: 4,
            fma_ports: 2,
            fma_latency: 4.0,
            freq_ghz: 2.5,
            l1d_bytes: 64 << 10,
            l2_bytes: 1 << 20,
            l3_bytes: 32 << 20,
            l2_gbps: 120.0,
            l3_gbps: 180.0,
            dram_gbps: 190.0,
            parallel_overhead_us: 12.0,
            scalar_ipc: 2.0,
        }
    }

    /// AMD EPYC 7R13 (Milan, AWS c6a): 48 cores, AVX2, 2.65 GHz.
    pub fn epyc_7r13() -> Platform {
        Platform {
            name: "epyc_7r13",
            display: "AMD EPYC 7R13",
            cores: 48,
            simd_lanes: 8,
            fma_ports: 2,
            fma_latency: 4.0,
            freq_ghz: 2.65,
            l1d_bytes: 32 << 10,
            l2_bytes: 512 << 10,
            l3_bytes: 32 << 20, // one CCD slice
            l2_gbps: 170.0,
            l3_gbps: 250.0,
            dram_gbps: 150.0,
            parallel_overhead_us: 10.0,
            scalar_ipc: 2.5,
        }
    }

    /// Apple M2 Pro: 8 performance cores modeled, NEON with 4 FMA pipes,
    /// 3.5 GHz, big shared L2, very high memory bandwidth.
    pub fn m2_pro() -> Platform {
        Platform {
            name: "m2_pro",
            display: "Apple M2 Pro",
            cores: 8,
            simd_lanes: 4,
            fma_ports: 4,
            fma_latency: 3.0,
            freq_ghz: 3.5,
            l1d_bytes: 128 << 10,
            l2_bytes: 4 << 20, // per-core share of the 32 MB cluster L2
            l3_bytes: 24 << 20,
            l2_gbps: 240.0,
            l3_gbps: 250.0,
            dram_gbps: 200.0,
            parallel_overhead_us: 6.0,
            scalar_ipc: 3.0,
        }
    }

    /// Intel Core i9 (Raptor Lake class): 8 P-cores modeled, AVX2, 5.0 GHz.
    /// This is the paper's ablation environment.
    pub fn core_i9() -> Platform {
        Platform {
            name: "core_i9",
            display: "Intel Core i9",
            cores: 16,
            simd_lanes: 8,
            fma_ports: 2,
            fma_latency: 4.0,
            freq_ghz: 5.0,
            l1d_bytes: 48 << 10,
            l2_bytes: 2 << 20,
            l3_bytes: 36 << 20,
            l2_gbps: 300.0,
            l3_gbps: 300.0,
            dram_gbps: 90.0,
            parallel_overhead_us: 5.0,
            scalar_ipc: 3.0,
        }
    }

    /// Intel Xeon E3 (Skylake-era workstation): 4 cores, AVX2, 3.5 GHz.
    pub fn xeon_e3() -> Platform {
        Platform {
            name: "xeon_e3",
            display: "Intel Xeon E3",
            cores: 4,
            simd_lanes: 8,
            fma_ports: 2,
            fma_latency: 4.0,
            freq_ghz: 3.5,
            l1d_bytes: 32 << 10,
            l2_bytes: 256 << 10,
            l3_bytes: 8 << 20,
            l2_gbps: 140.0,
            l3_gbps: 120.0,
            dram_gbps: 34.0,
            parallel_overhead_us: 4.0,
            scalar_ipc: 2.5,
        }
    }

    /// Peak f32 GFLOP/s of one core (2 flops per FMA lane).
    pub fn core_peak_gflops(&self) -> f64 {
        self.freq_ghz * self.simd_lanes as f64 * self.fma_ports as f64 * 2.0
    }

    /// Peak f32 GFLOP/s of the whole chip.
    pub fn chip_peak_gflops(&self) -> f64 {
        self.core_peak_gflops() * self.cores as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_platforms_unique_names() {
        let all = Platform::all();
        assert_eq!(all.len(), 5);
        let mut names: Vec<_> = all.iter().map(|p| p.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 5);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Platform::by_name("core_i9").unwrap().display, "Intel Core i9");
        assert!(Platform::by_name("tpu_v9").is_none());
    }

    #[test]
    fn peak_flops_sane() {
        // Core i9: 5.0 GHz * 8 lanes * 2 ports * 2 = 160 GFLOP/s per core.
        let p = Platform::core_i9();
        assert_eq!(p.core_peak_gflops(), 160.0);
        assert_eq!(p.chip_peak_gflops(), 160.0 * 16.0);
    }

    #[test]
    fn cache_hierarchy_monotone() {
        for p in Platform::all() {
            assert!(p.l1d_bytes < p.l2_bytes, "{}", p.name);
            assert!(p.l2_bytes < p.l3_bytes, "{}", p.name);
            assert!(p.l2_gbps > p.dram_gbps / 8.0, "{}", p.name);
        }
    }
}
