//! Cost models: the analytical surrogate f-hat used inside MCTS rollouts,
//! the hardware simulator f that stands in for the paper's five-CPU
//! testbed, feature extraction for prompts/diagnostics, the platform
//! descriptors, and the shared per-stage [`AnalysisCache`] every cost-model
//! consumer memoizes access analyses through.

pub mod access;
pub mod analysis;
pub mod analytical;
pub mod batch;
pub mod calibration;
pub mod features;
pub mod platform;
pub mod simulator;

pub use analysis::AnalysisCache;
pub use analytical::{CostModel, HardwareModel, SurrogateModel};
pub use batch::{latency_batch, LatencyJob};
pub use calibration::CalibrationStats;
pub use features::Features;
pub use platform::Platform;
