//! The hardware simulator `f` — the "testbed" the search is measured on.
//!
//! Substitutes for the paper's real five-CPU measurement harness (see
//! DESIGN.md §Substitutions). For each stage it models:
//!
//! - **compute**: FMA issue throughput (SIMD lanes x ports) vs the
//!   accumulation-latency bound (independent chains), plus scalar ILP when
//!   not vectorized and gather penalties for strided vector loads;
//! - **memory**: per-level cache traffic from the tiling-reuse analysis in
//!   [`super::access`], divided by per-level bandwidths;
//! - **loop overhead**: branch/increment cost per non-unrolled loop level,
//!   and a register-pressure penalty for oversized unrolled bodies;
//! - **parallelism**: work division over the parallel prefix with
//!   quantization imbalance, fork/join overhead and shared-bandwidth
//!   saturation for L3/DRAM;
//! - **measurement noise**: small multiplicative lognormal noise per
//!   (schedule, seed), motivating the paper's 20-repeat protocol.
//!
//! Deterministic given (program, platform, seed), and fast (~microseconds),
//! so whole Table-1 sweeps run in seconds.

use crate::tir::{Program, Stage};
use crate::util::rng::Pcg;

use super::access::{self, StageAnalysis};
use super::analysis::AnalysisCache;
use super::platform::Platform;

/// Relative sigma of simulated measurement noise.
const NOISE_SIGMA: f64 = 0.02;

/// Independent accumulation chains the backend compiler extracts from any
/// schedule (unroll + reassociation at -O3). Explicit Unroll/Vectorize
/// raise `chains` beyond this floor.
const IMPLICIT_CHAINS: f64 = 12.0;

/// Fraction of SIMD lanes the backend auto-vectorizer captures on loops the
/// schedule did not explicitly vectorize.
const AUTOVEC_FRAC: f64 = 0.40;

/// Simulated latency of one program execution, in seconds.
/// `seed` selects the measurement-noise draw; seed 0 disables noise.
pub fn simulate(program: &Program, platform: &Platform, seed: u64) -> f64 {
    simulate_impl(program, seed, |p, s| stage_latency(&access::analyze(p, s), platform))
}

/// [`simulate`] with per-stage analyses served from the shared
/// [`AnalysisCache`]. Bit-identical to the uncached path (the analysis is a
/// pure value; see the cache's module docs), so the 20-repeat measurement
/// protocol pays for each distinct stage's analysis exactly once.
pub fn simulate_cached(
    program: &Program,
    platform: &Platform,
    seed: u64,
    analysis: &AnalysisCache,
) -> f64 {
    simulate_impl(program, seed, |p, s| stage_latency(&analysis.analyze(p, s), platform))
}

/// One summation loop shared by the cached and uncached paths, so the
/// bit-identity contract cannot drift between two hand-synchronized copies.
fn simulate_impl(
    program: &Program,
    seed: u64,
    stage_cost: impl Fn(&Program, &Stage) -> f64,
) -> f64 {
    let mut total = 0.0;
    for stage in &program.stages {
        total += stage_cost(program, stage);
        // Per-stage fixed launch cost (kernel call, arg setup).
        total += 2.0e-7;
    }
    apply_noise(program, seed, total)
}

/// Multiplicative lognormal measurement noise, stable per (program, seed).
fn apply_noise(program: &Program, seed: u64, total: f64) -> f64 {
    if seed == 0 {
        return total;
    }
    let mut rng = Pcg::new(seed ^ fingerprint(program));
    let noise = (rng.gen_normal() * NOISE_SIGMA).exp();
    total * noise
}

/// Breakdown of one stage's latency into its bounding terms — the
/// explanation surface behind `rcc explain` and the perf work in
/// EXPERIMENTS.md §Perf.
#[derive(Debug, Clone, Default)]
pub struct LatencyBreakdown {
    pub issue_s: f64,
    pub latency_bound_s: f64,
    pub overhead_s: f64,
    pub pressure: f64,
    pub l2_s: f64,
    pub l3_s: f64,
    pub dram_s: f64,
    pub parallel_eff: f64,
    pub fork_join_s: f64,
    pub total_s: f64,
}

impl LatencyBreakdown {
    pub fn render(&self) -> String {
        format!(
            "issue {:.3}ms | fma-latency {:.3}ms | loop-overhead {:.3}ms | pressure x{:.2}\n\
             l2 {:.3}ms | l3 {:.3}ms | dram {:.3}ms | parallel eff {:.1} | fork/join {:.3}ms\n\
             total {:.3}ms",
            self.issue_s * 1e3,
            self.latency_bound_s * 1e3,
            self.overhead_s * 1e3,
            self.pressure,
            self.l2_s * 1e3,
            self.l3_s * 1e3,
            self.dram_s * 1e3,
            self.parallel_eff,
            self.fork_join_s * 1e3,
            self.total_s * 1e3,
        )
    }
}

/// Latency of one analyzed stage on a platform, in seconds.
pub fn stage_latency(a: &StageAnalysis, p: &Platform) -> f64 {
    stage_breakdown(a, p).total_s
}

/// Full latency breakdown (see [`stage_latency`]).
pub fn stage_breakdown(a: &StageAnalysis, p: &Platform) -> LatencyBreakdown {
    let freq_hz = p.freq_ghz * 1e9;

    // ---- compute bound ----------------------------------------------------
    let flops = a.flops as f64;
    let (lanes_eff, gather_penalty) = vector_efficiency(a, p);
    // Throughput bound: flops / (lanes * ports * 2 flops-per-FMA).
    let issue_cycles =
        flops / (lanes_eff * p.fma_ports as f64 * 2.0) * gather_penalty;
    // Latency bound: an accumulator element can only be updated every
    // `fma_latency` cycles; independent accumulator elements (`chains`)
    // hide the stall. The backend compiler gets baseline credit for
    // unroll+reassociate (IMPLICIT_CHAINS) on any schedule.
    let updates = a.total_iters as f64;
    let chains_eff = (a.chains as f64).max(IMPLICIT_CHAINS);
    let latency_cycles = updates * p.fma_latency / chains_eff;

    // Loop bookkeeping overhead.
    let overhead_cycles = a.overhead_iters * 1.2;

    // Register pressure: unrolled body too large spills.
    let body = a.unrolled_product * a.vector_extent.unwrap_or(1);
    let pressure = if body > 256 {
        1.5
    } else if body > 64 {
        1.15
    } else {
        1.0
    };

    let compute_cycles = issue_cycles.max(latency_cycles) * pressure + overhead_cycles;
    let compute_s = compute_cycles / freq_hz;

    // ---- memory bound ------------------------------------------------------
    // Store traffic is read-for-ownership + writeback; a local accumulation
    // tile (cache_write) write-combines.
    let store_w = 2.0;
    let mut l2_bytes = access::traffic_bytes(a, p.l1d_bytes as i64, store_w);
    let l3_bytes = access::traffic_bytes(a, p.l2_bytes as i64, store_w);
    let dram_bytes = access::traffic_bytes(a, p.l3_bytes as i64, store_w);

    // Accumulation-interruption spills: writebacks beyond the compulsory
    // one-per-element land at the level that holds the output tile — cheap
    // (L2) when the output fits, DRAM-visible when it does not.
    let store = a.accesses.iter().find(|acc| acc.is_store);
    let out_elems = store.map(|s| s.elems_at_depth[0]).unwrap_or(1);
    let excess_wb = (a.writebacks - out_elems).max(0) as f64;
    let mut wb_spill = 0.0;
    if a.wb_tile_bytes > p.l2_bytes as i64 {
        // The thrashed output tile exceeds L2: spills are DRAM/L3-visible.
        wb_spill = excess_wb * access::LINE_BYTES as f64 * 0.25;
    } else {
        l2_bytes += excess_wb * 4.0; // read-modify-write stays cache-resident
    }

    let l2_s = l2_bytes / (p.l2_gbps * 1e9);
    let l3_s = (l3_bytes + wb_spill * 0.5) / (p.l3_gbps * 1e9);
    let dram_s = (dram_bytes + wb_spill * 0.5) / (p.dram_gbps * 1e9);

    // ---- parallel scaling ---------------------------------------------------
    let par = a.parallel_extent.max(1) as f64;
    let used = par.min(p.cores as f64);
    // Quantization imbalance: time is set by the core with ceil(P/used) units.
    let balance = if par > 0.0 {
        par / (used * (par / used).ceil())
    } else {
        1.0
    };
    let eff = used * balance;

    // Private resources (compute, L1->L2) scale with cores; shared L3/DRAM
    // saturate.
    let compute_par = compute_s / eff;
    let l2_par = l2_s / eff;
    let l3_par = l3_s / (eff.min(8.0));
    let dram_par = dram_s; // shared bus

    let fork_join = if par > 1.0 {
        p.parallel_overhead_us * 1e-6
    } else {
        0.0
    };

    // Bounds overlap imperfectly: max + a fraction of the rest.
    let bounds = [compute_par, l2_par, l3_par, dram_par];
    let dominant = bounds.iter().cloned().fold(0.0, f64::max);
    let rest: f64 = bounds.iter().sum::<f64>() - dominant;
    LatencyBreakdown {
        issue_s: issue_cycles / freq_hz / eff,
        latency_bound_s: latency_cycles / freq_hz / eff,
        overhead_s: overhead_cycles / freq_hz / eff,
        pressure,
        l2_s: l2_par,
        l3_s: l3_par,
        dram_s: dram_par,
        parallel_eff: eff,
        fork_join_s: fork_join,
        total_s: dominant + 0.25 * rest + fork_join,
    }
}

/// Effective SIMD lanes + gather penalty for vectorized innermost loops.
fn vector_efficiency(a: &StageAnalysis, p: &Platform) -> (f64, f64) {
    match a.vector_extent {
        // No explicit vectorization: the backend auto-vectorizer captures a
        // fraction of the lanes (the paper's "pre-optimized" baselines are
        // -O3-compiled, not scalar).
        None => ((p.simd_lanes as f64 * AUTOVEC_FRAC).max(1.0), 1.0),
        Some(ve) => {
            let lanes = p.simd_lanes as f64;
            // Short vectors underfill the lanes.
            let fill = (ve as f64 / lanes).min(4.0);
            let lanes_eff = lanes * fill.min(1.0);
            // Strided (non-unit, non-broadcast) loads become gathers.
            let mut penalty = 1.0;
            for acc in &a.accesses {
                if !acc.is_store && acc.innermost_stride > 1 {
                    penalty *= 3.0;
                }
            }
            (lanes_eff.max(1.0), penalty)
        }
    }
}

/// Structural hash so noise is stable per schedule (re-measuring the same
/// schedule with the same seed returns the same value).
fn fingerprint(program: &Program) -> u64 {
    let mut h: u64 = 0x9E3779B97F4A7C15;
    for s in &program.stages {
        for l in &s.loops {
            h ^= (l.extent as u64).wrapping_mul(0x100000001b3);
            h = h.rotate_left(13) ^ (l.kind as u64 + 1);
        }
        h = h.wrapping_mul(31).wrapping_add(s.cache_write as u64);
    }
    h
}

/// Speedup of `opt` over `base` on `platform` (the paper's figure of merit:
/// unoptimized time / optimized time).
pub fn speedup(base: &Program, opt: &Program, platform: &Platform) -> f64 {
    simulate(base, platform, 0) / simulate(opt, platform, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload::{self, WorkloadId};

    fn i9() -> Platform {
        Platform::core_i9()
    }

    #[test]
    fn latency_positive_and_deterministic() {
        for w in WorkloadId::ALL {
            let p = w.build();
            let t1 = simulate(&p, &i9(), 0);
            let t2 = simulate(&p, &i9(), 0);
            assert!(t1 > 0.0, "{}", w.name());
            assert_eq!(t1, t2);
        }
    }

    #[test]
    fn cached_simulation_bit_identical_to_uncached() {
        let cache = AnalysisCache::new();
        for w in WorkloadId::ALL {
            let p = w.build();
            for seed in [0u64, 1, 7] {
                let plain = simulate(&p, &i9(), seed);
                // Twice: first call populates, second hits the cache.
                let first = simulate_cached(&p, &i9(), seed, &cache);
                let hit = simulate_cached(&p, &i9(), seed, &cache);
                assert_eq!(plain.to_bits(), first.to_bits(), "{} seed {seed}", w.name());
                assert_eq!(plain.to_bits(), hit.to_bits(), "{} seed {seed}", w.name());
            }
        }
        assert!(!cache.is_empty());
    }

    #[test]
    fn noise_small_and_seeded() {
        let p = WorkloadId::DeepSeekMoe.build();
        let base = simulate(&p, &i9(), 0);
        let a = simulate(&p, &i9(), 1);
        let b = simulate(&p, &i9(), 1);
        let c = simulate(&p, &i9(), 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!((a / base - 1.0).abs() < 0.2);
    }

    #[test]
    fn vectorize_helps_contiguous_matmul() {
        let p = workload::moe_matmul("m", 16, 2048, 1024);
        let base = simulate(&p, &i9(), 0);
        // j innermost (contiguous for B and C), tile to 16, vectorize.
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 16 }.apply(&p).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2] }.apply(&q).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 3 }.apply(&q).unwrap();
        let t = simulate(&q, &i9(), 0);
        assert!(t < base, "vectorized {t} vs base {base}");
    }

    #[test]
    fn parallel_helps_large_work() {
        let p = WorkloadId::DeepSeekMoe.build();
        let base = simulate(&p, &i9(), 0);
        let q = Transform::Parallel { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        let t = simulate(&q, &i9(), 0);
        assert!(t < base, "parallel {t} vs base {base}");
    }

    #[test]
    fn tiling_helps_cache_bound_matmul() {
        let p = workload::moe_matmul("m", 64, 2048, 2048);
        let base = simulate(&p, &i9(), 0);
        // Classic register/cache tiling.
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 }.apply(&p).unwrap();
        let q = Transform::TileSize { stage: 0, loop_idx: 3, factor: 64 }.apply(&q).unwrap();
        // (t, j0, j1, k0, k1) -> (t, j0, k0, j1, k1)
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2, 4] }.apply(&q).unwrap();
        let t = simulate(&q, &i9(), 0);
        assert!(t < base, "tiled {t} vs base {base}");
    }

    #[test]
    fn reduction_outer_writeback_storm_hurts_large_output() {
        // When the thrashed output tile exceeds L2, hoisting the reduction
        // loop outermost forces every accumulation run to spill to DRAM:
        // (k, t, j) must lose to (t, k, j), which thrashes only one row.
        let p = workload::moe_matmul("m", 2048, 2048, 64);
        let base = Transform::Reorder { stage: 0, perm: vec![0, 2, 1] }.apply(&p).unwrap();
        let base_t = simulate(&base, &i9(), 0);
        let q = Transform::Reorder { stage: 0, perm: vec![2, 0, 1] }.apply(&p).unwrap();
        let t = simulate(&q, &i9(), 0);
        assert!(t > base_t, "reduction-outer {t} should be worse than {base_t}");
    }

    #[test]
    fn platforms_differ() {
        let p = WorkloadId::Llama4Mlp.build();
        let times: Vec<f64> = Platform::all()
            .iter()
            .map(|pl| simulate(&p, pl, 0))
            .collect();
        let mut uniq = times.clone();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        assert_eq!(uniq.len(), times.len(), "{times:?}");
    }

    #[test]
    fn good_schedule_speedup_in_paper_range() {
        // A hand-built "good" schedule should land in the single-to-low-double
        // digit speedup range the paper reports (not 1000x, not 1.01x).
        let p = WorkloadId::DeepSeekMoe.build();
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 }.apply(&p).unwrap();
        // (t, j0, j1(64), k)
        let q = Transform::TileSize { stage: 0, loop_idx: 3, factor: 16 }.apply(&q).unwrap();
        // (t, j0, j1, k0, k1)
        let q = Transform::Reorder { stage: 0, perm: vec![1, 0, 3, 4, 2] }.apply(&q).unwrap();
        // (j0, t, k0, k1, j1)
        let q = Transform::Parallel { stage: 0, loop_idx: 0 }.apply(&q).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 4 }.apply(&q).unwrap();
        let q = Transform::Unroll { stage: 0, loop_idx: 3 }.apply(&q).unwrap();
        let s = speedup(&p, &q, &i9());
        assert!(s > 2.0 && s < 400.0, "speedup {s}");
    }
}
