//! Loop-nest access analysis.
//!
//! Shared machinery for the hardware simulator, the rollout surrogate and
//! the feature extractor: per-depth working-set footprints, cache-line
//! traffic under a capacity model, innermost-access strides, parallel
//! structure and accumulation-chain analysis.
//!
//! The model is the classic tiling-reuse analysis: for a cache of capacity
//! `C`, find the outermost loop depth `d` at which the nest's working set
//! fits in `C`; every loop outside `d` then re-streams that working set, so
//! traffic(level) = trips(0..d) x footprint_lines(d).

use std::sync::Mutex;

use crate::tir::expr::LinIdx;
use crate::tir::program::{BufKind, LoopKind, Program, ReduceOp, Stage};

pub const LINE_BYTES: i64 = 64;
const F32_BYTES: i64 = 4;

/// Per-analysis memo of [`traffic_bytes`] components, keyed by cache
/// capacity: `(capacity, load_bytes, store_bytes)`. Analyses are shared
/// (`Arc<StageAnalysis>` out of the `AnalysisCache`) across the 20-repeat
/// measurement protocol, both cost models and every worker thread, and
/// each simulator call re-derives traffic for the same three capacity
/// levels — the last repeated pure computation on the simulate hot path.
/// A handful of capacities ever occur per platform, so a small
/// linear-scan vec under a mutex beats a hash map here.
///
/// Store traffic is memoized separately from load traffic (the one store
/// is the last access), so one entry serves every `store_weight`
/// bit-identically. Cloning an analysis starts an empty memo: entries are
/// recomputable pure values, never state.
#[derive(Debug, Default)]
pub struct TrafficMemo {
    slots: Mutex<Vec<(i64, f64, f64)>>,
}

impl Clone for TrafficMemo {
    fn clone(&self) -> Self {
        TrafficMemo::default()
    }
}

/// Analysis of one buffer access (load or store) within a stage.
#[derive(Debug, Clone)]
pub struct AccessInfo {
    pub buffer: usize,
    pub is_store: bool,
    /// Distinct elements touched by the loops at depth >= d, for d in 0..=n.
    pub elems_at_depth: Vec<i64>,
    /// Distinct cache lines touched by the loops at depth >= d.
    pub lines_at_depth: Vec<i64>,
    /// Stride (in elements) of the flattened index w.r.t. the innermost
    /// loop: 0 = invariant (broadcast), 1 = contiguous, else strided.
    pub innermost_stride: i64,
}

/// Full analysis of one stage.
#[derive(Debug, Clone)]
pub struct StageAnalysis {
    /// trips[d] = product of extents of loops 0..d (iterations of everything
    /// outside depth d). trips[0] = 1.
    pub trips: Vec<i64>,
    /// Combined working set in bytes at each depth (line-granular).
    pub footprint_bytes: Vec<i64>,
    pub accesses: Vec<AccessInfo>,
    /// Product of extents of the parallel prefix.
    pub parallel_extent: i64,
    /// Independent accumulation chains available in the innermost region
    /// (spatial unroll x vector lanes) — bounds latency-limited FMA issue.
    pub chains: i64,
    /// Innermost loop is vectorized, and with which extent.
    pub vector_extent: Option<i64>,
    /// Product of unrolled loop extents.
    pub unrolled_product: i64,
    /// Iterations executed by non-unrolled, non-vectorized loop levels —
    /// drives branch/increment overhead.
    pub overhead_iters: f64,
    /// Writebacks of the output per full stage execution (accumulation
    /// interruption model; see `writeback_count`).
    pub writebacks: i64,
    /// Bytes of output live across one accumulation-interruption cycle:
    /// the output lines touched inside the outermost reduction loop. This
    /// is the working set that writeback traffic thrashes, so it decides
    /// which cache level absorbs the spills.
    pub wb_tile_bytes: i64,
    pub total_iters: i64,
    pub flops: u64,
    /// Lazily memoized per-capacity traffic components (see
    /// [`TrafficMemo`]); starts empty, filled by [`traffic_bytes`].
    pub traffic_memo: TrafficMemo,
}

/// Analyze a stage. Cost-model hot path: called once per candidate
/// schedule evaluation.
pub fn analyze(program: &Program, stage: &Stage) -> StageAnalysis {
    let n = stage.loops.len();

    // trips[d] = prod extents[0..d]
    let mut trips = Vec::with_capacity(n + 1);
    trips.push(1i64);
    for l in &stage.loops {
        let last = *trips.last().unwrap();
        trips.push(last.saturating_mul(l.extent));
    }
    let total_iters = trips[n];

    // Axis spans: span_from[d][axis] = range of the axis expression when
    // loops at depth >= d run and loops outside are fixed.
    // Axis exprs are monotone non-decreasing in every var (splits produce
    // vo*f+vi, fuses produce f/e and f%e), so endpoint evaluation is exact.
    let n_axes = stage.axes.len();
    let env_lo = vec![0i64; stage.var_extents.len()];
    let mut span_from: Vec<Vec<i64>> = vec![vec![0; n_axes]; n + 1];
    for d in (0..n).rev() {
        let mut env_hi = env_lo.clone();
        for l in &stage.loops[d..] {
            env_hi[l.var] = l.extent - 1;
        }
        for (a, e) in stage.axis_exprs.iter().enumerate() {
            let lo = e.eval(&env_lo);
            let hi = e.eval(&env_hi);
            span_from[d][a] = (hi - lo).min(stage.axes[a].extent - 1);
        }
    }

    // Collect accesses: all loads + the output store.
    let mut loads = Vec::new();
    stage.block.rhs.loads(&mut loads);
    let mut raw: Vec<(usize, Vec<LinIdx>, bool)> = loads
        .into_iter()
        .map(|(b, idx)| (b, idx.to_vec(), false))
        .collect();
    raw.push((stage.block.out, stage.block.out_idx.clone(), true));

    let innermost_var_span = |d: usize| -> Vec<i64> {
        // Span of each axis when only the innermost loop moves.
        let mut env_hi = env_lo.clone();
        if n > 0 {
            env_hi[stage.loops[d].var] = stage.loops[d].extent - 1;
        }
        stage
            .axis_exprs
            .iter()
            .map(|e| e.eval(&env_hi) - e.eval(&env_lo))
            .collect()
    };
    let inner_axis_delta: Vec<i64> = if n > 0 {
        // Per-axis delta for one step of the innermost loop.
        let mut env_one = env_lo.clone();
        env_one[stage.loops[n - 1].var] = 1;
        stage
            .axis_exprs
            .iter()
            .map(|e| e.eval(&env_one) - e.eval(&env_lo))
            .collect()
    } else {
        vec![0; n_axes]
    };
    let _ = innermost_var_span;

    let mut accesses = Vec::with_capacity(raw.len());
    let mut footprint_bytes = vec![0i64; n + 1];
    for (buf, idx, is_store) in raw {
        let shape = &program.buffers[buf].shape;
        let mut elems_at_depth = Vec::with_capacity(n + 1);
        let mut lines_at_depth = Vec::with_capacity(n + 1);
        for d in 0..=n {
            let spans = &span_from[d]; // span_from[n] is all zeros

            // Per-dimension element counts and line count.
            let mut elems: i64 = 1;
            let mut lines: i64 = 1;
            for (dim, ix) in idx.iter().enumerate() {
                let dim_size = shape[dim];
                let mut span: i64 = 0;
                for &(a, k) in &ix.terms {
                    span += spans[a] * k.abs();
                }
                span = span.min(dim_size - 1);
                let dim_elems = (span + 1).min(dim_size);
                elems = elems.saturating_mul(dim_elems);
                if dim + 1 == idx.len() {
                    // Last (contiguous) dim: line count from the byte span.
                    let dense_lines = (span * F32_BYTES) / LINE_BYTES + 1;
                    lines = lines.saturating_mul(dense_lines.min(dim_elems));
                } else {
                    lines = lines.saturating_mul(dim_elems);
                }
            }
            elems_at_depth.push(elems);
            lines_at_depth.push(lines);
            footprint_bytes[d] += lines * LINE_BYTES;
        }
        // Innermost stride: change in the flattened index per step of the
        // innermost loop.
        let strides = program.buffers[buf].strides();
        let mut innermost_stride: i64 = 0;
        for (dim, ix) in idx.iter().enumerate() {
            let mut delta: i64 = 0;
            for &(a, k) in &ix.terms {
                delta += inner_axis_delta[a] * k;
            }
            innermost_stride += delta * strides[dim];
        }
        accesses.push(AccessInfo {
            buffer: buf,
            is_store,
            elems_at_depth,
            lines_at_depth,
            innermost_stride: innermost_stride.abs(),
        });
    }

    // Parallel prefix.
    let parallel_extent: i64 = stage
        .loops
        .iter()
        .take_while(|l| l.kind == LoopKind::Parallel)
        .map(|l| l.extent)
        .product();

    // Vector + unroll structure.
    let vector_extent = stage
        .loops
        .last()
        .filter(|l| l.kind == LoopKind::Vectorized)
        .map(|l| l.extent);
    let unrolled_product: i64 = stage
        .loops
        .iter()
        .filter(|l| l.kind == LoopKind::Unrolled)
        .map(|l| l.extent)
        .product();

    // Independent accumulation chains: spatial loops in the innermost
    // region (vectorized innermost + unrolled loops adjacent to it) supply
    // independent accumulators. Capped by the register file.
    let mut chains: i64 = 1;
    if stage.block.reduce != ReduceOp::Assign {
        for (li, l) in stage.loops.iter().enumerate().rev() {
            let spatial = !stage.loop_is_reduction(li);
            match l.kind {
                LoopKind::Vectorized => {
                    if spatial {
                        chains = chains.saturating_mul(l.extent);
                    }
                }
                LoopKind::Unrolled => {
                    if spatial {
                        chains = chains.saturating_mul(l.extent);
                    }
                    // Unrolled reduction loops break the dependence chain too
                    // (compiler reassociates across the unrolled body).
                    if !spatial {
                        chains = chains.saturating_mul(l.extent.min(4));
                    }
                }
                _ => break, // chain region = innermost vec/unroll suffix
            }
        }
    } else {
        chains = 64; // elementwise: no carried dependence
    }

    // Loop bookkeeping overhead: each non-unrolled, non-vectorized loop
    // level costs ~1 branch+increment per iteration of that level.
    let mut overhead_iters = 0.0f64;
    for (li, l) in stage.loops.iter().enumerate() {
        let level_iters = trips[li + 1] as f64;
        match l.kind {
            LoopKind::Unrolled => overhead_iters += level_iters * 0.05,
            LoopKind::Vectorized => overhead_iters += level_iters / l.extent.max(1) as f64,
            _ => overhead_iters += level_iters,
        }
    }

    let writebacks = writeback_count(stage, &trips);

    // Output tile live across accumulation interruptions: the store's
    // footprint inside the outermost reduction loop.
    let outermost_reduction = (0..n).find(|&li| stage.loop_is_reduction(li));
    let wb_tile_bytes = accesses
        .iter()
        .find(|acc| acc.is_store)
        .map(|acc| {
            let d = outermost_reduction.map(|li| li + 1).unwrap_or(n);
            acc.lines_at_depth[d] * LINE_BYTES
        })
        .unwrap_or(0);

    StageAnalysis {
        trips,
        footprint_bytes,
        accesses,
        parallel_extent,
        chains: chains.clamp(1, 64),
        vector_extent,
        unrolled_product,
        overhead_iters,
        writebacks,
        wb_tile_bytes,
        total_iters,
        flops: stage.flops(),
        traffic_memo: TrafficMemo::default(),
    }
}

/// How many times output elements are written back during the stage.
///
/// An accumulation run is uninterrupted while the innermost suffix of loops
/// leaves the output index unchanged (pure reduction suffix). Each
/// interruption forces a spill + reload. `cache_write` widens the window:
/// a register/L1 tile lets small spatial loops live inside the run.
fn writeback_count(stage: &Stage, trips: &[i64]) -> i64 {
    let n = stage.loops.len();
    if stage.block.reduce == ReduceOp::Assign {
        return trips[n]; // every iteration stores
    }
    // Find the innermost suffix of loops that do not move the output index.
    let mut suffix_run: i64 = 1;
    let mut tile_elems: i64 = 1;
    for li in (0..n).rev() {
        let l = &stage.loops[li];
        let moves_output = stage
            .axes_of_var(l.var)
            .iter()
            .any(|&a| stage.block.out_idx.iter().any(|ix| ix.coeff(a) != 0));
        if !moves_output {
            suffix_run = suffix_run.saturating_mul(l.extent);
        } else if stage.cache_write && tile_elems.saturating_mul(l.extent) <= 1024 {
            // With a local accumulation tile, small spatial loops stay
            // inside the run (the tile holds extent more accumulators).
            tile_elems = tile_elems.saturating_mul(l.extent);
            suffix_run = suffix_run.saturating_mul(l.extent);
        } else {
            break;
        }
    }
    (trips[n] / suffix_run.max(1)).max(1)
}

/// Cache traffic in bytes for a capacity level: the tiling-reuse model.
/// `store_weight` scales store traffic (read-for-ownership + write-back).
///
/// Memoized per `(analysis, capacity)` in the analysis itself (see
/// [`TrafficMemo`]): load and store components are cached separately and
/// recombined under the caller's `store_weight`, bit-identically to the
/// unmemoized sum — the store is the single last access, so
/// `loads + store_weight * store` reproduces the original left-to-right
/// accumulation exactly.
pub fn traffic_bytes(a: &StageAnalysis, capacity: i64, store_weight: f64) -> f64 {
    {
        let memo = a.traffic_memo.slots.lock().unwrap();
        if let Some(&(_, loads, stores)) = memo.iter().find(|e| e.0 == capacity) {
            return loads + store_weight * stores;
        }
    }
    let n = a.trips.len() - 1;
    // Outermost depth whose working set fits.
    let mut d_fit = n;
    for d in 0..=n {
        if a.footprint_bytes[d] <= capacity {
            d_fit = d;
            break;
        }
    }
    let trips = a.trips[d_fit] as f64;
    let mut loads = 0.0;
    let mut stores = 0.0;
    for acc in &a.accesses {
        let bytes = trips * acc.lines_at_depth[d_fit] as f64 * LINE_BYTES as f64;
        if acc.is_store {
            stores += bytes;
        } else {
            loads += bytes;
        }
    }
    let mut memo = a.traffic_memo.slots.lock().unwrap();
    if !memo.iter().any(|e| e.0 == capacity) {
        memo.push((capacity, loads, stores));
    }
    loads + store_weight * stores
}

/// Whole-program analysis (per stage) plus total weights for multi-stage
/// programs (attention = scores + output matmuls).
pub fn analyze_program(program: &Program) -> Vec<StageAnalysis> {
    program
        .stages
        .iter()
        .map(|s| analyze(program, s))
        .collect()
}

/// Does any buffer access have unit stride w.r.t. the innermost loop?
/// (Cheap helper for the feature extractor / reasoning diagnostics.)
pub fn innermost_contiguity(a: &StageAnalysis) -> (usize, usize, usize) {
    let mut contiguous = 0;
    let mut broadcast = 0;
    let mut strided = 0;
    for acc in &a.accesses {
        match acc.innermost_stride {
            0 => broadcast += 1,
            1 => contiguous += 1,
            _ => strided += 1,
        }
    }
    (contiguous, broadcast, strided)
}

/// Is `kind` a buffer the traffic model should ignore at register level?
pub fn is_external(kind: BufKind) -> bool {
    matches!(kind, BufKind::Input | BufKind::Output | BufKind::Intermediate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload;

    #[test]
    fn naive_matmul_footprints() {
        // C[4,6] = A[4,8] x B[8,6]
        let p = workload::moe_matmul("m", 4, 6, 8);
        let a = analyze(&p, &p.stages[0]);
        assert_eq!(a.total_iters, 4 * 6 * 8);
        assert_eq!(a.trips, vec![1, 4, 24, 192]);
        // At depth 0 the whole of A, B, C is live.
        // A: 4x8=32 elems, B: 8x6=48, C: 4x6=24.
        assert_eq!(a.accesses[0].elems_at_depth[0], 32);
        assert_eq!(a.accesses[1].elems_at_depth[0], 48);
        assert_eq!(a.accesses[2].elems_at_depth[0], 24);
        // At full depth (single iteration): 1 element each.
        assert_eq!(a.accesses[0].elems_at_depth[3], 1);
        assert_eq!(a.accesses[1].elems_at_depth[3], 1);
    }

    #[test]
    fn innermost_strides_matmul() {
        // Loops (t, j, k): A[t,k] stride 1 in k; B[k,j] stride = row (6);
        // C[t,j] invariant in k (stride 0).
        let p = workload::moe_matmul("m", 4, 6, 8);
        let a = analyze(&p, &p.stages[0]);
        assert_eq!(a.accesses[0].innermost_stride, 1); // A
        assert_eq!(a.accesses[1].innermost_stride, 6); // B
        assert_eq!(a.accesses[2].innermost_stride, 0); // C store
    }

    #[test]
    fn writebacks_reduction_innermost_vs_outermost() {
        let p = workload::moe_matmul("m", 4, 6, 8);
        let a = analyze(&p, &p.stages[0]);
        // k innermost: one writeback per output element.
        assert_eq!(a.writebacks, 24);
        // Reorder k outermost: writeback storm.
        let q = Transform::Reorder { stage: 0, perm: vec![2, 0, 1] }
            .apply(&p)
            .unwrap();
        let aq = analyze(&q, &q.stages[0]);
        assert_eq!(aq.writebacks, 192);
    }

    #[test]
    fn cache_write_extends_run() {
        let p = workload::moe_matmul("m", 4, 6, 8);
        // Put j inside k: (t, k, j) — j interrupts accumulation.
        let q = Transform::Reorder { stage: 0, perm: vec![0, 2, 1] }.apply(&p).unwrap();
        let aq = analyze(&q, &q.stages[0]);
        assert_eq!(aq.writebacks, 192); // every iteration spills
        let qc = Transform::CacheWrite { stage: 0 }.apply(&q).unwrap();
        let aqc = analyze(&qc, &qc.stages[0]);
        // j-tile (6 accumulators) lives locally: one writeback per (t) x j.
        assert!(aqc.writebacks < aq.writebacks);
    }

    #[test]
    fn traffic_fits_vs_streams() {
        let p = workload::moe_matmul("m", 16, 64, 64);
        let a = analyze(&p, &p.stages[0]);
        // Huge cache: cold misses only (footprint at depth 0).
        let cold = traffic_bytes(&a, 1 << 30, 1.0);
        assert_eq!(cold, a.footprint_bytes[0] as f64);
        // Tiny cache: traffic strictly larger.
        let hot = traffic_bytes(&a, 1 << 8, 1.0);
        assert!(hot > cold * 4.0, "hot={hot} cold={cold}");
    }

    #[test]
    fn traffic_memo_is_bit_identical_and_weight_independent() {
        let p = workload::moe_matmul("m", 16, 64, 64);
        let a = analyze(&p, &p.stages[0]);
        for cap in [1i64 << 8, 32 << 10, 1 << 30] {
            for w in [1.0, 1.6, 2.0] {
                // First call computes + memoizes; the second answers from
                // the memo; a fresh analysis is the unmemoized reference.
                let first = traffic_bytes(&a, cap, w);
                let memoized = traffic_bytes(&a, cap, w);
                let fresh = traffic_bytes(&analyze(&p, &p.stages[0]), cap, w);
                assert_eq!(first.to_bits(), memoized.to_bits(), "cap={cap} w={w}");
                assert_eq!(first.to_bits(), fresh.to_bits(), "cap={cap} w={w}");
            }
        }
        // One memo entry per distinct capacity, shared across weights.
        assert_eq!(a.traffic_memo.slots.lock().unwrap().len(), 3);
        // Clones restart cold (entries are pure values, not state).
        assert!(a.clone().traffic_memo.slots.lock().unwrap().is_empty());
    }

    #[test]
    fn tiling_reduces_small_cache_traffic() {
        // B streamed repeatedly: tiling j should cut the per-trip footprint.
        let p = workload::moe_matmul("m", 16, 256, 256);
        let a_naive = analyze(&p, &p.stages[0]);
        // Tile j by 16 and k by 16, order (t, j0, k0, j1, k1).
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 16 }.apply(&p).unwrap();
        let q = Transform::TileSize { stage: 0, loop_idx: 3, factor: 16 }.apply(&q).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2, 4] }.apply(&q).unwrap();
        let a_tiled = analyze(&q, &q.stages[0]);
        let cap = 32 << 10; // 32 KB L1
        let t_naive = traffic_bytes(&a_naive, cap, 1.0);
        let t_tiled = traffic_bytes(&a_tiled, cap, 1.0);
        assert!(
            t_tiled < t_naive,
            "tiled traffic {t_tiled} should beat naive {t_naive}"
        );
    }

    #[test]
    fn parallel_and_vector_structure() {
        let p = workload::moe_matmul("m", 16, 64, 64);
        let q = Transform::Parallel { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 16 }.apply(&q).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2] }.apply(&q).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 3 }.apply(&q).unwrap();
        let a = analyze(&q, &q.stages[0]);
        assert_eq!(a.parallel_extent, 16);
        assert_eq!(a.vector_extent, Some(16));
        assert!(a.chains >= 16); // vectorized spatial loop gives 16 chains
    }

    #[test]
    fn conv_footprint_includes_halo() {
        let p = workload::conv2d("c", 4, 4, 10, 10, 3);
        let a = analyze(&p, &p.stages[0]);
        // Input footprint at depth 0 = full input.
        assert_eq!(a.accesses[0].elems_at_depth[0], 4 * 10 * 10);
    }

    #[test]
    fn overhead_drops_with_unroll_and_vectorize() {
        let p = workload::moe_matmul("m", 16, 64, 64);
        let base = analyze(&p, &p.stages[0]).overhead_iters;
        let q = Transform::Unroll { stage: 0, loop_idx: 2 }.apply(&p).unwrap();
        let unrolled = analyze(&q, &q.stages[0]).overhead_iters;
        assert!(unrolled < base);
    }
}
