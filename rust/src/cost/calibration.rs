//! Cost-model calibration: surrogate prediction vs measured latency.
//!
//! Every hardware measurement the search folds comes with the surrogate
//! prediction that justified spending the sample (MCTS's child score, an
//! ES member's ranking fitness). [`CalibrationStats`] aggregates the
//! relative residuals `(predicted - measured) / measured` into a
//! mergeable summary that rides in the session `telemetry` block and the
//! registry run JSON — the predicted-vs-measured substrate ROADMAP item
//! 5's roofline cost-model work needs.
//!
//! Aggregation is raw sums (not means), so per-run stats merge exactly
//! and round-trip bit-exactly through the session journal. Failed
//! (quarantined) measurements carry an infinite sentinel and are never
//! recorded here.

use crate::util::json::{num, Json};

/// Mergeable residual summary of predicted-vs-measured latency pairs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibrationStats {
    /// Pairs recorded.
    pub n: u64,
    /// Sum of signed relative errors `(pred - meas) / meas` (bias).
    pub sum_rel: f64,
    /// Sum of absolute relative errors.
    pub sum_abs_rel: f64,
    /// Largest single absolute relative error.
    pub worst_abs_rel: f64,
}

impl CalibrationStats {
    /// Record one prediction/measurement pair. Non-finite or
    /// non-positive values (the quarantine sentinel, a degenerate
    /// baseline) are ignored — calibration only speaks for real samples.
    pub fn record(&mut self, predicted: f64, measured: f64) {
        if !predicted.is_finite() || !measured.is_finite() || measured <= 0.0 {
            return;
        }
        let rel = (predicted - measured) / measured;
        self.n += 1;
        self.sum_rel += rel;
        self.sum_abs_rel += rel.abs();
        if rel.abs() > self.worst_abs_rel {
            self.worst_abs_rel = rel.abs();
        }
    }

    /// Fold another summary in (exact: sums add, worst takes max).
    pub fn merge(&mut self, other: &CalibrationStats) {
        self.n += other.n;
        self.sum_rel += other.sum_rel;
        self.sum_abs_rel += other.sum_abs_rel;
        if other.worst_abs_rel > self.worst_abs_rel {
            self.worst_abs_rel = other.worst_abs_rel;
        }
    }

    /// Mean absolute relative error (0 when empty).
    pub fn mean_abs_rel(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum_abs_rel / self.n as f64 }
    }

    /// Mean signed relative error: positive = the model over-predicts.
    pub fn bias(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum_rel / self.n as f64 }
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// One human line for session reports.
    pub fn render_line(&self) -> String {
        format!(
            "calibration: {} pairs, mean |err| {:.1}%, bias {:+.1}%, worst {:.1}%",
            self.n,
            self.mean_abs_rel() * 100.0,
            self.bias() * 100.0,
            self.worst_abs_rel * 100.0
        )
    }

    /// Raw sums plus derived means (readability); [`from_json`] reads
    /// only the raw fields, so the round-trip is bit-exact.
    ///
    /// [`from_json`]: CalibrationStats::from_json
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("n", num(self.n as f64))
            .set("sum_rel", num(self.sum_rel))
            .set("sum_abs_rel", num(self.sum_abs_rel))
            .set("worst_abs_rel", num(self.worst_abs_rel))
            .set("mean_abs_rel", num(self.mean_abs_rel()))
            .set("bias", num(self.bias()));
        j
    }

    /// Decode [`to_json`] output; a missing/empty document decodes as the
    /// empty summary (older journals predate calibration).
    ///
    /// [`to_json`]: CalibrationStats::to_json
    pub fn from_json(j: &Json) -> CalibrationStats {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        CalibrationStats {
            n: f("n") as u64,
            sum_rel: f("sum_rel"),
            sum_abs_rel: f("sum_abs_rel"),
            worst_abs_rel: f("worst_abs_rel"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_aggregates_signed_and_absolute_residuals() {
        let mut c = CalibrationStats::default();
        c.record(12.0, 10.0); // +20%
        c.record(8.0, 10.0); // -20%
        c.record(15.0, 10.0); // +50%
        assert_eq!(c.n, 3);
        assert!((c.bias() - (0.2 - 0.2 + 0.5) / 3.0).abs() < 1e-12);
        assert!((c.mean_abs_rel() - 0.3).abs() < 1e-12);
        assert!((c.worst_abs_rel - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sentinels_and_degenerate_measurements_ignored() {
        let mut c = CalibrationStats::default();
        c.record(1.0, f64::INFINITY); // quarantined measurement
        c.record(f64::NAN, 1.0);
        c.record(f64::INFINITY, 1.0);
        c.record(1.0, 0.0);
        c.record(1.0, -2.0);
        assert!(c.is_empty());
        assert_eq!(c.mean_abs_rel(), 0.0);
        assert_eq!(c.bias(), 0.0);
    }

    #[test]
    fn merge_is_exact() {
        let mut a = CalibrationStats::default();
        a.record(11.0, 10.0);
        a.record(14.0, 10.0);
        let mut b = CalibrationStats::default();
        b.record(5.0, 10.0);
        let mut whole = CalibrationStats::default();
        for (p, m) in [(11.0, 10.0), (14.0, 10.0), (5.0, 10.0)] {
            whole.record(p, m);
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert!((a.worst_abs_rel - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let mut c = CalibrationStats::default();
        c.record(0.123456789, 0.987654321);
        c.record(3.0, 7.0);
        let text = c.to_json().to_string();
        let back = CalibrationStats::from_json(&Json::parse(&text).unwrap());
        assert_eq!(back.n, c.n);
        assert_eq!(back.sum_rel.to_bits(), c.sum_rel.to_bits());
        assert_eq!(back.sum_abs_rel.to_bits(), c.sum_abs_rel.to_bits());
        assert_eq!(back.worst_abs_rel.to_bits(), c.worst_abs_rel.to_bits());
        assert!(c.render_line().contains("2 pairs"));
    }
}
