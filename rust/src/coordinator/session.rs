//! Single tuning sessions: strategy dispatch, repeated (multi-seed) runs
//! with the paper's mean-of-20 protocol, parallel execution across
//! repeats, crash-safe journaling/resume, and the session-level
//! open/commit lifecycle of the persistent tuning database.
//!
//! The multi-model drivers (the `rcc serve --tune` fleet and the
//! end-to-end task set) live in [`super::fleet`]; this module owns
//! everything from one `(workload, platform)` pair down.
//!
//! Every parallel site here — the session's repeats and each repeat's
//! batched evaluation — runs as task groups on **one** persistent
//! [`Executor`] sized by `TuneConfig::workers`. Nested sites share that
//! single core budget (waiting submitters help run queued tasks) instead
//! of multiplying per-site thread pools into `workers²` threads.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::cost::{AnalysisCache, CalibrationStats, HardwareModel, Platform, SurrogateModel};
use crate::db::{workload_fingerprint, Database, MeasureCache, TuningRecord, WarmStart};
use crate::obs;
use crate::reasoning::{CostTracker, LlmPolicy, ModelProfile, SimulatedLlm};
use crate::search::{
    EvoConfig, EvolutionaryStrategy, MctsConfig, MctsStrategy, RandomPolicy, SearchContext,
    SearchResult, SearchStrategy,
};
use crate::tir::workload::WorkloadId;
use crate::tir::Program;
use crate::transfer::{self, Exemplar};
use crate::util::executor::Executor;
use crate::util::faults;
use crate::util::json::{self, Json};
use crate::util::stats;

use super::config::{Strategy, TuneConfig};
use super::journal::{JournalEntry, JournalHeader, SessionJournal};

/// Database-derived hints shared by every repeat of a session: warm-start
/// traces plus a measurement cache pre-populated with known costs. Each run
/// clones the cache (runs are independent; counters are per-run) unless the
/// session opts into `share_repeat_cache`. With transfer tuning enabled the
/// warm traces also include rebased cross-workload records, and
/// `exemplars` feeds the LLM proposal policy's few-shot context.
#[derive(Debug, Clone, Default)]
pub struct SearchHints {
    pub warm: WarmStart,
    pub cache: MeasureCache,
    /// Few-shot exemplars from structurally similar workloads (transfer
    /// subsystem); only the LLM strategy consumes these.
    pub exemplars: Vec<Exemplar>,
}

/// Observability snapshot of one tuning session: this session's share of
/// the process-wide per-phase time aggregates plus executor counters,
/// captured as before/after deltas around the repeats. Phase rows populate
/// only while tracing is enabled (`--trace` / `RCC_TRACE`); the executor
/// counters are always on. Pure telemetry — never part of any result
/// comparison, so tracing on/off cannot perturb determinism contracts.
#[derive(Debug, Clone, Default)]
pub struct SessionTelemetry {
    /// `(phase name, stat)` rows for phases that recorded at least once.
    pub phases: Vec<(String, obs::PhaseStat)>,
    pub exec: obs::ExecCounters,
    /// Cost-model calibration: surrogate predictions vs measured latencies,
    /// aggregated over every repeat of the session. Always on (the pairs
    /// exist regardless of tracing); empty only when nothing was measured.
    pub calibration: CalibrationStats,
    /// Trace events lost to per-thread ring overwrites during this
    /// session's window (0 unless tracing is enabled and overran a ring).
    pub dropped_events: u64,
}

impl SessionTelemetry {
    /// Delta between two snapshots taken around the reported body of work
    /// (a session's repeats, a serve fleet, ...). `dropped0` is the ring
    /// overwrite counter at the start of the window.
    pub fn capture(
        phases0: &obs::PhaseTotals,
        exec0: &obs::ExecCounters,
        dropped0: u64,
    ) -> SessionTelemetry {
        SessionTelemetry {
            phases: obs::phase_totals()
                .delta_since(phases0)
                .nonzero()
                .into_iter()
                .map(|(k, s)| (k.name().to_string(), s))
                .collect(),
            exec: obs::exec_counters().delta_since(exec0),
            calibration: CalibrationStats::default(),
            dropped_events: obs::dropped().saturating_sub(dropped0),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
            && self.exec == obs::ExecCounters::default()
            && self.calibration.is_empty()
            && self.dropped_events == 0
    }

    /// JSON block for the session report (`Registry::record`).
    pub fn to_json(&self) -> Json {
        let mut phases = Json::obj();
        for (name, s) in &self.phases {
            let mut row = Json::obj();
            row.set("count", json::num(s.count as f64));
            row.set("total_ms", json::num(s.total_ns as f64 / 1e6));
            phases.set(name, row);
        }
        let mut exec = Json::obj();
        exec.set("own_pops", json::num(self.exec.own_pops as f64));
        exec.set("steals", json::num(self.exec.steals as f64));
        exec.set("help_steals", json::num(self.exec.help_steals as f64));
        exec.set("idle_wakeups", json::num(self.exec.idle_wakeups as f64));
        exec.set("queue_hwm", json::num(self.exec.queue_hwm as f64));
        let mut doc = Json::obj();
        doc.set("phases", phases);
        doc.set("executor", exec);
        doc.set("calibration", self.calibration.to_json());
        doc.set("dropped_events", json::num(self.dropped_events as f64));
        doc
    }

    /// Human block for `rcc tune` / `rcc serve --tune` summaries.
    pub fn render(&self) -> String {
        let mut out = String::from("telemetry:\n");
        if self.phases.is_empty() {
            out.push_str("  (no phase spans; enable with --trace or RCC_TRACE)\n");
        }
        for (name, s) in &self.phases {
            out.push_str(&format!(
                "  {:<12} {:>7} x {:>10.3} ms\n",
                name,
                s.count,
                s.total_ns as f64 / 1e6
            ));
        }
        out.push_str(&format!("  {}\n", self.exec.render_line()));
        if !self.calibration.is_empty() {
            out.push_str(&format!("  {}\n", self.calibration.render_line()));
        }
        if self.dropped_events > 0 {
            out.push_str(&format!(
                "  warning: {} trace event(s) lost to ring overwrites\n",
                self.dropped_events
            ));
        }
        out
    }
}

/// Outcome of a repeated tuning session on one (workload, platform).
#[derive(Debug, Clone)]
pub struct SessionResult {
    pub config_strategy: Strategy,
    pub workload: String,
    pub platform: String,
    pub runs: Vec<SearchResult>,
    /// Aggregated LLM accounting over the repeats (llm_mcts only).
    pub llm_costs: CostTracker,
    pub llm_fallback_rate: f64,
    /// Repeats replayed verbatim from a `--resume` journal instead of
    /// being re-run (0 for a fresh session).
    pub resumed_repeats: usize,
    /// Observability counters scoped to this session.
    pub telemetry: SessionTelemetry,
}

impl SessionResult {
    /// Mean best speedup across repeats.
    pub fn mean_speedup(&self) -> f64 {
        stats::mean(&self.runs.iter().map(|r| r.best_speedup()).collect::<Vec<_>>())
    }

    /// Mean best speedup within the first `samples` measurements.
    pub fn mean_speedup_at(&self, samples: usize) -> f64 {
        stats::mean(
            &self
                .runs
                .iter()
                .map(|r| r.speedup_at(samples))
                .collect::<Vec<_>>(),
        )
    }

    /// Mean samples needed to reach `target` speedup (runs that never reach
    /// it count as their full budget).
    pub fn mean_samples_to(&self, target: f64) -> f64 {
        stats::mean(
            &self
                .runs
                .iter()
                .map(|r| r.samples_to_reach(target).unwrap_or(r.samples_used) as f64)
                .collect::<Vec<_>>(),
        )
    }

    /// Total measurement-cache hits across repeats (0 without a database).
    pub fn total_cache_hits(&self) -> usize {
        self.runs.iter().map(|r| r.cache_hits).sum()
    }

    /// Total hardware samples consumed across repeats.
    pub fn total_samples(&self) -> usize {
        self.runs.iter().map(|r| r.samples_used).sum()
    }

    /// Total quarantined hardware measurements across repeats (samples
    /// spent on failures; always 0 without an armed fault plan).
    pub fn total_failed_measurements(&self) -> usize {
        self.runs.iter().map(|r| r.failed_measurements).sum()
    }
}

pub(super) fn platform_for(cfg: &TuneConfig) -> Result<Platform> {
    Platform::by_name(&cfg.platform)
        .ok_or_else(|| anyhow!("unknown platform {:?} (see `rcc platforms`)", cfg.platform))
}

fn mcts_cfg_for(cfg: &TuneConfig) -> MctsConfig {
    MctsConfig {
        exploration_c: cfg.exploration_c,
        branching: cfg.branching,
        rollout_len: cfg.rollout_len,
        history_depth: cfg.history_depth,
        max_trace_len: cfg.max_trace_len,
    }
}

/// Run one strategy once on a prebuilt program.
pub fn run_once(program: &Program, cfg: &TuneConfig, seed: u64) -> Result<SearchResult> {
    run_once_warm(program, cfg, seed, None)
}

/// [`run_once`] with database hints: the search is warm-started from
/// `hints.warm` and evaluates through a clone of `hints.cache`. Spins up
/// a private executor of `cfg.resolved_workers()` for this one run;
/// sessions instead thread one shared executor through every repeat.
pub fn run_once_warm(
    program: &Program,
    cfg: &TuneConfig,
    seed: u64,
    hints: Option<&SearchHints>,
) -> Result<SearchResult> {
    let exec = Executor::new(cfg.resolved_workers());
    Ok(run_once_with_accounting(program, cfg, seed, hints, &AnalysisCache::new(), &exec)?.0)
}

/// Run one strategy once, returning LLM accounting when applicable. All
/// strategies dispatch through the [`SearchStrategy`] trait; the run's
/// batched evaluation streams onto `exec` (shared session-wide, so nested
/// parallel sites split one core budget) and `cfg.eval_batch` flows into
/// the [`SearchContext`] driving the leaf-parallel trajectory.
///
/// `analysis` is the session-wide access-analysis memoization: the
/// surrogate, the hardware model and (for llm_mcts) the reasoning engine
/// all share it, so one distinct stage structure is analyzed once per
/// session — across the 20-repeat protocol and every feature extraction.
/// Sharing is invisible to results: cached analyses are pure values, so
/// every run stays bit-identical to an uncached one (unlike the
/// measurement cache, which each run deliberately clones).
fn run_once_with_accounting(
    program: &Program,
    cfg: &TuneConfig,
    seed: u64,
    hints: Option<&SearchHints>,
    analysis: &AnalysisCache,
    exec: &Arc<Executor>,
) -> Result<(SearchResult, CostTracker, f64, u64)> {
    let platform = platform_for(cfg)?;
    let surrogate = SurrogateModel::with_analysis(platform.clone(), analysis.share());
    let hardware = HardwareModel::with_analysis(platform.clone(), analysis.share());
    let mcts_cfg = mcts_cfg_for(cfg);
    let mut ctx =
        SearchContext::new(program, &surrogate, &hardware, &platform, cfg.budget, seed);
    ctx.warm = hints.map(|h| &h.warm).filter(|w| !w.is_empty());
    ctx.cache = hints.map(|h| &h.cache);
    ctx.shared_cache = cfg.share_repeat_cache;
    ctx.executor = Arc::clone(exec);
    ctx.eval_batch = cfg.resolved_eval_batch();
    let result = match cfg.strategy {
        Strategy::Evolutionary => {
            let r = EvolutionaryStrategy::new(EvoConfig::default()).search(&ctx);
            (r, CostTracker::default(), 0.0, 0)
        }
        Strategy::Mcts => {
            let mut policy = RandomPolicy::new(seed);
            let r = MctsStrategy::new(mcts_cfg, &mut policy).search(&ctx);
            (r, CostTracker::default(), 0.0, 0)
        }
        Strategy::LlmMcts => {
            let model = ModelProfile::by_name(&cfg.model)
                .ok_or_else(|| anyhow!("unknown model {:?} (see `rcc models`)", cfg.model))?;
            let engine = SimulatedLlm::new(model, seed).with_analysis(analysis.share());
            let mut policy = LlmPolicy::new(engine, cfg.history_depth, seed)
                .with_exemplars(hints.map(|h| h.exemplars.clone()).unwrap_or_default());
            let r = MctsStrategy::new(mcts_cfg, &mut policy).search(&ctx);
            let fb = policy.fallbacks.fallback_rate();
            let expansions = policy.fallbacks.fallbacks;
            (r, policy.costs, fb, expansions)
        }
    };
    Ok(result)
}

/// Repeat a tuning run over `cfg.repeats` seeds (in parallel) and aggregate
/// — the paper's statistical protocol.
pub fn run_session(cfg: &TuneConfig) -> Result<SessionResult> {
    let workload = WorkloadId::from_name(&cfg.workload)
        .ok_or_else(|| anyhow!("unknown workload {:?} (see `rcc show`)", cfg.workload))?;
    let program = workload.build();
    run_session_on(&program, cfg)
}

/// Same as [`run_session`] but over an arbitrary program (used by e2e).
/// Owns a session executor of `cfg.resolved_workers()`.
pub fn run_session_on(program: &Program, cfg: &TuneConfig) -> Result<SessionResult> {
    let exec = Executor::new(cfg.resolved_workers());
    run_session_on_with(program, cfg, &exec, None)
}

/// The session core: repeats run as a task group on the caller's
/// persistent `exec`, and each repeat's inner batched-evaluation fan-out
/// streams onto the *same* executor — nesting shares one core budget
/// instead of multiplying pools.
///
/// When `cfg.db_path` is set, the session opens the tuning database,
/// derives warm-start hints for this program's structural fingerprint, runs
/// every repeat against them, then records each run's best trace and
/// commits — the open → search → commit lifecycle that makes measurements
/// durable across processes.
///
/// `pool` is the `rcc serve --tune` cross-session measurement pool: when
/// set, the session's database hints are spliced into it (keep-best), the
/// session evaluates through *shared* handles on it, and its measurements
/// become visible to every concurrently tuned model — so one program
/// fingerprint is never measured twice in a serve session. Pooling implies
/// `share_repeat_cache` semantics (repeats run serially in seed order;
/// order-dependent sharing stays deterministic).
pub fn run_session_on_with(
    program: &Program,
    cfg: &TuneConfig,
    exec: &Arc<Executor>,
    pool: Option<&MeasureCache>,
) -> Result<SessionResult> {
    // Validate the platform up front so every repeat fails the same way.
    platform_for(cfg)?;
    // ---- crash-safe journaling / resume --------------------------------
    // The serve fleet shares one measurement pool across many sessions; a
    // single journal path cannot describe that, so refuse loudly instead
    // of corrupting checkpoints.
    if pool.is_some() && (cfg.journal_path.is_some() || cfg.resume_from.is_some()) {
        return Err(anyhow!(
            "--journal/--resume are per-session and not supported with the serve fleet"
        ));
    }
    let header = JournalHeader {
        workload_fp: workload_fingerprint(program),
        workload: program.name.clone(),
        platform: cfg.platform.clone(),
        strategy: cfg.strategy.name().to_string(),
        model: cfg.model.clone(),
        seed: cfg.seed,
        budget: cfg.budget,
        repeats: cfg.repeats,
        eval_batch: cfg.resolved_eval_batch(),
        share_repeat_cache: cfg.share_repeat_cache,
    };
    // Resume loads + validates the old journal and keeps appending to it;
    // a fresh `--journal` atomically replaces whatever was at the path.
    let mut replayed: HashMap<usize, JournalEntry> = HashMap::new();
    let journal: Option<SessionJournal> = if let Some(rp) = &cfg.resume_from {
        let path = Path::new(rp);
        let (jh, entries) = SessionJournal::load(path)?;
        jh.ensure_matches(&header).with_context(|| format!("--resume {rp}"))?;
        for e in entries {
            if e.repeat < cfg.repeats {
                replayed.insert(e.repeat, e);
            }
        }
        Some(SessionJournal::open(path))
    } else if let Some(jp) = &cfg.journal_path {
        Some(SessionJournal::create(Path::new(jp), &header)?)
    } else {
        None
    };
    // Telemetry baseline: the session reports its own share of the
    // process-wide counters (read-only snapshots; never affects results).
    let phases0 = obs::phase_totals();
    let exec0 = obs::exec_counters();
    let dropped0 = obs::dropped();
    // Audit header: one `session` record delimits this session's slice of
    // the decision log (`rcc explain` reconstructs from the last slice).
    if obs::audit::armed() {
        let mut r = obs::audit::record("session", cfg.seed);
        r.set("workload", json::s(&program.name))
            .set("platform", json::s(&cfg.platform))
            .set("strategy", json::s(cfg.strategy.name()))
            .set("budget", json::num(cfg.budget as f64))
            .set("repeats", json::num(cfg.repeats as f64))
            .set("shape_class", json::s(&format!("{:016x}", crate::db::shape_class(program))));
        obs::audit::emit(r);
    }
    let mut db = match &cfg.db_path {
        Some(p) => Some(Database::open(Path::new(p))?),
        None => None,
    };
    // Attach the ANN transfer index before hint derivation so similarity
    // retrieval goes sublinear on large databases. Below the threshold
    // retrieval stays on the exact scan, so small sessions are
    // bit-identical with the index attached or not.
    if cfg.transfer && cfg.transfer_index && (cfg.warm_start || cfg.strategy == Strategy::LlmMcts)
    {
        if let Some(d) = db.as_mut() {
            d.attach_transfer_index(cfg.transfer_index_threshold);
        }
    }
    let hints = db.as_ref().map(|db| {
        let (warm, cache) = db.hints(program, &cfg.platform, cfg.warm_top_k);
        let mut hints = SearchHints {
            warm: if cfg.warm_start { warm } else { WarmStart::default() },
            cache,
            exemplars: Vec::new(),
        };
        // Cross-workload transfer: rebased traces from structurally similar
        // workloads extend the warm frontier (appended after the exact
        // records — those carry real measurements of *this* program), and
        // exemplars flow to the LLM policy. Recorded latencies of other
        // shapes are never planted in the measurement cache: a transferred
        // candidate is measured like any other, it just exists earlier.
        // Skip the whole derivation when nothing would consume it: warm
        // entries are gated on `warm_start` and only the LLM strategy
        // reads exemplars.
        if cfg.transfer && (cfg.warm_start || cfg.strategy == Strategy::LlmMcts) {
            let t = transfer::derive_hints(db, program, &cfg.platform, cfg.transfer_top_k);
            if cfg.warm_start {
                hints.warm.entries.extend(t.warm_entries);
            }
            hints.exemplars = t.exemplars;
        }
        hints
    });
    // Splice the serve-fleet measurement pool in: database hints flow into
    // the pool (keep-best, so merge order cannot matter) and the session
    // evaluates through shared handles on it. `--share-repeat-cache`
    // without a database still needs a session-lived cache for the repeats
    // to share; hand them an empty one (no warm traces, no exemplars —
    // just the pooled measurements).
    let pooled = pool.is_some();
    let hints = match (hints, pool) {
        (Some(mut h), Some(p)) => {
            h.cache.merge_into(p);
            h.cache = p.share();
            Some(h)
        }
        (None, Some(p)) => {
            Some(SearchHints { cache: p.share(), ..SearchHints::default() })
        }
        (None, None) if cfg.share_repeat_cache => Some(SearchHints::default()),
        (h, None) => h,
    };

    let seeds: Vec<u64> = (0..cfg.repeats as u64).map(|i| cfg.seed + i * 1009).collect();

    let mut run_cfg = cfg.clone();
    // Resolve `eval_batch` against the configured worker count up front so
    // the leaf-parallel trajectory never depends on scheduling.
    run_cfg.eval_batch = cfg.resolved_eval_batch();
    // Pooled sessions evaluate through shared cache handles — the same
    // order-dependent sharing `--share-repeat-cache` opts into.
    if pooled {
        run_cfg.share_repeat_cache = true;
    }
    // A shared cache (repeat-shared or serve-pooled) makes repeats
    // order-dependent (each may answer from whichever repeat measured a
    // program first), so the repeats must run serially, in seed order, to
    // stay deterministic run-to-run — the "workers never change results"
    // contract then still holds: the inner batched-evaluation fan-out
    // keeps the executor's full budget. Journaling and an armed crash
    // clock also force seed order: checkpoints mean "repeats 0..k are
    // durable" and a deterministic kill point needs a deterministic
    // repeat-in-flight — both wall-clock-only choices under that same
    // contract.
    let serial_repeats =
        run_cfg.share_repeat_cache || journal.is_some() || faults::crash_armed();
    let run_cfg = &run_cfg;
    let hints = hints.as_ref();
    // One analysis cache for the whole session: the repeats evaluate the
    // same workload, so they share every per-stage analysis (thread-safe,
    // and pure values — sharing cannot perturb per-seed determinism).
    let analysis = AnalysisCache::new();
    let analysis = &analysis;
    // Repeats run as one task group on the shared session executor. Each
    // repeat is an independent seeded run over a private clone of the
    // hints cache, and the group folds results by seed index, so the
    // executor width never affects results — a serial executor runs the
    // repeats strictly serially, inline. A repeat's own batched
    // evaluation submits nested groups to the same executor (waiting
    // submitters help), so repeats × eval_batch never oversubscribes.
    let shared_cache = run_cfg.share_repeat_cache;
    let mut resumed_repeats = 0usize;
    let outcomes: Vec<Result<(SearchResult, CostTracker, f64, u64)>> = if serial_repeats {
        let mut outcomes = Vec::with_capacity(seeds.len());
        for (i, &seed) in seeds.iter().enumerate() {
            // A journaled repeat replays verbatim — bit-identical by
            // construction — re-applying its cache delta so later repeats
            // observe exactly the cache state of the uninterrupted run.
            if let Some(e) = replayed.remove(&i) {
                if let Some(h) = hints.filter(|_| shared_cache) {
                    for (plat, fp, lat) in &e.cache_delta {
                        h.cache.insert(*fp, plat, *lat);
                    }
                }
                resumed_repeats += 1;
                outcomes.push(Ok((e.result, e.costs, e.fb_rate, e.expansions)));
                continue;
            }
            let cache_before = match (&journal, hints) {
                (Some(_), Some(h)) if shared_cache => Some(h.cache.entries()),
                _ => None,
            };
            let out = run_once_with_accounting(program, run_cfg, seed, hints, analysis, exec);
            // An armed crash clock models a mid-session kill: the repeat
            // in flight when the clock expired is *discarded* (a real kill
            // loses it mid-write) and the session aborts before the
            // database commit. `--resume` re-runs it from its fixed seed.
            if faults::crash_due() {
                return Err(anyhow!(
                    "injected crash: fault plan expired after {} measurement steps (repeat {i} discarded{})",
                    faults::steps(),
                    if journal.is_some() { "; restart with --resume" } else { "" },
                ));
            }
            if let (Some(j), Ok(o)) = (&journal, &out) {
                let cache_delta = match cache_before {
                    Some(before) => diff_cache_entries(
                        &before,
                        hints.map(|h| h.cache.entries()).unwrap_or_default(),
                    ),
                    None => Vec::new(),
                };
                j.append(&JournalEntry {
                    repeat: i,
                    seed,
                    result: o.0.clone(),
                    costs: o.1.clone(),
                    fb_rate: o.2,
                    expansions: o.3,
                    cache_delta,
                })?;
            }
            outcomes.push(out);
        }
        outcomes
    } else {
        exec.run(
            seeds
                .iter()
                .map(|&seed| {
                    move || run_once_with_accounting(program, run_cfg, seed, hints, analysis, exec)
                })
                .collect(),
        )
    };

    let mut runs = Vec::new();
    let mut llm_costs = CostTracker::default();
    let mut fb_rates = Vec::new();
    for o in outcomes {
        let o = o?;
        runs.push(o.0);
        llm_costs.merge(&o.1);
        fb_rates.push(o.2);
    }

    // Audit: one `result` record per repeat, emitted in seed order on the
    // coordinating thread (never from the fan-out workers). The sample-
    // efficiency curve rides along so `rcc explain` can plot convergence
    // from the decision log alone.
    if obs::audit::armed() {
        for (run, &seed) in runs.iter().zip(&seeds) {
            let mut r = obs::audit::record("result", seed);
            r.set("baseline", json::num(run.baseline_latency))
                .set("best_latency", json::num(run.best_latency))
                .set("samples", json::num(run.samples_used as f64))
                .set("failed", json::num(run.failed_measurements as f64));
            let curve: Vec<Json> = run
                .curve
                .iter()
                .map(|m| {
                    let mut p = Json::obj();
                    p.set("sample", json::num(m.sample as f64));
                    p.set("latency", json::num(m.latency));
                    p
                })
                .collect();
            r.set("curve", json::arr(curve));
            obs::audit::emit(r);
        }
    }

    // Persist each repeat's best discovery and flush. Records carry the
    // transfer metadata (shape class + per-stage extents) that lets future
    // sessions on structurally similar workloads find and rebase them.
    if let Some(db) = &mut db {
        let fp = workload_fingerprint(program);
        let class = crate::db::shape_class(program);
        let extents = transfer::workload_extents(program);
        let timestamp = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        for (run, &seed) in runs.iter().zip(&seeds) {
            if run.best_trace.is_empty() {
                continue; // nothing beat the baseline; no record to keep
            }
            // A warm run that only re-confirms a recorded result adds no
            // information; skip the append so the log doesn't grow with
            // duplicates on every converged re-run.
            if db.has_equivalent(fp, &cfg.platform, &run.best_trace, run.best_latency) {
                continue;
            }
            db.add(TuningRecord {
                workload_fp: fp,
                workload: program.name.clone(),
                platform: cfg.platform.clone(),
                strategy: run.strategy.clone(),
                trace: run.best_trace.clone(),
                latency: run.best_latency,
                baseline_latency: run.baseline_latency,
                seed,
                timestamp,
                shape_class: class,
                extents: extents.clone(),
            });
        }
        db.commit()
            .with_context(|| format!("committing tuning records for {}", program.name))?;
    }

    let mut telemetry = SessionTelemetry::capture(&phases0, &exec0, dropped0);
    for r in &runs {
        telemetry.calibration.merge(&r.calibration);
    }
    Ok(SessionResult {
        config_strategy: cfg.strategy,
        workload: cfg.workload.clone(),
        platform: cfg.platform.clone(),
        runs,
        llm_costs,
        llm_fallback_rate: stats::mean(&fb_rates),
        resumed_repeats,
        telemetry,
    })
}

/// Entries present in `after` but not `before` (or with a changed value):
/// the measurements one repeat contributed to the session-shared cache.
/// Both snapshots come sorted from [`MeasureCache::entries`], so the delta
/// is deterministic.
fn diff_cache_entries(
    before: &[(String, u64, f64)],
    after: Vec<(String, u64, f64)>,
) -> Vec<(String, u64, f64)> {
    let prev: HashMap<(&str, u64), f64> =
        before.iter().map(|(p, fp, l)| ((p.as_str(), *fp), *l)).collect();
    after
        .into_iter()
        .filter(|(p, fp, l)| {
            prev.get(&(p.as_str(), *fp)).map_or(true, |old| old.to_bits() != l.to_bits())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            strategy,
            budget: 30,
            repeats: 2,
            ..Default::default()
        }
    }

    #[test]
    fn session_aggregates_repeats() {
        let s = run_session(&quick_cfg(Strategy::Mcts)).unwrap();
        assert_eq!(s.runs.len(), 2);
        assert!(s.mean_speedup() > 1.0);
        assert!(s.mean_speedup_at(30) >= s.mean_speedup_at(5));
    }

    #[test]
    fn llm_session_tracks_costs() {
        let s = run_session(&quick_cfg(Strategy::LlmMcts)).unwrap();
        assert!(s.llm_costs.calls > 0);
        assert!(s.llm_costs.prompt_tokens > 0);
        assert_eq!(s.llm_fallback_rate, 0.0); // gpt4o_mini never falls back
    }

    #[test]
    fn session_telemetry_aggregates_calibration() {
        // Calibration is always-on: every measured sample pairs a surrogate
        // prediction with the hardware latency, and the session telemetry
        // merges per-run summaries exactly.
        let s = run_session(&quick_cfg(Strategy::Mcts)).unwrap();
        assert!(s.telemetry.calibration.n > 0, "no calibration pairs recorded");
        let mut merged = CalibrationStats::default();
        for r in &s.runs {
            merged.merge(&r.calibration);
        }
        assert_eq!(merged, s.telemetry.calibration);
        assert!(s.telemetry.calibration.mean_abs_rel().is_finite());
        let e = run_session(&quick_cfg(Strategy::Evolutionary)).unwrap();
        assert!(e.telemetry.calibration.n > 0, "ES records calibration too");
    }

    #[test]
    fn es_session_runs() {
        let s = run_session(&quick_cfg(Strategy::Evolutionary)).unwrap();
        assert!(s.mean_speedup() > 1.0);
        assert_eq!(s.llm_costs.calls, 0);
    }

    #[test]
    fn unknown_platform_is_an_error_not_a_panic() {
        let cfg = TuneConfig {
            platform: "quantum_abacus".to_string(),
            ..quick_cfg(Strategy::Mcts)
        };
        let err = run_session(&cfg).unwrap_err();
        assert!(err.to_string().contains("quantum_abacus"), "{err}");
        let program = WorkloadId::DeepSeekMoe.build_test();
        assert!(run_once(&program, &cfg, 1).is_err());
    }

    #[test]
    fn unknown_workload_and_model_are_errors() {
        let cfg = TuneConfig {
            workload: "nope".to_string(),
            ..quick_cfg(Strategy::Mcts)
        };
        assert!(run_session(&cfg).is_err());
        let cfg = TuneConfig {
            model: "gpt9".to_string(),
            ..quick_cfg(Strategy::LlmMcts)
        };
        assert!(run_session(&cfg).is_err());
    }

    #[test]
    fn sessions_deterministic() {
        let a = run_session(&quick_cfg(Strategy::Mcts)).unwrap();
        let b = run_session(&quick_cfg(Strategy::Mcts)).unwrap();
        assert_eq!(
            a.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
            b.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>()
        );
    }

    #[test]
    fn shared_repeat_cache_sessions_stay_deterministic() {
        // Sharing the measurement cache across repeats forces the repeat
        // pool serial (sharing is order-dependent); with that, two
        // identical sessions — even with a wide worker budget for the
        // inner evaluation fan-out — must produce identical results.
        let mk_db = |tag: &str| {
            std::env::temp_dir().join(format!(
                "rcc_shared_cache_{tag}_{}_{}.jsonl",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            ))
        };
        let run = |db: &std::path::PathBuf| {
            let cfg = TuneConfig {
                strategy: Strategy::Mcts,
                budget: 25,
                repeats: 2,
                workers: 4,
                share_repeat_cache: true,
                db_path: Some(db.to_string_lossy().to_string()),
                ..Default::default()
            };
            run_session(&cfg).unwrap()
        };
        // Fresh databases for both sessions so neither warm-starts.
        let (da, db_) = (mk_db("a"), mk_db("b"));
        let a = run(&da);
        let b = run(&db_);
        assert_eq!(
            a.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
            b.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>()
        );
        assert_eq!(
            a.runs.iter().map(|r| r.samples_used).collect::<Vec<_>>(),
            b.runs.iter().map(|r| r.samples_used).collect::<Vec<_>>()
        );
        std::fs::remove_file(&da).ok();
        std::fs::remove_file(&db_).ok();
    }

    fn temp_journal(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "rcc_session_journal_{tag}_{}_{}.jsonl",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ))
    }

    fn result_key(r: &SearchResult) -> (u64, usize, Vec<(usize, u64)>) {
        (
            r.best_latency.to_bits(),
            r.samples_used,
            r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect(),
        )
    }

    #[test]
    fn journaled_session_resumes_bit_identically() {
        let jp = temp_journal("full");
        let mut cfg = quick_cfg(Strategy::Mcts);
        cfg.journal_path = Some(jp.to_string_lossy().to_string());
        let a = run_session(&cfg).unwrap();
        assert_eq!(a.resumed_repeats, 0);
        let (h, entries) = SessionJournal::load(&jp).unwrap();
        assert_eq!(h.repeats, 2);
        assert_eq!(entries.len(), 2, "every repeat checkpointed");

        // Resuming a complete journal replays everything, runs nothing,
        // and reproduces the session bit-for-bit.
        let mut rcfg = cfg.clone();
        rcfg.journal_path = None;
        rcfg.resume_from = Some(jp.to_string_lossy().to_string());
        let b = run_session(&rcfg).unwrap();
        assert_eq!(b.resumed_repeats, 2);
        assert_eq!(
            a.runs.iter().map(result_key).collect::<Vec<_>>(),
            b.runs.iter().map(result_key).collect::<Vec<_>>()
        );

        // Mismatched parameters refuse to resume, naming the field.
        let mut bad = rcfg.clone();
        bad.budget += 1;
        let err = run_session(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("budget"), "{err:#}");
        std::fs::remove_file(&jp).ok();
    }

    #[test]
    fn truncated_journal_resume_re_runs_missing_repeats() {
        // An uninterrupted journaled session, then simulate a kill by
        // truncating the journal to header + repeat 0 + a torn tail line.
        let jp = temp_journal("truncated");
        let mut cfg = quick_cfg(Strategy::Mcts);
        cfg.journal_path = Some(jp.to_string_lossy().to_string());
        let full = run_session(&cfg).unwrap();
        let text = std::fs::read_to_string(&jp).unwrap();
        let keep: Vec<&str> = text.lines().take(2).collect();
        std::fs::write(&jp, format!("{}\n{{\"repeat\":1,\"se", keep.join("\n"))).unwrap();

        let mut rcfg = cfg.clone();
        rcfg.journal_path = None;
        rcfg.resume_from = Some(jp.to_string_lossy().to_string());
        let resumed = run_session(&rcfg).unwrap();
        assert_eq!(resumed.resumed_repeats, 1, "repeat 0 replays, repeat 1 re-runs");
        assert_eq!(
            full.runs.iter().map(result_key).collect::<Vec<_>>(),
            resumed.runs.iter().map(result_key).collect::<Vec<_>>(),
            "resume after a torn journal is bit-identical to the uninterrupted run"
        );
        // The re-run repeat was re-checkpointed into the same journal.
        let (_, entries) = SessionJournal::load(&jp).unwrap();
        assert_eq!(entries.len(), 2);
        std::fs::remove_file(&jp).ok();
    }

    #[test]
    fn session_with_db_persists_and_warm_starts() {
        let db_path = std::env::temp_dir().join(format!(
            "rcc_tuner_db_{}_{}.jsonl",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let cfg = TuneConfig {
            db_path: Some(db_path.to_string_lossy().to_string()),
            ..quick_cfg(Strategy::Mcts)
        };
        let cold = run_session(&cfg).unwrap();
        assert_eq!(cold.total_cache_hits(), 0, "cold run has nothing to hit");
        let db = Database::open(&db_path).unwrap();
        assert!(
            (1..=2).contains(&db.len()),
            "one record per repeat (minus same-trace dedup), got {}",
            db.len()
        );

        let warm = run_session(&cfg).unwrap();
        assert!(
            warm.total_cache_hits() > 0,
            "warm run must reuse recorded measurements"
        );
        std::fs::remove_file(&db_path).ok();
    }
}
