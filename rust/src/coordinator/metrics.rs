//! Serving metrics: per-artifact latency/throughput summaries.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::stats::{percentile, Summary};

/// Rolling metrics for one served model (artifact).
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batch_latency: Summary,
    /// Per-request end-to-end latencies (seconds), kept for percentiles.
    pub request_latencies: Vec<f64>,
}

impl ModelMetrics {
    pub fn record_batch(&mut self, batch_size: usize, exec_latency_s: f64, request_waits: &[f64]) {
        self.requests += batch_size as u64;
        self.batches += 1;
        self.batch_latency.record(exec_latency_s);
        for &w in request_waits {
            self.request_latencies.push(w + exec_latency_s);
        }
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.request_latencies, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.request_latencies, 99.0)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Registry of metrics across served models + wall-clock throughput.
#[derive(Debug)]
pub struct ServerMetrics {
    pub per_model: BTreeMap<String, ModelMetrics>,
    started: Instant,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics { per_model: BTreeMap::new(), started: Instant::now() }
    }
}

impl ServerMetrics {
    pub fn model(&mut self, name: &str) -> &mut ModelMetrics {
        self.per_model.entry(name.to_string()).or_default()
    }

    pub fn total_requests(&self) -> u64 {
        self.per_model.values().map(|m| m.requests).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed <= 0.0 {
            0.0
        } else {
            self.total_requests() as f64 / elapsed
        }
    }

    /// Render the serving report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<20} {:>8} {:>8} {:>10} {:>10} {:>10}\n",
            "model", "reqs", "batches", "mean batch", "p50 ms", "p99 ms"
        ));
        for (name, m) in &self.per_model {
            out.push_str(&format!(
                "{:<20} {:>8} {:>8} {:>10.2} {:>10.3} {:>10.3}\n",
                name,
                m.requests,
                m.batches,
                m.mean_batch_size(),
                m.p50() * 1e3,
                m.p99() * 1e3
            ));
        }
        out.push_str(&format!(
            "total: {} requests, {:.1} req/s\n",
            self.total_requests(),
            self.throughput_rps()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServerMetrics::default();
        m.model("moe").record_batch(4, 0.002, &[0.0, 0.001, 0.0005, 0.0]);
        m.model("moe").record_batch(2, 0.001, &[0.0, 0.0]);
        let mm = &m.per_model["moe"];
        assert_eq!(mm.requests, 6);
        assert_eq!(mm.batches, 2);
        assert_eq!(mm.mean_batch_size(), 3.0);
        assert!(mm.p99() >= mm.p50());
        let report = m.report();
        assert!(report.contains("moe"));
        assert!(report.contains("total: 6 requests"));
    }
}
