//! Serving metrics: per-artifact latency/throughput summaries.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::rng::Pcg;
use crate::util::stats::{percentile, Summary};

/// Capacity of [`LatencyReservoir`]: enough samples for a stable p99
/// (~10 expected tail samples) at constant memory.
pub const LATENCY_RESERVOIR_CAP: usize = 1024;

/// Bounded uniform sample of per-request latencies (Vitter's Algorithm R).
/// A serving loop runs indefinitely, so keeping every latency would grow
/// without bound; a reservoir keeps memory at O(cap) while percentiles
/// stay estimates over the *full* history, not a recent window. The
/// replacement RNG is seeded at construction, so the sample — and the
/// reported p50/p99 — is deterministic for a given latency sequence.
#[derive(Debug, Clone)]
pub struct LatencyReservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Pcg,
}

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir { samples: Vec::new(), seen: 0, rng: Pcg::new(0x5EED_1A7E) }
    }
}

impl LatencyReservoir {
    pub fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.gen_range(self.seen as usize);
            if j < LATENCY_RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }

    /// Total latencies ever recorded (≥ the retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The retained sample, unsorted (`percentile` sorts a copy).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Rolling metrics for one served model (artifact).
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batch_latency: Summary,
    /// Per-request end-to-end latencies (seconds), reservoir-sampled for
    /// percentiles at bounded memory. Under the simulated backend these
    /// are *virtual* (tick-clock) latencies — deterministic per load
    /// seed; wall-clock latencies live in `wall_latencies`.
    pub request_latencies: LatencyReservoir,
    /// Wall-clock per-request latencies (seconds). Never part of any
    /// determinism contract — benches read these for real throughput.
    pub wall_latencies: LatencyReservoir,
    /// Requests accepted by admission control.
    pub admitted: u64,
    /// Requests refused at the admission gate (`ServeError::Overloaded`).
    pub rejected: u64,
    /// Queued requests dropped for exceeding the queue-delay deadline.
    pub evicted: u64,
    /// Dispatches forced by the max-wait tick before `min_fill` was
    /// reached (the drain fix: tail requests no longer wait for `drain()`).
    pub partial_dispatches: u64,
    /// Deepest this model's ingress queue ever got (at admission time).
    pub queue_hwm: u64,
}

impl ModelMetrics {
    pub fn record_batch(&mut self, batch_size: usize, exec_latency_s: f64, request_waits: &[f64]) {
        self.requests += batch_size as u64;
        self.batches += 1;
        self.batch_latency.record(exec_latency_s);
        for &w in request_waits {
            self.request_latencies.record(w + exec_latency_s);
        }
    }

    /// A request passed the admission gate with `depth` requests now queued.
    pub fn record_admit(&mut self, depth: usize) {
        self.admitted += 1;
        self.queue_hwm = self.queue_hwm.max(depth as u64);
    }

    /// A request was refused at the admission gate.
    pub fn record_reject(&mut self) {
        self.rejected += 1;
    }

    /// A queued request was dropped for exceeding its queue-delay deadline.
    pub fn record_evict(&mut self) {
        self.evicted += 1;
    }

    /// One scheduling tick started `started` of this model's requests
    /// (continuous batching: requests, not fixed batches). `partial` marks
    /// a max-wait forced flush below the configured fill.
    pub fn record_dispatch(&mut self, started: usize, exec_latency_s: f64, partial: bool) {
        self.requests += started as u64;
        self.batches += 1;
        self.batch_latency.record(exec_latency_s);
        if partial {
            self.partial_dispatches += 1;
        }
    }

    /// A request finished: `virt_latency_s` is its deterministic
    /// tick-clock enqueue→completion latency, `wall_latency_s` the
    /// wall-clock one.
    pub fn record_completion(&mut self, virt_latency_s: f64, wall_latency_s: f64) {
        self.request_latencies.record(virt_latency_s);
        self.wall_latencies.record(wall_latency_s);
    }

    pub fn p50(&self) -> f64 {
        percentile(self.request_latencies.samples(), 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(self.request_latencies.samples(), 99.0)
    }

    /// Wall-clock p99 (benches only; not deterministic).
    pub fn wall_p99(&self) -> f64 {
        percentile(self.wall_latencies.samples(), 99.0)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Registry of metrics across served models + wall-clock throughput.
#[derive(Debug)]
pub struct ServerMetrics {
    pub per_model: BTreeMap<String, ModelMetrics>,
    started: Instant,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics { per_model: BTreeMap::new(), started: Instant::now() }
    }
}

impl ServerMetrics {
    pub fn model(&mut self, name: &str) -> &mut ModelMetrics {
        self.per_model.entry(name.to_string()).or_default()
    }

    pub fn total_requests(&self) -> u64 {
        self.per_model.values().map(|m| m.requests).sum()
    }

    pub fn total_admitted(&self) -> u64 {
        self.per_model.values().map(|m| m.admitted).sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.per_model.values().map(|m| m.rejected).sum()
    }

    pub fn total_evicted(&self) -> u64 {
        self.per_model.values().map(|m| m.evicted).sum()
    }

    pub fn total_partial_dispatches(&self) -> u64 {
        self.per_model.values().map(|m| m.partial_dispatches).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed <= 0.0 {
            0.0
        } else {
            self.total_requests() as f64 / elapsed
        }
    }

    /// Render the serving report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<20} {:>8} {:>8} {:>10} {:>10} {:>10} {:>8} {:>8} {:>8}\n",
            "model", "reqs", "batches", "mean batch", "p50 ms", "p99 ms", "admit", "reject", "evict"
        ));
        for (name, m) in &self.per_model {
            out.push_str(&format!(
                "{:<20} {:>8} {:>8} {:>10.2} {:>10.3} {:>10.3} {:>8} {:>8} {:>8}\n",
                name,
                m.requests,
                m.batches,
                m.mean_batch_size(),
                m.p50() * 1e3,
                m.p99() * 1e3,
                m.admitted,
                m.rejected,
                m.evicted
            ));
        }
        out.push_str(&format!(
            "admission: admitted={} rejected={} evicted={} partial_flushes={}\n",
            self.total_admitted(),
            self.total_rejected(),
            self.total_evicted(),
            self.total_partial_dispatches()
        ));
        out.push_str(&format!(
            "total: {} requests, {:.1} req/s\n",
            self.total_requests(),
            self.throughput_rps()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServerMetrics::default();
        m.model("moe").record_batch(4, 0.002, &[0.0, 0.001, 0.0005, 0.0]);
        m.model("moe").record_batch(2, 0.001, &[0.0, 0.0]);
        let mm = &m.per_model["moe"];
        assert_eq!(mm.requests, 6);
        assert_eq!(mm.batches, 2);
        assert_eq!(mm.mean_batch_size(), 3.0);
        assert!(mm.p99() >= mm.p50());
        let report = m.report();
        assert!(report.contains("moe"));
        assert!(report.contains("total: 6 requests"));
    }

    #[test]
    fn admission_counters_roll_up_into_the_report() {
        let mut m = ServerMetrics::default();
        m.model("moe").record_admit(3);
        m.model("moe").record_admit(5);
        m.model("moe").record_reject();
        m.model("moe").record_evict();
        m.model("moe").record_dispatch(2, 0.004, true);
        m.model("moe").record_completion(0.004, 0.0041);
        m.model("mlp").record_admit(1);
        m.model("mlp").record_dispatch(1, 0.002, false);
        let moe = &m.per_model["moe"];
        assert_eq!(moe.admitted, 2);
        assert_eq!(moe.rejected, 1);
        assert_eq!(moe.evicted, 1);
        assert_eq!(moe.partial_dispatches, 1);
        assert_eq!(moe.queue_hwm, 5);
        assert_eq!(moe.requests, 2);
        assert_eq!(moe.request_latencies.seen(), 1);
        assert_eq!(moe.wall_latencies.seen(), 1);
        assert_eq!(m.total_admitted(), 3);
        assert_eq!(m.total_rejected(), 1);
        let report = m.report();
        assert!(report.contains("admission: admitted=3 rejected=1 evicted=1 partial_flushes=1"));
        assert!(report.contains("total: 3 requests"));
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_percentiles_stable() {
        let mut r = LatencyReservoir::default();
        for i in 0..10_000 {
            r.record(i as f64 / 10_000.0); // uniform [0, 1)
        }
        assert_eq!(r.len(), LATENCY_RESERVOIR_CAP, "memory stays bounded");
        assert_eq!(r.seen(), 10_000);
        let p50 = percentile(r.samples(), 50.0);
        let p99 = percentile(r.samples(), 99.0);
        assert!((p50 - 0.5).abs() < 0.08, "p50 of uniform sample drifted: {p50}");
        assert!(p99 > 0.9, "p99 of uniform sample drifted: {p99}");

        // Fixed seed: the same latency sequence yields the same sample.
        let mut r2 = LatencyReservoir::default();
        for i in 0..10_000 {
            r2.record(i as f64 / 10_000.0);
        }
        assert_eq!(r.samples(), r2.samples());
    }
}
