//! Multi-model tuning drivers: the end-to-end multi-task session behind
//! the paper's Table 2 and the `rcc serve --tune` model fleet (one session
//! per distinct hosted workload, pooled measurements, shared executor).
//!
//! Single-session mechanics — strategy dispatch, repeats, journaling, the
//! database lifecycle — live in [`super::session`]; this module only
//! fans sessions out and aggregates.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::db::MeasureCache;
use crate::schedule::Schedule;
use crate::search::SearchResult;
use crate::tir::workload::{E2eTask, WorkloadId};
use crate::tir::Program;
use crate::util::executor::Executor;
use crate::util::stats;

use super::config::TuneConfig;
use super::session::{run_session_on, run_session_on_with, SessionResult};

/// End-to-end result: per-task sessions + the invocation-weighted speedup
/// (the Table-2 metric: total model latency before vs after tuning).
#[derive(Debug, Clone)]
pub struct E2eResult {
    pub tasks: Vec<(String, SessionResult)>,
    pub total_samples: usize,
    pub weighted_speedup: f64,
}

/// Tune every task of an end-to-end model and combine by invocation count.
pub fn run_e2e(tasks: &[E2eTask], cfg: &TuneConfig) -> Result<E2eResult> {
    let mut sessions = Vec::new();
    let mut base_total = 0.0;
    let mut opt_total = 0.0;
    let mut total_samples = 0;
    for task in tasks {
        let mut task_cfg = cfg.clone();
        // Budget splits across tasks proportional to... equal shares here;
        // the paper tunes each extracted task with the shared budget.
        task_cfg.budget = (cfg.budget / tasks.len()).max(10);
        let session = run_session_on(&task.program, &task_cfg)?;
        // Weighted latency: mean best latency per run x invocations.
        let base = stats::mean(
            &session.runs.iter().map(|r| r.baseline_latency).collect::<Vec<_>>(),
        );
        let best = stats::mean(
            &session.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
        );
        base_total += base * task.invocations as f64;
        opt_total += best * task.invocations as f64;
        total_samples += session.runs.iter().map(|r| r.samples_used).sum::<usize>()
            / session.runs.len().max(1);
        sessions.push((task.program.name.clone(), session));
    }
    Ok(E2eResult {
        tasks: sessions,
        total_samples,
        weighted_speedup: base_total / opt_total,
    })
}

/// Outcome of a [`tune_models`] fleet: per-model sessions plus the shared
/// measurement pool's accounting (the `rcc serve --tune` summary).
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// `(model, session)` pairs in input order. Models aliasing the same
    /// workload share one session (identical program fingerprints are
    /// tuned — and measured — exactly once per serve session).
    pub sessions: Vec<(String, SessionResult)>,
    /// Distinct (program fingerprint, platform) measurements in the shared
    /// pool after the fleet: database-seeded plus newly measured.
    pub pool_entries: usize,
    /// Candidate evaluations across all sessions answered by the shared
    /// pool (database warm entries or another repeat's/session's
    /// measurement) instead of spending a hardware sample.
    pub pooled_hits: usize,
}

/// Tune every registered model concurrently on a private executor of
/// `base_cfg.resolved_workers()` total parallelism. See
/// [`tune_models_on`] — the serving plane passes its own executor there so
/// background tuning shares (and yields) the serve cores instead of
/// spawning a second pool.
pub fn tune_models(models: &[String], base_cfg: &TuneConfig) -> Result<FleetResult> {
    let exec = Executor::new(base_cfg.resolved_workers());
    tune_models_on(models, base_cfg, &exec)
}

/// Tune every registered model concurrently — one session per *distinct*
/// workload, run as a task group on the caller's persistent `exec`. The
/// sessions' nested parallel sites (repeats, batched evaluation) submit to
/// the same executor, so the fleet never oversubscribes the machine the
/// way stacked per-site pools did. Fleet tasks run at the executor's
/// default (low) priority: when the serving plane shares the executor,
/// serve traffic dispatched at high priority preempts tuning at every
/// dequeue and steal site.
///
/// Cross-session measurement dedup: all sessions evaluate through one
/// shared [`MeasureCache`] pool (via `MeasureCache::share`), so a program
/// fingerprint measured by any session — or already recorded in the
/// database — is never measured twice in a serve session. Distinct
/// workloads produce disjoint fingerprint sets, so concurrent pooling
/// stays deterministic; models aliasing one workload are deduplicated
/// onto a single session outright.
///
/// All sessions share one tuning database path; the database's advisory
/// file lock serializes their commits, so no session's records are lost
/// (the serving-side "tune everything you host at once" path behind
/// `rcc serve --tune`). Models that don't name a known workload are
/// skipped.
pub fn tune_models_on(
    models: &[String],
    base_cfg: &TuneConfig,
    exec: &Arc<Executor>,
) -> Result<FleetResult> {
    let tunable: Vec<&String> = models
        .iter()
        .filter(|m| WorkloadId::from_name(m).is_some())
        .collect();
    if tunable.is_empty() {
        return Ok(FleetResult { sessions: Vec::new(), pool_entries: 0, pooled_hits: 0 });
    }
    let pool = MeasureCache::new();
    // One session per distinct workload, in first-appearance order.
    let mut unique: Vec<&str> = Vec::new();
    for m in &tunable {
        if !unique.contains(&m.as_str()) {
            unique.push(m.as_str());
        }
    }
    let (pool_ref, cfg_ref) = (&pool, base_cfg);
    let results: Vec<Result<SessionResult>> = exec.run(
        unique
            .iter()
            .map(|&w| {
                move || {
                    let mut cfg = cfg_ref.clone();
                    cfg.workload = w.to_string();
                    let workload = WorkloadId::from_name(w).expect("filtered to known workloads");
                    run_session_on_with(&workload.build(), &cfg, exec, Some(pool_ref))
                }
            })
            .collect(),
    );
    let mut by_workload: HashMap<&str, SessionResult> = HashMap::new();
    for (w, r) in unique.iter().copied().zip(results) {
        by_workload.insert(w, r?);
    }
    // Hits are counted once per actually-run session (aliased models
    // re-present the same session in `sessions`, they don't re-run it).
    let pooled_hits = by_workload.values().map(|s| s.total_cache_hits()).sum();
    let sessions: Vec<(String, SessionResult)> = tunable
        .into_iter()
        .map(|m| (m.clone(), by_workload[m.as_str()].clone()))
        .collect();
    Ok(FleetResult { sessions, pool_entries: pool.len(), pooled_hits })
}

/// Replay the best trace of a search result into a concrete program
/// (used by `rcc show-best` and the serving annotations).
pub fn best_program(base: &Program, result: &SearchResult) -> Program {
    let sched = Schedule::new(base.clone());
    let (best, _) = sched.apply_all(&result.best_trace);
    best.current
}

#[cfg(test)]
mod tests {
    use super::super::config::Strategy;
    use super::*;

    fn quick_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            strategy,
            budget: 30,
            repeats: 2,
            ..Default::default()
        }
    }

    #[test]
    fn e2e_weighted_speedup() {
        let tasks = crate::tir::workload::llama3_e2e_test();
        let mut cfg = quick_cfg(Strategy::LlmMcts);
        cfg.budget = 30;
        cfg.repeats = 1;
        let r = run_e2e(&tasks, &cfg).unwrap();
        assert_eq!(r.tasks.len(), 3);
        assert!(r.weighted_speedup > 1.0, "e2e speedup {}", r.weighted_speedup);
    }

    #[test]
    fn journal_is_rejected_for_the_serve_fleet() {
        let pool = MeasureCache::new();
        let mut cfg = quick_cfg(Strategy::Mcts);
        cfg.journal_path = Some("/tmp/never-written.jsonl".to_string());
        let program = WorkloadId::DeepSeekMoe.build_test();
        let exec = Executor::new(1);
        let err =
            run_session_on_with(&program, &cfg, &exec, Some(&pool)).unwrap_err();
        assert!(err.to_string().contains("serve fleet"), "{err}");
    }

    #[test]
    fn fleet_on_shared_executor_matches_private_executor() {
        // `tune_models` (private pool-sized executor) and `tune_models_on`
        // (caller-owned executor, as the serving plane uses) must produce
        // identical sessions: executor identity and width are scheduling
        // details, never part of any result.
        let models = vec!["deepseek_moe".to_string(), "llama4_mlp".to_string()];
        let mut cfg = quick_cfg(Strategy::Mcts);
        cfg.budget = 25;
        cfg.repeats = 1;
        let a = tune_models(&models, &cfg).unwrap();
        let exec = Executor::new(4);
        let b = tune_models_on(&models, &cfg, &exec).unwrap();
        let key = |f: &FleetResult| {
            f.sessions
                .iter()
                .map(|(m, s)| {
                    (m.clone(), s.runs.iter().map(|r| r.best_latency.to_bits()).collect::<Vec<_>>())
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b));
    }
}
