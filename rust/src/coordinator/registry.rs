//! Run registry: persistent records of tuning sessions.
//!
//! Every session can be recorded as a JSON document under `results/runs/`;
//! `rcc history` lists them and `rcc best` replays the best trace of a
//! recorded run. This is the framework feature that makes tuned schedules
//! *deployable*: the serving path looks up the best schedule for a
//! (workload, platform) pair instead of re-tuning.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::schedule::Transform;
use crate::util::json::{arr, num, s, Json};

use super::tuner::SessionResult;

/// Where run records live.
#[derive(Debug, Clone)]
pub struct Registry {
    pub dir: PathBuf,
}

/// A persisted record of one tuning session.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub id: String,
    pub strategy: String,
    pub workload: String,
    pub platform: String,
    pub mean_speedup: f64,
    pub best_speedup: f64,
    pub samples: usize,
    pub best_trace: Vec<Transform>,
}

impl Registry {
    pub fn open(dir: &Path) -> Result<Registry> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating registry dir {}", dir.display()))?;
        Ok(Registry { dir: dir.to_path_buf() })
    }

    pub fn default_location() -> Result<Registry> {
        Registry::open(Path::new("results/runs"))
    }

    /// Persist a session; returns the record id.
    pub fn record(&self, session: &SessionResult) -> Result<String> {
        let best_run = session
            .runs
            .iter()
            .max_by(|a, b| a.best_speedup().partial_cmp(&b.best_speedup()).unwrap())
            .ok_or_else(|| anyhow!("empty session"))?;
        let id = format!(
            "{}-{}-{}-{:x}",
            session.config_strategy.name(),
            session.workload,
            session.platform,
            fxhash(&format!(
                "{}{}{}",
                session.mean_speedup(),
                best_run.samples_used,
                session.runs.len()
            ))
        );
        let mut doc = Json::obj();
        doc.set("id", s(&id))
            .set("strategy", s(session.config_strategy.name()))
            .set("workload", s(&session.workload))
            .set("platform", s(&session.platform))
            .set("repeats", num(session.runs.len() as f64))
            .set("mean_speedup", num(session.mean_speedup()))
            .set("best_speedup", num(best_run.best_speedup()))
            .set("samples", num(best_run.samples_used as f64))
            .set(
                "best_trace",
                arr(best_run
                    .best_trace
                    .iter()
                    .map(|t| s(&crate::reasoning::engine::render_transform(t)))
                    .collect()),
            )
            .set(
                "curve",
                arr(best_run
                    .curve
                    .iter()
                    .map(|m| {
                        let mut o = Json::obj();
                        o.set("sample", num(m.sample as f64))
                            .set("latency", num(m.latency))
                            .set("best_speedup", num(m.best_speedup));
                        o
                    })
                    .collect()),
            )
            .set("telemetry", session.telemetry.to_json());
        // Temp sibling + atomic rename: a crash mid-write must never leave
        // a torn record for `list()` to trip over (`rcc serve` resolves
        // best schedules through these files at startup).
        let path = self.dir.join(format!("{id}.json"));
        let tmp = self.dir.join(format!("{id}.json.tmp"));
        std::fs::write(&tmp, doc.to_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(id)
    }

    /// List all persisted records (most recent speedup first).
    pub fn list(&self) -> Result<Vec<RunRecord>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            match Self::load_record(&path) {
                Ok(r) => out.push(r),
                Err(e) => eprintln!("warning: skipping malformed record {}: {e}", path.display()),
            }
        }
        out.sort_by(|a, b| b.best_speedup.partial_cmp(&a.best_speedup).unwrap());
        Ok(out)
    }

    /// Best record for a (workload, platform) pair, if any.
    pub fn best_for(&self, workload: &str, platform: &str) -> Result<Option<RunRecord>> {
        Ok(self
            .list()?
            .into_iter()
            .find(|r| r.workload == workload && r.platform == platform))
    }

    fn load_record(path: &Path) -> Result<RunRecord> {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).ok_or_else(|| anyhow!("malformed JSON"))?;
        let get_s = |k: &str| -> Result<String> {
            doc.get(k)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let get_n =
            |k: &str| -> Result<f64> { doc.get(k).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("missing {k}")) };
        let trace_texts: Vec<String> = doc
            .get("best_trace")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|t| t.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        // Re-parse the rendered transforms through the proposal parser.
        let mut best_trace = Vec::new();
        for t in &trace_texts {
            if let crate::reasoning::proposal::Parsed::Valid(tr) =
                parse_one_rendered(t).ok_or_else(|| anyhow!("bad trace element {t}"))?
            {
                best_trace.push(tr);
            }
        }
        Ok(RunRecord {
            id: get_s("id")?,
            strategy: get_s("strategy")?,
            workload: get_s("workload")?,
            platform: get_s("platform")?,
            mean_speedup: get_n("mean_speedup")?,
            best_speedup: get_n("best_speedup")?,
            samples: get_n("samples")? as usize,
            best_trace,
        })
    }
}

fn parse_one_rendered(text: &str) -> Option<crate::reasoning::proposal::Parsed> {
    let resp = format!("Transformations to apply: {text}.");
    crate::reasoning::proposal::parse_response(&resp).into_iter().next()
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_session, Strategy, TuneConfig};

    fn temp_registry() -> Registry {
        let dir = std::env::temp_dir().join(format!(
            "rcc_reg_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        Registry::open(&dir).unwrap()
    }

    fn session() -> SessionResult {
        run_session(&TuneConfig {
            strategy: Strategy::LlmMcts,
            budget: 25,
            repeats: 2,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn record_and_list_roundtrip() {
        let reg = temp_registry();
        let s = session();
        let id = reg.record(&s).unwrap();
        let records = reg.list().unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert_eq!(r.id, id);
        assert_eq!(r.workload, "deepseek_moe");
        assert!((r.mean_speedup - s.mean_speedup()).abs() < 1e-9);
        assert!(!r.best_trace.is_empty());
        // The persisted document carries the calibration summary and the
        // per-sample latencies of the sample-efficiency curve.
        let text = std::fs::read_to_string(reg.dir.join(format!("{id}.json"))).unwrap();
        let doc = Json::parse(&text).unwrap();
        let cal = doc.get("telemetry").and_then(|t| t.get("calibration")).unwrap();
        assert!(cal.get("n").and_then(Json::as_f64).unwrap() > 0.0);
        let curve = doc.get("curve").and_then(Json::as_arr).unwrap();
        assert!(!curve.is_empty());
        assert!(curve[0].get("latency").and_then(Json::as_f64).is_some());
        std::fs::remove_dir_all(&reg.dir).ok();
    }

    #[test]
    fn recorded_trace_replays_on_workload() {
        let reg = temp_registry();
        let s = session();
        reg.record(&s).unwrap();
        let r = reg.best_for("deepseek_moe", "core_i9").unwrap().unwrap();
        let base = crate::tir::WorkloadId::DeepSeekMoe.build();
        let sched = crate::schedule::Schedule::new(base);
        let (best, applied) = sched.apply_all(&r.best_trace);
        assert_eq!(applied, r.best_trace.len(), "persisted trace must replay");
        best.current.validate().unwrap();
        std::fs::remove_dir_all(&reg.dir).ok();
    }

    #[test]
    fn best_for_missing_pair_is_none() {
        let reg = temp_registry();
        assert!(reg.best_for("nope", "core_i9").unwrap().is_none());
        std::fs::remove_dir_all(&reg.dir).ok();
    }

    #[test]
    fn malformed_records_skipped() {
        let reg = temp_registry();
        std::fs::write(reg.dir.join("junk.json"), "{not json").unwrap();
        assert!(reg.list().unwrap().is_empty());
        std::fs::remove_dir_all(&reg.dir).ok();
    }

    #[test]
    fn truncated_record_skipped_loudly_and_tmp_files_ignored() {
        let reg = temp_registry();
        let s = session();
        let id = reg.record(&s).unwrap();
        // Simulate a torn write of a *second* record: a valid record
        // truncated mid-file must be skipped, not fail the whole listing.
        let good = std::fs::read_to_string(reg.dir.join(format!("{id}.json"))).unwrap();
        std::fs::write(reg.dir.join("torn.json"), &good[..good.len() / 2]).unwrap();
        // A leftover temp sibling (crash between write and rename) is not
        // a record and must not be listed.
        std::fs::write(reg.dir.join("stale.json.tmp"), &good).unwrap();
        let records = reg.list().unwrap();
        assert_eq!(records.len(), 1, "only the intact record survives");
        assert_eq!(records[0].id, id);
        std::fs::remove_dir_all(&reg.dir).ok();
    }
}
