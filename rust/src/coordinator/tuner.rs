//! Tuning sessions: strategy dispatch, repeated (multi-seed) runs with the
//! paper's mean-of-20 protocol, parallel execution across repeats, and the
//! end-to-end multi-task driver behind Table 2.

use crate::cost::{HardwareModel, Platform, SurrogateModel};
use crate::reasoning::{CostTracker, LlmPolicy, ModelProfile, SimulatedLlm};
use crate::schedule::Schedule;
use crate::search::{
    evolutionary_search, mcts_search, EvoConfig, MctsConfig, RandomPolicy, SearchResult,
};
use crate::tir::workload::{E2eTask, WorkloadId};
use crate::tir::Program;
use crate::util::stats;

use super::config::{Strategy, TuneConfig};

/// Outcome of a repeated tuning session on one (workload, platform).
#[derive(Debug, Clone)]
pub struct SessionResult {
    pub config_strategy: Strategy,
    pub workload: String,
    pub platform: String,
    pub runs: Vec<SearchResult>,
    /// Aggregated LLM accounting over the repeats (llm_mcts only).
    pub llm_costs: CostTracker,
    pub llm_fallback_rate: f64,
}

impl SessionResult {
    /// Mean best speedup across repeats.
    pub fn mean_speedup(&self) -> f64 {
        stats::mean(&self.runs.iter().map(|r| r.best_speedup()).collect::<Vec<_>>())
    }

    /// Mean best speedup within the first `samples` measurements.
    pub fn mean_speedup_at(&self, samples: usize) -> f64 {
        stats::mean(
            &self
                .runs
                .iter()
                .map(|r| r.speedup_at(samples))
                .collect::<Vec<_>>(),
        )
    }

    /// Mean samples needed to reach `target` speedup (runs that never reach
    /// it count as their full budget).
    pub fn mean_samples_to(&self, target: f64) -> f64 {
        stats::mean(
            &self
                .runs
                .iter()
                .map(|r| r.samples_to_reach(target).unwrap_or(r.samples_used) as f64)
                .collect::<Vec<_>>(),
        )
    }
}

/// Run one strategy once on a prebuilt program.
pub fn run_once(program: &Program, cfg: &TuneConfig, seed: u64) -> SearchResult {
    let platform = Platform::by_name(&cfg.platform)
        .unwrap_or_else(|| panic!("unknown platform {}", cfg.platform));
    let surrogate = SurrogateModel { platform: platform.clone() };
    let hardware = HardwareModel { platform: platform.clone() };
    let mcts_cfg = MctsConfig {
        exploration_c: cfg.exploration_c,
        branching: cfg.branching,
        rollout_len: cfg.rollout_len,
        history_depth: cfg.history_depth,
        max_trace_len: cfg.max_trace_len,
    };
    match cfg.strategy {
        Strategy::Evolutionary => evolutionary_search(
            program,
            &surrogate,
            &hardware,
            &EvoConfig::default(),
            &platform,
            cfg.budget,
            seed,
        ),
        Strategy::Mcts => {
            let mut policy = RandomPolicy::new(seed);
            mcts_search(
                program, &mut policy, &surrogate, &hardware, &mcts_cfg, &platform, cfg.budget,
                seed,
            )
        }
        Strategy::LlmMcts => {
            let model = ModelProfile::by_name(&cfg.model)
                .unwrap_or_else(|| panic!("unknown model {}", cfg.model));
            let engine = SimulatedLlm::new(model, seed);
            let mut policy = LlmPolicy::new(engine, cfg.history_depth, seed);
            mcts_search(
                program, &mut policy, &surrogate, &hardware, &mcts_cfg, &platform, cfg.budget,
                seed,
            )
        }
    }
}

/// Run one strategy once, returning LLM accounting when applicable.
fn run_once_with_accounting(
    program: &Program,
    cfg: &TuneConfig,
    seed: u64,
) -> (SearchResult, CostTracker, f64, u64) {
    if cfg.strategy != Strategy::LlmMcts {
        return (run_once(program, cfg, seed), CostTracker::default(), 0.0, 0);
    }
    let platform = Platform::by_name(&cfg.platform).expect("platform");
    let surrogate = SurrogateModel { platform: platform.clone() };
    let hardware = HardwareModel { platform: platform.clone() };
    let mcts_cfg = MctsConfig {
        exploration_c: cfg.exploration_c,
        branching: cfg.branching,
        rollout_len: cfg.rollout_len,
        history_depth: cfg.history_depth,
        max_trace_len: cfg.max_trace_len,
    };
    let model = ModelProfile::by_name(&cfg.model).expect("model");
    let engine = SimulatedLlm::new(model, seed);
    let mut policy = LlmPolicy::new(engine, cfg.history_depth, seed);
    let result = mcts_search(
        program, &mut policy, &surrogate, &hardware, &mcts_cfg, &platform, cfg.budget, seed,
    );
    let fb = policy.fallbacks.fallback_rate();
    let expansions = policy.fallbacks.fallbacks;
    (result, policy.costs, fb, expansions)
}

/// Repeat a tuning run over `cfg.repeats` seeds (in parallel) and aggregate
/// — the paper's statistical protocol.
pub fn run_session(cfg: &TuneConfig) -> SessionResult {
    let workload = WorkloadId::from_name(&cfg.workload)
        .unwrap_or_else(|| panic!("unknown workload {}", cfg.workload));
    let program = workload.build();
    run_session_on(&program, cfg)
}

/// Same as [`run_session`] but over an arbitrary program (used by e2e).
pub fn run_session_on(program: &Program, cfg: &TuneConfig) -> SessionResult {
    let seeds: Vec<u64> = (0..cfg.repeats as u64).map(|i| cfg.seed + i * 1009).collect();
    let mut outcomes: Vec<Option<(SearchResult, CostTracker, f64, u64)>> =
        (0..seeds.len()).map(|_| None).collect();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (slot, &seed) in outcomes.iter_mut().zip(&seeds) {
            let program = &program;
            let cfg = &cfg;
            handles.push(scope.spawn(move || {
                *slot = Some(run_once_with_accounting(program, cfg, seed));
            }));
        }
        for h in handles {
            h.join().expect("tuning repeat panicked");
        }
    });

    let mut runs = Vec::new();
    let mut llm_costs = CostTracker::default();
    let mut fb_rates = Vec::new();
    for o in outcomes.into_iter().flatten() {
        runs.push(o.0);
        llm_costs.merge(&o.1);
        fb_rates.push(o.2);
    }
    SessionResult {
        config_strategy: cfg.strategy,
        workload: cfg.workload.clone(),
        platform: cfg.platform.clone(),
        runs,
        llm_costs,
        llm_fallback_rate: stats::mean(&fb_rates),
    }
}

/// End-to-end result: per-task sessions + the invocation-weighted speedup
/// (the Table-2 metric: total model latency before vs after tuning).
#[derive(Debug, Clone)]
pub struct E2eResult {
    pub tasks: Vec<(String, SessionResult)>,
    pub total_samples: usize,
    pub weighted_speedup: f64,
}

/// Tune every task of an end-to-end model and combine by invocation count.
pub fn run_e2e(tasks: &[E2eTask], cfg: &TuneConfig) -> E2eResult {
    let platform = Platform::by_name(&cfg.platform).expect("platform");
    let mut sessions = Vec::new();
    let mut base_total = 0.0;
    let mut opt_total = 0.0;
    let mut total_samples = 0;
    for task in tasks {
        let mut task_cfg = cfg.clone();
        // Budget splits across tasks proportional to... equal shares here;
        // the paper tunes each extracted task with the shared budget.
        task_cfg.budget = (cfg.budget / tasks.len()).max(10);
        let session = run_session_on(&task.program, &task_cfg);
        // Weighted latency: mean best latency per run x invocations.
        let base = stats::mean(
            &session.runs.iter().map(|r| r.baseline_latency).collect::<Vec<_>>(),
        );
        let best = stats::mean(
            &session.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
        );
        base_total += base * task.invocations as f64;
        opt_total += best * task.invocations as f64;
        total_samples += session.runs.iter().map(|r| r.samples_used).sum::<usize>()
            / session.runs.len().max(1);
        sessions.push((task.program.name.clone(), session));
    }
    let _ = platform;
    E2eResult {
        tasks: sessions,
        total_samples,
        weighted_speedup: base_total / opt_total,
    }
}

/// Replay the best trace of a search result into a concrete program
/// (used by `rcc show-best` and the serving annotations).
pub fn best_program(base: &Program, result: &SearchResult) -> Program {
    let sched = Schedule::new(base.clone());
    let (best, _) = sched.apply_all(&result.best_trace);
    best.current
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            strategy,
            budget: 30,
            repeats: 2,
            ..Default::default()
        }
    }

    #[test]
    fn session_aggregates_repeats() {
        let s = run_session(&quick_cfg(Strategy::Mcts));
        assert_eq!(s.runs.len(), 2);
        assert!(s.mean_speedup() > 1.0);
        assert!(s.mean_speedup_at(30) >= s.mean_speedup_at(5));
    }

    #[test]
    fn llm_session_tracks_costs() {
        let s = run_session(&quick_cfg(Strategy::LlmMcts));
        assert!(s.llm_costs.calls > 0);
        assert!(s.llm_costs.prompt_tokens > 0);
        assert_eq!(s.llm_fallback_rate, 0.0); // gpt4o_mini never falls back
    }

    #[test]
    fn es_session_runs() {
        let s = run_session(&quick_cfg(Strategy::Evolutionary));
        assert!(s.mean_speedup() > 1.0);
        assert_eq!(s.llm_costs.calls, 0);
    }

    #[test]
    fn e2e_weighted_speedup() {
        let tasks = crate::tir::workload::llama3_e2e_test();
        let mut cfg = quick_cfg(Strategy::LlmMcts);
        cfg.budget = 30;
        cfg.repeats = 1;
        let r = run_e2e(&tasks, &cfg);
        assert_eq!(r.tasks.len(), 3);
        assert!(r.weighted_speedup > 1.0, "e2e speedup {}", r.weighted_speedup);
    }

    #[test]
    fn sessions_deterministic() {
        let a = run_session(&quick_cfg(Strategy::Mcts));
        let b = run_session(&quick_cfg(Strategy::Mcts));
        assert_eq!(
            a.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
            b.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>()
        );
    }
}
