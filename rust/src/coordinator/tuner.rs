//! Back-compat facade over the split tuning layers: single-session
//! mechanics now live in [`super::session`] and the multi-model drivers
//! (e2e tasks, the `rcc serve --tune` fleet) in [`super::fleet`]. This
//! module only re-exports so existing call sites and tests keep
//! compiling; new code should import from the specific layer.

pub use super::fleet::{
    best_program, run_e2e, tune_models, tune_models_on, E2eResult, FleetResult,
};
pub use super::session::{
    run_once, run_once_warm, run_session, run_session_on, run_session_on_with, SearchHints,
    SessionResult, SessionTelemetry,
};
