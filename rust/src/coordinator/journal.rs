//! Crash-safe session journal: an append-only JSONL checkpoint of a tuning
//! session's per-repeat outcomes, so `rcc tune --resume <journal>` can
//! restart a killed session and produce a **bit-identical**
//! `SessionResult` to the uninterrupted run.
//!
//! File shape (mirrors the tuning database's durability contracts):
//!
//! - Line 1 is the header: the session parameters that pin the repeat
//!   trajectory (workload fingerprint, platform, strategy, seed, budget,
//!   repeats, resolved eval-batch width, cache-sharing mode, model). It is
//!   written to a temp sibling and atomically renamed into place, so a
//!   crash mid-create never leaves a half-written header and any stale
//!   journal is replaced whole.
//! - Every later line is one completed repeat: its index, seed, full
//!   [`SearchResult`], LLM accounting, and — in shared-cache sessions —
//!   the measurement-cache delta that repeat contributed (what later
//!   repeats are allowed to observe). Appends are fsynced, so once
//!   `append` returns a kill loses at most the repeat in flight.
//! - On load, a malformed entry line (the torn tail of a mid-append kill)
//!   is **skipped loudly, never fatal** — the database-wide recovery
//!   contract. A missing or mismatched header *is* fatal: there is nothing
//!   safe to resume.
//!
//! Numbers that must survive bit-exactly do: finite `f64`s round-trip
//! through the crate's shortest-roundtrip JSON writer/parser, and `u64`
//! identifiers that may exceed 2^53 (fingerprints, seeds) are carried as
//! strings. Transforms reuse the registry's rendered-text codec, which is
//! exact (integer parameters only).

use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::reasoning::CostTracker;
use crate::schedule::Transform;
use crate::search::{Measurement, SearchResult};
use crate::util::json::{arr, num, s, Json};

/// Session parameters pinned at journal creation. Resume refuses to mix
/// journals across sessions whose results could diverge.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalHeader {
    pub workload_fp: u64,
    pub workload: String,
    pub platform: String,
    pub strategy: String,
    pub model: String,
    pub seed: u64,
    pub budget: usize,
    pub repeats: usize,
    /// Resolved width (`TuneConfig::resolved_eval_batch`): `eval_batch = 0`
    /// follows the worker count, which changes the MCTS trajectory — so the
    /// *resolved* value is what resume must agree on.
    pub eval_batch: usize,
    pub share_repeat_cache: bool,
}

const JOURNAL_KIND: &str = "rcc-session-journal";
const JOURNAL_VERSION: f64 = 1.0;

impl JournalHeader {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", s(JOURNAL_KIND))
            .set("version", num(JOURNAL_VERSION))
            .set("workload_fp", s(&format!("{:016x}", self.workload_fp)))
            .set("workload", s(&self.workload))
            .set("platform", s(&self.platform))
            .set("strategy", s(&self.strategy))
            .set("model", s(&self.model))
            .set("seed", s(&self.seed.to_string()))
            .set("budget", num(self.budget as f64))
            .set("repeats", num(self.repeats as f64))
            .set("eval_batch", num(self.eval_batch as f64))
            .set("share_repeat_cache", Json::Bool(self.share_repeat_cache));
        o
    }

    fn from_json(doc: &Json) -> Result<JournalHeader> {
        if get_str(doc, "kind")? != JOURNAL_KIND {
            return Err(anyhow!("not a session journal (kind mismatch)"));
        }
        Ok(JournalHeader {
            workload_fp: u64::from_str_radix(&get_str(doc, "workload_fp")?, 16)
                .map_err(|e| anyhow!("bad workload_fp: {e}"))?,
            workload: get_str(doc, "workload")?,
            platform: get_str(doc, "platform")?,
            strategy: get_str(doc, "strategy")?,
            model: get_str(doc, "model")?,
            seed: get_u64_str(doc, "seed")?,
            budget: get_num(doc, "budget")? as usize,
            repeats: get_num(doc, "repeats")? as usize,
            eval_batch: get_num(doc, "eval_batch")? as usize,
            share_repeat_cache: get_bool(doc, "share_repeat_cache")?,
        })
    }

    /// Refuse to resume under parameters that could change results,
    /// naming every mismatched field.
    pub fn ensure_matches(&self, current: &JournalHeader) -> Result<()> {
        let mut bad: Vec<String> = Vec::new();
        let mut chk = |name: &str, a: &str, b: &str| {
            if a != b {
                bad.push(format!("{name}: journal={a}, session={b}"));
            }
        };
        chk(
            "workload_fp",
            &format!("{:016x}", self.workload_fp),
            &format!("{:016x}", current.workload_fp),
        );
        chk("platform", &self.platform, &current.platform);
        chk("strategy", &self.strategy, &current.strategy);
        chk("model", &self.model, &current.model);
        chk("seed", &self.seed.to_string(), &current.seed.to_string());
        chk("budget", &self.budget.to_string(), &current.budget.to_string());
        chk("repeats", &self.repeats.to_string(), &current.repeats.to_string());
        chk(
            "eval_batch",
            &self.eval_batch.to_string(),
            &current.eval_batch.to_string(),
        );
        chk(
            "share_repeat_cache",
            &self.share_repeat_cache.to_string(),
            &current.share_repeat_cache.to_string(),
        );
        if bad.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("journal does not match this session: {}", bad.join("; ")))
        }
    }
}

/// One completed repeat, exactly as the session loop would have produced
/// it: replaying this entry instead of re-running the repeat is
/// bit-identical by construction.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    /// Repeat index within the session (`0..repeats`).
    pub repeat: usize,
    /// The repeat's root seed (`session seed + repeat * 1009`).
    pub seed: u64,
    pub result: SearchResult,
    pub costs: CostTracker,
    pub fb_rate: f64,
    pub expansions: u64,
    /// Measurements this repeat added to the session-shared cache, as
    /// `(platform, program fingerprint, latency)` — empty unless the
    /// session shares its repeat cache. Resume replays these so later
    /// repeats observe exactly the cache state they would have seen.
    pub cache_delta: Vec<(String, u64, f64)>,
}

impl JournalEntry {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("repeat", num(self.repeat as f64))
            .set("seed", s(&self.seed.to_string()))
            .set("result", result_to_json(&self.result))
            .set("costs", costs_to_json(&self.costs))
            .set("fb_rate", num(self.fb_rate))
            .set("expansions", num(self.expansions as f64))
            .set(
                "cache_delta",
                arr(self
                    .cache_delta
                    .iter()
                    .map(|(plat, fp, lat)| {
                        arr(vec![s(plat), s(&format!("{fp:016x}")), num(*lat)])
                    })
                    .collect()),
            );
        o
    }

    fn from_json(doc: &Json) -> Result<JournalEntry> {
        let mut cache_delta = Vec::new();
        for row in doc
            .get("cache_delta")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing cache_delta"))?
        {
            let row = row.as_arr().ok_or_else(|| anyhow!("bad cache_delta row"))?;
            match row {
                [p, fp, lat] => cache_delta.push((
                    p.as_str().ok_or_else(|| anyhow!("bad delta platform"))?.to_string(),
                    u64::from_str_radix(
                        fp.as_str().ok_or_else(|| anyhow!("bad delta fp"))?,
                        16,
                    )
                    .map_err(|e| anyhow!("bad delta fp: {e}"))?,
                    lat.as_f64().ok_or_else(|| anyhow!("bad delta latency"))?,
                )),
                _ => return Err(anyhow!("bad cache_delta row arity")),
            }
        }
        Ok(JournalEntry {
            repeat: get_num(doc, "repeat")? as usize,
            seed: get_u64_str(doc, "seed")?,
            result: result_from_json(
                doc.get("result").ok_or_else(|| anyhow!("missing result"))?,
            )?,
            costs: costs_from_json(
                doc.get("costs").ok_or_else(|| anyhow!("missing costs"))?,
            )?,
            fb_rate: get_num(doc, "fb_rate")?,
            expansions: get_num(doc, "expansions")? as u64,
            cache_delta,
        })
    }
}

/// Handle on a journal file. Creation is atomic; appends are durable.
#[derive(Debug, Clone)]
pub struct SessionJournal {
    path: PathBuf,
}

impl SessionJournal {
    /// Start a fresh journal: header written via temp sibling + atomic
    /// rename (replacing any stale journal whole).
    pub fn create(path: &Path, header: &JournalHeader) -> Result<SessionJournal> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating journal dir {}", dir.display()))?;
        }
        let tmp = path.with_extension("jsonl.tmp");
        let mut line = header.to_json().to_string();
        line.push('\n');
        std::fs::write(&tmp, &line)
            .with_context(|| format!("writing journal header {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing journal {}", path.display()))?;
        Ok(SessionJournal { path: path.to_path_buf() })
    }

    /// Re-open an existing journal for further appends (the resume path;
    /// call [`SessionJournal::load`] first to validate the header).
    pub fn open(path: &Path) -> SessionJournal {
        SessionJournal { path: path.to_path_buf() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one repeat checkpoint and fsync: after this returns, a kill
    /// at any point loses at most the repeat in flight.
    pub fn append(&self, entry: &JournalEntry) -> Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening journal {}", self.path.display()))?;
        let mut line = entry.to_json().to_string();
        line.push('\n');
        f.write_all(line.as_bytes())
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        f.sync_data()
            .with_context(|| format!("syncing journal {}", self.path.display()))?;
        Ok(())
    }

    /// Load header + journaled repeats (sorted by repeat index; a
    /// duplicate index keeps the first occurrence, loudly). Malformed
    /// entry lines — the torn tail of a mid-append kill — are skipped
    /// loudly, never fatal. A missing/malformed header is fatal.
    pub fn load(path: &Path) -> Result<(JournalHeader, Vec<JournalEntry>)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading journal {}", path.display()))?;
        let mut lines = text.lines();
        let header_line = lines
            .next()
            .ok_or_else(|| anyhow!("journal {} is empty", path.display()))?;
        let header = Json::parse(header_line)
            .ok_or_else(|| anyhow!("journal {} has a malformed header", path.display()))
            .and_then(|j| JournalHeader::from_json(&j))
            .with_context(|| format!("journal {}", path.display()))?;
        let mut entries: Vec<JournalEntry> = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line)
                .ok_or_else(|| anyhow!("malformed JSON"))
                .and_then(|j| JournalEntry::from_json(&j));
            match parsed {
                Ok(e) if entries.iter().any(|x| x.repeat == e.repeat) => {
                    eprintln!(
                        "warning: journal {}: duplicate repeat {} at line {}; keeping the first",
                        path.display(),
                        e.repeat,
                        i + 2
                    );
                }
                Ok(e) => entries.push(e),
                Err(err) => eprintln!(
                    "warning: journal {}: skipping malformed line {}: {err}",
                    path.display(),
                    i + 2
                ),
            }
        }
        entries.sort_by_key(|e| e.repeat);
        Ok((header, entries))
    }
}

// ---- field helpers --------------------------------------------------------

fn get_str(doc: &Json, k: &str) -> Result<String> {
    doc.get(k)
        .and_then(|v| v.as_str())
        .map(String::from)
        .ok_or_else(|| anyhow!("missing {k}"))
}

fn get_num(doc: &Json, k: &str) -> Result<f64> {
    doc.get(k).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("missing {k}"))
}

fn get_bool(doc: &Json, k: &str) -> Result<bool> {
    match doc.get(k) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(anyhow!("missing {k}")),
    }
}

/// `u64` carried as a decimal string (fingerprints and seeds may exceed
/// 2^53, past which JSON numbers stop being exact).
fn get_u64_str(doc: &Json, k: &str) -> Result<u64> {
    get_str(doc, k)?.parse::<u64>().map_err(|e| anyhow!("bad {k}: {e}"))
}

// ---- SearchResult / CostTracker codecs ------------------------------------

fn result_to_json(r: &SearchResult) -> Json {
    let mut o = Json::obj();
    o.set("strategy", s(&r.strategy))
        .set("workload", s(&r.workload))
        .set("platform", s(&r.platform))
        .set("baseline_latency", num(r.baseline_latency))
        .set("best_latency", num(r.best_latency))
        .set(
            "best_trace",
            arr(r
                .best_trace
                .iter()
                .map(|t| s(&crate::reasoning::engine::render_transform(t)))
                .collect()),
        )
        .set(
            "curve",
            arr(r
                .curve
                .iter()
                .map(|m| {
                    arr(vec![
                        num(m.sample as f64),
                        num(m.latency),
                        num(m.best_speedup),
                        num(m.trace_len as f64),
                    ])
                })
                .collect()),
        )
        .set("samples_used", num(r.samples_used as f64))
        .set("cache_hits", num(r.cache_hits as f64))
        .set("cache_misses", num(r.cache_misses as f64))
        .set("failed_measurements", num(r.failed_measurements as f64))
        .set("calibration", r.calibration.to_json());
    o
}

fn result_from_json(doc: &Json) -> Result<SearchResult> {
    let mut best_trace: Vec<Transform> = Vec::new();
    for t in doc
        .get("best_trace")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("missing best_trace"))?
    {
        let text = t.as_str().ok_or_else(|| anyhow!("bad trace element"))?;
        best_trace.push(
            parse_rendered_transform(text)
                .ok_or_else(|| anyhow!("bad trace element {text:?}"))?,
        );
    }
    let mut curve: Vec<Measurement> = Vec::new();
    for row in doc
        .get("curve")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("missing curve"))?
    {
        let row = row.as_arr().ok_or_else(|| anyhow!("bad curve row"))?;
        let f = |i: usize| -> Result<f64> {
            row.get(i)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("bad curve row"))
        };
        curve.push(Measurement {
            sample: f(0)? as usize,
            latency: f(1)?,
            best_speedup: f(2)?,
            trace_len: f(3)? as usize,
        });
    }
    Ok(SearchResult {
        strategy: get_str(doc, "strategy")?,
        workload: get_str(doc, "workload")?,
        platform: get_str(doc, "platform")?,
        baseline_latency: get_num(doc, "baseline_latency")?,
        best_latency: get_num(doc, "best_latency")?,
        best_trace,
        curve,
        samples_used: get_num(doc, "samples_used")? as usize,
        cache_hits: get_num(doc, "cache_hits")? as usize,
        cache_misses: get_num(doc, "cache_misses")? as usize,
        failed_measurements: get_num(doc, "failed_measurements")? as usize,
        // Older journals predate calibration; a missing block decodes as
        // the empty summary (raw sums round-trip bit-exactly otherwise).
        calibration: doc
            .get("calibration")
            .map(crate::cost::CalibrationStats::from_json)
            .unwrap_or_default(),
    })
}

fn costs_to_json(c: &CostTracker) -> Json {
    let mut o = Json::obj();
    o.set("calls", num(c.calls as f64))
        .set("prompt_tokens", num(c.prompt_tokens as f64))
        .set("completion_tokens", num(c.completion_tokens as f64))
        .set("retries", num(c.retries as f64))
        .set("degraded", num(c.degraded as f64))
        .set("backoff_ms", num(c.backoff_ms as f64))
        .set("proposals_offered", num(c.proposals_offered as f64))
        .set("proposals_accepted", num(c.proposals_accepted as f64));
    o
}

fn costs_from_json(doc: &Json) -> Result<CostTracker> {
    // The proposal counters are optional: journals written before the
    // audit plane simply decode them as 0.
    let opt = |k: &str| doc.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    Ok(CostTracker {
        calls: get_num(doc, "calls")? as u64,
        prompt_tokens: get_num(doc, "prompt_tokens")? as u64,
        completion_tokens: get_num(doc, "completion_tokens")? as u64,
        retries: get_num(doc, "retries")? as u64,
        degraded: get_num(doc, "degraded")? as u64,
        backoff_ms: get_num(doc, "backoff_ms")? as u64,
        proposals_offered: opt("proposals_offered"),
        proposals_accepted: opt("proposals_accepted"),
    })
}

/// Exact inverse of `render_transform`, via the proposal parser (the same
/// codec the run registry uses for persisted best traces).
fn parse_rendered_transform(text: &str) -> Option<Transform> {
    let resp = format!("Transformations to apply: {text}.");
    match crate::reasoning::proposal::parse_response(&resp).into_iter().next()? {
        crate::reasoning::proposal::Parsed::Valid(t) => Some(t),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> JournalHeader {
        JournalHeader {
            workload_fp: 0xdead_beef_cafe_f00d,
            workload: "deepseek_moe".to_string(),
            platform: "core_i9".to_string(),
            strategy: "llm_mcts".to_string(),
            model: "gpt4o_mini".to_string(),
            seed: u64::MAX - 7, // exercise the >2^53 string codec
            budget: 40,
            repeats: 3,
            eval_batch: 1,
            share_repeat_cache: true,
        }
    }

    fn sample_entry(repeat: usize) -> JournalEntry {
        JournalEntry {
            repeat,
            seed: 42 + repeat as u64 * 1009,
            result: SearchResult {
                strategy: "llm_mcts".to_string(),
                workload: "deepseek_moe".to_string(),
                platform: "core_i9".to_string(),
                baseline_latency: 0.012345678901234567,
                best_latency: 0.003141592653589793,
                best_trace: vec![
                    Transform::TileSize { stage: 0, loop_idx: 1, factor: 8 },
                    Transform::Reorder { stage: 1, perm: vec![1, 0] },
                    Transform::CacheWrite { stage: 0 },
                ],
                curve: vec![
                    Measurement {
                        sample: 1,
                        latency: 0.0101010101010101,
                        best_speedup: 1.0000000000000002,
                        trace_len: 2,
                    },
                    Measurement {
                        sample: 2,
                        latency: 0.003141592653589793,
                        best_speedup: 3.9297,
                        trace_len: 3,
                    },
                ],
                samples_used: 2,
                cache_hits: 1,
                cache_misses: 2,
                failed_measurements: 1,
                calibration: {
                    let mut c = crate::cost::CalibrationStats::default();
                    c.record(0.0111111111111111, 0.0101010101010101);
                    c.record(0.0029999999999999, 0.003141592653589793);
                    c
                },
            },
            costs: CostTracker {
                calls: 9,
                prompt_tokens: 12345,
                completion_tokens: 678,
                retries: 4,
                degraded: 1,
                backoff_ms: 175,
                proposals_offered: 27,
                proposals_accepted: 21,
            },
            fb_rate: 0.1111111111111111,
            expansions: 3,
            cache_delta: vec![
                ("core_i9".to_string(), u64::MAX - 1, 0.000123456789012345),
                ("core_i9".to_string(), 17, 2.0),
            ],
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "rcc_journal_{tag}_{}_{}.jsonl",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ))
    }

    fn assert_entries_bit_equal(a: &JournalEntry, b: &JournalEntry) {
        assert_eq!(a.repeat, b.repeat);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.result.strategy, b.result.strategy);
        assert_eq!(
            a.result.baseline_latency.to_bits(),
            b.result.baseline_latency.to_bits()
        );
        assert_eq!(a.result.best_latency.to_bits(), b.result.best_latency.to_bits());
        assert_eq!(a.result.best_trace, b.result.best_trace);
        assert_eq!(a.result.curve.len(), b.result.curve.len());
        for (x, y) in a.result.curve.iter().zip(&b.result.curve) {
            assert_eq!(x.sample, y.sample);
            assert_eq!(x.latency.to_bits(), y.latency.to_bits());
            assert_eq!(x.best_speedup.to_bits(), y.best_speedup.to_bits());
            assert_eq!(x.trace_len, y.trace_len);
        }
        assert_eq!(a.result.samples_used, b.result.samples_used);
        assert_eq!(a.result.cache_hits, b.result.cache_hits);
        assert_eq!(a.result.cache_misses, b.result.cache_misses);
        assert_eq!(a.result.failed_measurements, b.result.failed_measurements);
        assert_eq!(a.result.calibration.n, b.result.calibration.n);
        assert_eq!(
            a.result.calibration.sum_rel.to_bits(),
            b.result.calibration.sum_rel.to_bits()
        );
        assert_eq!(
            a.result.calibration.sum_abs_rel.to_bits(),
            b.result.calibration.sum_abs_rel.to_bits()
        );
        assert_eq!(
            a.result.calibration.worst_abs_rel.to_bits(),
            b.result.calibration.worst_abs_rel.to_bits()
        );
        assert_eq!(a.costs.calls, b.costs.calls);
        assert_eq!(a.costs.proposals_offered, b.costs.proposals_offered);
        assert_eq!(a.costs.proposals_accepted, b.costs.proposals_accepted);
        assert_eq!(a.costs.prompt_tokens, b.costs.prompt_tokens);
        assert_eq!(a.costs.retries, b.costs.retries);
        assert_eq!(a.costs.degraded, b.costs.degraded);
        assert_eq!(a.costs.backoff_ms, b.costs.backoff_ms);
        assert_eq!(a.fb_rate.to_bits(), b.fb_rate.to_bits());
        assert_eq!(a.expansions, b.expansions);
        assert_eq!(a.cache_delta.len(), b.cache_delta.len());
        for ((p1, f1, l1), (p2, f2, l2)) in a.cache_delta.iter().zip(&b.cache_delta) {
            assert_eq!(p1, p2);
            assert_eq!(f1, f2);
            assert_eq!(l1.to_bits(), l2.to_bits());
        }
    }

    #[test]
    fn header_and_entry_roundtrip_bit_exact() {
        let h = sample_header();
        let h2 = JournalHeader::from_json(&Json::parse(&h.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(h, h2);
        let e = sample_entry(1);
        let e2 = JournalEntry::from_json(&Json::parse(&e.to_json().to_string()).unwrap())
            .unwrap();
        assert_entries_bit_equal(&e, &e2);
    }

    #[test]
    fn create_append_load_roundtrip() {
        let path = tmp_path("roundtrip");
        let j = SessionJournal::create(&path, &sample_header()).unwrap();
        j.append(&sample_entry(0)).unwrap();
        j.append(&sample_entry(2)).unwrap();
        j.append(&sample_entry(1)).unwrap();
        let (h, entries) = SessionJournal::load(&path).unwrap();
        assert_eq!(h, sample_header());
        assert_eq!(
            entries.iter().map(|e| e.repeat).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "entries sort by repeat index"
        );
        assert_entries_bit_equal(&entries[1], &sample_entry(1));
        // Re-creating over an existing journal replaces it whole.
        SessionJournal::create(&path, &sample_header()).unwrap();
        let (_, entries) = SessionJournal::load(&path).unwrap();
        assert!(entries.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_skipped_loudly_not_fatal() {
        let path = tmp_path("torn");
        let j = SessionJournal::create(&path, &sample_header()).unwrap();
        j.append(&sample_entry(0)).unwrap();
        // Simulate a kill mid-append: a truncated JSON line at the tail.
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"repeat\":1,\"seed\":\"43\",\"res").unwrap();
        drop(f);
        let (_, entries) = SessionJournal::load(&path).unwrap();
        assert_eq!(entries.len(), 1, "intact prefix survives a torn tail");
        assert_eq!(entries[0].repeat, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_mismatch_and_bad_header_are_fatal() {
        let h = sample_header();
        let mut other = h.clone();
        other.budget = 41;
        other.platform = "m2_pro".to_string();
        let err = h.ensure_matches(&other).unwrap_err().to_string();
        assert!(err.contains("budget"), "{err}");
        assert!(err.contains("platform"), "{err}");
        assert!(h.ensure_matches(&h.clone()).is_ok());

        let path = tmp_path("badheader");
        std::fs::write(&path, "{not json\n").unwrap();
        assert!(SessionJournal::load(&path).is_err(), "bad header must be fatal");
        std::fs::write(&path, "{\"kind\":\"something-else\"}\n").unwrap();
        assert!(SessionJournal::load(&path).is_err(), "wrong kind must be fatal");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn duplicate_repeat_keeps_first() {
        let path = tmp_path("dup");
        let j = SessionJournal::create(&path, &sample_header()).unwrap();
        let mut first = sample_entry(0);
        first.costs.calls = 1;
        let mut second = sample_entry(0);
        second.costs.calls = 2;
        j.append(&first).unwrap();
        j.append(&second).unwrap();
        let (_, entries) = SessionJournal::load(&path).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].costs.calls, 1);
        std::fs::remove_file(&path).ok();
    }
}
