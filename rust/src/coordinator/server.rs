//! The model-serving plane (the "efficient model serving" of the title).
//!
//! **Continuous batching.** Instead of draining fixed batches, the server
//! holds `max_batch` in-flight *slots* and refills them every scheduling
//! tick: the moment a slot frees, the next admitted request takes it — a
//! short request is never held hostage behind a long batch, because
//! requests are admitted and retired individually (the vLLM-style
//! in-flight batching the serving literature converged on).
//!
//! **Admission control.** Every model's ingress queue is bounded by an
//! admission budget derived from its (tuned) service latency: a model
//! whose tuned schedule runs faster earns a deeper queue for the same
//! target queueing delay. Past the budget, [`Server::try_submit`] fails
//! with a typed [`ServeError::Overloaded`] — backpressure, not an
//! unbounded queue. Queued requests that exceed the optional queue-delay
//! deadline are evicted. Slot refill walks the models round-robin from a
//! persistent cursor, so a deep queue cannot starve its neighbors.
//!
//! **Two clocks.** All scheduling decisions — admission, eviction, batch
//! composition, completion — run on a virtual tick clock, so the decision
//! sequence and the reported per-request (virtual) latencies are
//! bit-deterministic per load seed, independent of executor width. Wall
//! time is measured alongside purely for throughput/latency *reporting*
//! (benches), never consulted for a decision.
//!
//! Two backends share the machinery: the PJRT [`Runtime`] over built
//! artifacts (`--features xla`), and a simulated backend
//! ([`Server::start_sim`]) whose per-model service times come from the
//! cost simulator — so the full serving plane (and its tests/benches)
//! runs without artifacts, and execution can be fanned onto the shared
//! [`Executor`] at high priority to preempt background tuning.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cost::simulator::simulate;
use crate::cost::Platform;
use crate::db::Database;
use crate::obs;
use crate::runtime::{Manifest, Runtime};
use crate::tir::workload::WorkloadId;
use crate::util::executor::{Executor, Priority};
use crate::util::rng::Pcg;

use super::metrics::ServerMetrics;

/// The best-known tuned schedule for a served model, looked up from the
/// tuning database when one is attached.
#[derive(Debug, Clone)]
pub struct BestSchedule {
    /// Speedup over the unoptimized baseline on the record's platform.
    pub speedup: f64,
    /// Platform the schedule was tuned for.
    pub platform: String,
    /// Search strategy that found it.
    pub strategy: String,
    /// Transformation-trace length.
    pub trace_len: usize,
}

/// Typed admission failures — the backpressure surface of the server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The model is not registered with this server.
    UnknownModel(String),
    /// The model's ingress queue is at its admission budget; the caller
    /// should back off (or shed) rather than queue unboundedly.
    Overloaded { model: String, depth: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel(m) => write!(f, "unknown model {m}"),
            ServeError::Overloaded { model, depth } => {
                write!(f, "overloaded: {model} queue at admission budget ({depth} queued)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Serving-plane configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// In-flight request slots (the continuous batch's width).
    pub max_batch: usize,
    /// Hard upper bound on any model's admission budget (and thus on every
    /// ingress queue) — nothing in the server grows past this.
    pub queue_cap: usize,
    /// Minimum queued requests before a slot is taken (amortization
    /// threshold; 1 = dispatch immediately).
    pub min_fill: usize,
    /// Ticks after which a waiting request dispatches even below
    /// `min_fill` (the drain fix: tail requests never wait for `drain()`).
    pub max_wait_ticks: u64,
    /// Evict a queued request older than this many ticks (0 = never).
    pub max_queue_ticks: u64,
    /// Target queueing delay, in ticks, that admission budgets are derived
    /// from: `budget = clamp(target_delay_ticks / service_ticks, 1,
    /// queue_cap)` — faster (tuned) models earn deeper queues.
    pub target_delay_ticks: u64,
    /// Load generator: max arrivals per tick (open loop, uniform 0..=N).
    pub arrival_burst: usize,
    /// Seconds per virtual tick; 0.0 = auto (half the fastest model's
    /// simulated latency, so the fastest model takes 2 ticks).
    pub tick_s: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            queue_cap: 64,
            min_fill: 1,
            max_wait_ticks: 4,
            max_queue_ticks: 0,
            target_delay_ticks: 64,
            arrival_burst: 2,
            tick_s: 0.0,
        }
    }
}

/// A request waiting in a model's ingress queue.
#[derive(Debug, Clone)]
struct Queued {
    seed: u64,
    enqueued: u64,
    arrived: Instant,
}

/// A request occupying an in-flight batch slot.
#[derive(Debug, Clone)]
struct Slot {
    model: String,
    seed: u64,
    enqueued: u64,
    arrived: Instant,
    /// Tick at which this request completes and frees the slot.
    finish: u64,
}

enum Backend {
    /// PJRT executables over built artifacts; requests execute inline at
    /// dispatch (service occupies one tick).
    Runtime(Runtime),
    /// Cost-simulator service times; optional calibrated busy work fans
    /// onto the shared executor at high priority.
    Sim,
}

/// Calibrated busy work for the simulated backend and the serve benches:
/// `units` dependent multiply-adds the optimizer cannot elide.
pub fn synthetic_work(units: u64) -> u64 {
    let mut acc = 0u64;
    for i in 0..units {
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(acc)
}

/// The serving engine: per-model bounded ingress queues feeding a
/// continuously-batched slot pool.
pub struct Server {
    backend: Backend,
    pub metrics: ServerMetrics,
    pub config: ServerConfig,
    /// Registered models, sorted — the round-robin universe.
    models: Vec<String>,
    queues: BTreeMap<String, VecDeque<Queued>>,
    /// Simulated base latency (seconds) per model, where known.
    base_latency: BTreeMap<String, f64>,
    /// Service time in ticks per model (≥ 1), after tuning annotations.
    service_ticks: BTreeMap<String, u64>,
    /// Admission budget (max queue depth) per model.
    budgets: BTreeMap<String, usize>,
    /// In-flight slots (`None` = free).
    slots: Vec<Option<Slot>>,
    /// Virtual tick clock.
    now: u64,
    /// Round-robin refill cursor into `models`.
    rr: usize,
    /// Resolved seconds per tick.
    tick_s: f64,
    /// Best-known tuned schedule per model, populated by
    /// [`Server::attach_tuning_db`].
    best_known: BTreeMap<String, BestSchedule>,
    /// Shared executor for simulated execution (high-priority dispatch).
    exec: Option<Arc<Executor>>,
    /// Busy-work units per service tick on the simulated backend.
    spin_work: u64,
}

impl Server {
    /// Load every artifact and stand up the server on the PJRT runtime.
    pub fn start(manifest: &Manifest, config: ServerConfig) -> Result<Server> {
        let mut runtime = Runtime::cpu()?;
        runtime.load_all(manifest)?;
        let models: Vec<String> = manifest.artifacts.keys().cloned().collect();
        Server::build(Backend::Runtime(runtime), models, config)
    }

    /// Stand up the server on the simulated backend: every model must name
    /// a known workload; its service time comes from the cost simulator.
    /// This is the artifact-free path behind `rcc serve --sim`, the tests
    /// and the benches.
    pub fn start_sim(models: &[String], config: ServerConfig) -> Result<Server> {
        for m in models {
            if WorkloadId::from_name(m).is_none() {
                return Err(ServeError::UnknownModel(m.clone()).into());
            }
        }
        Server::build(Backend::Sim, models.to_vec(), config)
    }

    fn build(backend: Backend, mut models: Vec<String>, config: ServerConfig) -> Result<Server> {
        models.sort();
        models.dedup();
        let platform = Platform::by_name("core_i9").expect("stock platform");
        let mut base_latency = BTreeMap::new();
        for m in &models {
            if let Some(w) = WorkloadId::from_name(m) {
                // Seed 0 is the noise-free simulation: a pure function of
                // the program structure, so service times are stable.
                base_latency.insert(m.clone(), simulate(&w.build(), &platform, 0));
            }
        }
        let min_base = base_latency.values().cloned().fold(f64::INFINITY, f64::min);
        let tick_s = if config.tick_s > 0.0 {
            config.tick_s
        } else if min_base.is_finite() {
            min_base / 2.0
        } else {
            1e-3
        };
        let slots = vec![None; config.max_batch.max(1)];
        let queues = models.iter().map(|m| (m.clone(), VecDeque::new())).collect();
        let mut server = Server {
            backend,
            metrics: ServerMetrics::default(),
            config,
            models,
            queues,
            base_latency,
            service_ticks: BTreeMap::new(),
            budgets: BTreeMap::new(),
            slots,
            now: 0,
            rr: 0,
            tick_s,
            best_known: BTreeMap::new(),
            exec: None,
            spin_work: 0,
        };
        server.recompute_schedule_params();
        Ok(server)
    }

    /// Fan simulated execution onto `exec` as high-priority tasks
    /// (`spin_work` busy units per service tick): serve traffic then
    /// preempts any low-priority background tuning sharing the executor.
    pub fn with_executor(mut self, exec: Arc<Executor>, spin_work: u64) -> Server {
        self.exec = Some(exec);
        self.spin_work = spin_work;
        self
    }

    /// Derive per-model service ticks and admission budgets from the
    /// (possibly tuned) latencies.
    fn recompute_schedule_params(&mut self) {
        for m in &self.models {
            let ticks = match self.base_latency.get(m) {
                Some(base) => {
                    let eff = match self.best_known.get(m) {
                        Some(b) if b.speedup > 0.0 => base / b.speedup,
                        _ => *base,
                    };
                    ((eff / self.tick_s).round() as u64).max(1)
                }
                // Runtime artifacts without a workload mapping execute
                // inline: one tick of service.
                None => 1,
            };
            self.service_ticks.insert(m.clone(), ticks);
            let budget = (self.config.target_delay_ticks / ticks)
                .clamp(1, self.config.queue_cap as u64) as usize;
            self.budgets.insert(m.clone(), budget);
        }
    }

    /// Attach the tuning database: every served model with a recorded run
    /// gets annotated with its best-known schedule, and admission budgets
    /// are re-derived from the tuned latencies (a faster tuned schedule
    /// earns a deeper queue for the same target delay). Returns how many
    /// models matched a record.
    pub fn attach_tuning_db(&mut self, db: &Database) -> usize {
        let mut n = 0;
        for model in &self.models {
            if let Some(rec) = db.best_for_workload(model) {
                self.best_known.insert(
                    model.clone(),
                    BestSchedule {
                        speedup: rec.speedup(),
                        platform: rec.platform.clone(),
                        strategy: rec.strategy.clone(),
                        trace_len: rec.trace.len(),
                    },
                );
                n += 1;
            }
        }
        self.recompute_schedule_params();
        n
    }

    /// Best-known schedule for a model, if the database had one.
    pub fn best_schedule(&self, model: &str) -> Option<&BestSchedule> {
        self.best_known.get(model)
    }

    /// One line per model describing its best-known schedule (or lack of
    /// one) — printed by `rcc serve`.
    pub fn schedule_summary(&self) -> String {
        let mut out = String::new();
        for model in &self.models {
            match self.best_known.get(model) {
                Some(b) => out.push_str(&format!(
                    "{:<18} {:>6.2}x via {} on {} ({} transforms)\n",
                    model, b.speedup, b.strategy, b.platform, b.trace_len
                )),
                None => out.push_str(&format!("{model:<18} (no tuning record)\n")),
            }
        }
        out
    }

    /// Enqueue a request through admission control. `Err(Overloaded)` is
    /// the backpressure signal: the queue is at the model's admission
    /// budget and the request was *not* queued.
    pub fn try_submit(&mut self, model: &str, seed: u64) -> Result<(), ServeError> {
        let budget = *self
            .budgets
            .get(model)
            .ok_or_else(|| ServeError::UnknownModel(model.to_string()))?;
        let q = self.queues.get_mut(model).expect("budget implies queue");
        let depth = q.len();
        if depth >= budget {
            self.metrics.model(model).record_reject();
            obs::instant2(obs::EventKind::ServeEnqueue, depth as u64, 0);
            return Err(ServeError::Overloaded { model: model.to_string(), depth });
        }
        q.push_back(Queued { seed, enqueued: self.now, arrived: Instant::now() });
        self.metrics.model(model).record_admit(depth + 1);
        obs::instant2(obs::EventKind::ServeEnqueue, depth as u64 + 1, 1);
        Ok(())
    }

    /// [`Server::try_submit`] for callers that treat rejection as fatal.
    pub fn submit(&mut self, model: &str, seed: u64) -> Result<()> {
        self.try_submit(model, seed).map_err(Into::into)
    }

    /// Requests waiting in ingress queues (bounded by budgets).
    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Requests occupying in-flight slots.
    pub fn in_flight(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Current virtual tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Service time in ticks for a model.
    pub fn service_ticks(&self, model: &str) -> Option<u64> {
        self.service_ticks.get(model).copied()
    }

    /// Admission budget (max queue depth) for a model.
    pub fn budget(&self, model: &str) -> Option<usize> {
        self.budgets.get(model).copied()
    }

    /// Override a model's service time (experiments/tests); re-derives its
    /// admission budget.
    pub fn set_service_ticks(&mut self, model: &str, ticks: u64) -> Result<(), ServeError> {
        if !self.service_ticks.contains_key(model) {
            return Err(ServeError::UnknownModel(model.to_string()));
        }
        let ticks = ticks.max(1);
        self.service_ticks.insert(model.to_string(), ticks);
        let budget = (self.config.target_delay_ticks / ticks)
            .clamp(1, self.config.queue_cap as u64) as usize;
        self.budgets.insert(model.to_string(), budget);
        Ok(())
    }

    /// One scheduling tick: retire finished slots, evict deadline-expired
    /// queue entries, refill free slots round-robin, execute what started.
    /// Returns the number of requests that *completed* this tick.
    pub fn step(&mut self) -> Result<usize> {
        self.now += 1;

        // 1. Retire: every slot whose service finished frees immediately —
        //    the next admitted request takes it this same tick.
        let mut completed = 0usize;
        for slot in &mut self.slots {
            if let Some(s) = slot {
                if s.finish <= self.now {
                    let virt = (s.finish - s.enqueued) as f64 * self.tick_s;
                    let wall = s.arrived.elapsed().as_secs_f64();
                    self.metrics.model(&s.model).record_completion(virt, wall);
                    *slot = None;
                    completed += 1;
                }
            }
        }

        // 2. Evict queue entries past the queueing-delay deadline.
        if self.config.max_queue_ticks > 0 {
            for m in &self.models {
                let q = self.queues.get_mut(m).expect("registered");
                while let Some(front) = q.front() {
                    if self.now.saturating_sub(front.enqueued) > self.config.max_queue_ticks {
                        q.pop_front();
                        self.metrics.model(m).record_evict();
                    } else {
                        break;
                    }
                }
            }
        }

        // 3. Refill free slots round-robin across models from the
        //    persistent cursor: one request per model per pass, so no
        //    model's deep queue starves the others.
        let mut started: BTreeMap<String, (usize, bool)> = BTreeMap::new();
        let mut new_slots: Vec<usize> = Vec::new();
        let free: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_none()).collect();
        let n_models = self.models.len();
        let mut scanned_without_take = 0usize;
        let mut free_iter = free.into_iter();
        let mut next_free = free_iter.next();
        while let Some(slot_idx) = next_free {
            if n_models == 0 || scanned_without_take >= n_models {
                break; // full pass with nothing eligible
            }
            let model = self.models[self.rr % n_models].clone();
            self.rr = (self.rr + 1) % n_models;
            let ticks = self.service_ticks[&model];
            let q = self.queues.get_mut(&model).expect("registered");
            let eligible = q.front().map_or(false, |front| {
                q.len() >= self.config.min_fill
                    || self.now.saturating_sub(front.enqueued) >= self.config.max_wait_ticks
            });
            if !eligible {
                scanned_without_take += 1;
                continue;
            }
            // A take below `min_fill` is a max-wait forced flush: count it
            // (once per model per tick) so the drain fix is observable.
            let partial = q.len() < self.config.min_fill;
            let req = q.pop_front().expect("eligible implies non-empty");
            self.slots[slot_idx] = Some(Slot {
                model: model.clone(),
                seed: req.seed,
                enqueued: req.enqueued,
                arrived: req.arrived,
                finish: self.now + ticks,
            });
            new_slots.push(slot_idx);
            let e = started.entry(model).or_insert((0, false));
            e.0 += 1;
            e.1 |= partial;
            scanned_without_take = 0;
            next_free = free_iter.next();
        }

        // 4. Execute what started this tick.
        let total_started: usize = started.values().map(|(n, _)| n).sum();
        if total_started > 0 {
            let occupancy = self.in_flight() as u64;
            let _sp = obs::span2(obs::EventKind::ServeBatch, total_started as u64, occupancy);
            let t0 = Instant::now();
            match &self.backend {
                Backend::Runtime(rt) => {
                    for &i in &new_slots {
                        let s = self.slots[i].as_ref().expect("just filled");
                        let exe = rt
                            .get(&s.model)
                            .ok_or_else(|| anyhow::anyhow!("{} not loaded", s.model))?;
                        let inputs = exe.random_inputs(s.seed);
                        let out = exe.run(&inputs)?;
                        debug_assert!(out.outputs[0].iter().all(|x| x.is_finite()));
                    }
                }
                Backend::Sim => {
                    if let (Some(exec), true) = (&self.exec, self.spin_work > 0) {
                        // One high-priority task per started request,
                        // scaled by its service time: serve work preempts
                        // background tuning at every dequeue/steal site.
                        let tasks: Vec<_> = new_slots
                            .iter()
                            .map(|&i| {
                                let s = self.slots[i].as_ref().expect("just filled");
                                let units = self.spin_work * self.service_ticks[&s.model];
                                move || synthetic_work(units)
                            })
                            .collect();
                        exec.run_with(Priority::High, tasks);
                    }
                }
            }
            let exec_latency = t0.elapsed().as_secs_f64();
            for (model, (n, partial)) in &started {
                self.metrics.model(model).record_dispatch(*n, exec_latency, *partial);
            }
        }
        Ok(completed)
    }

    /// Tick until every queue and slot is empty; returns requests completed.
    pub fn drain(&mut self) -> Result<u64> {
        let mut completed = 0u64;
        while self.pending() > 0 || self.in_flight() > 0 {
            completed += self.step()? as u64;
        }
        Ok(completed)
    }

    /// Drive a seeded open-loop workload: up to `arrival_burst` arrivals
    /// per tick across the registered models, overload rejections counted
    /// (not fatal), one scheduling tick per arrival burst, then a full
    /// drain (tail requests flush via `max_wait_ticks`, not the drain).
    /// The arrival sequence — and with it every admission, eviction and
    /// batch-composition decision — is a pure function of `seed`.
    pub fn run_synthetic(&mut self, total: usize, seed: u64) -> Result<()> {
        let models = self.models.clone();
        let mut rng = Pcg::new(seed);
        let mut issued = 0usize;
        while issued < total {
            let burst = rng.gen_range(self.config.arrival_burst + 1);
            for _ in 0..burst {
                if issued >= total {
                    break;
                }
                let m = &models[rng.gen_range(models.len())];
                match self.try_submit(m, issued as u64) {
                    Ok(()) | Err(ServeError::Overloaded { .. }) => {}
                    Err(e) => return Err(e.into()),
                }
                issued += 1;
            }
            self.step()?;
        }
        self.drain()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_models(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn sim_server_serves_and_completes() {
        // Generous target delay so both models' budgets cover the burst
        // regardless of their relative simulated latencies.
        let cfg = ServerConfig { target_delay_ticks: 4096, ..ServerConfig::default() };
        let mut server =
            Server::start_sim(&sim_models(&["deepseek_moe", "llama4_mlp"]), cfg).unwrap();
        for i in 0..10 {
            let m = if i % 2 == 0 { "deepseek_moe" } else { "llama4_mlp" };
            server.try_submit(m, i).unwrap();
        }
        let completed = server.drain().unwrap();
        assert_eq!(completed, 10);
        assert_eq!(server.pending(), 0);
        assert_eq!(server.in_flight(), 0);
        assert_eq!(server.metrics.total_requests(), 10);
        let mm = &server.metrics.per_model["deepseek_moe"];
        assert_eq!(mm.admitted, 5);
        assert!(mm.batches > 0);
        assert!(mm.p50() > 0.0, "virtual latencies recorded");
    }

    #[test]
    fn unknown_model_is_a_typed_error() {
        let mut server =
            Server::start_sim(&sim_models(&["deepseek_moe"]), ServerConfig::default()).unwrap();
        assert_eq!(
            server.try_submit("nope", 0),
            Err(ServeError::UnknownModel("nope".to_string()))
        );
        assert!(Server::start_sim(&sim_models(&["nope"]), ServerConfig::default()).is_err());
    }

    #[test]
    fn overload_rejects_with_typed_error_and_bounded_queue() {
        let cfg = ServerConfig { queue_cap: 4, ..ServerConfig::default() };
        let mut server = Server::start_sim(&sim_models(&["deepseek_moe"]), cfg).unwrap();
        // Budget clamps to queue_cap: 4 admitted, the rest backpressured.
        let mut rejected = 0;
        for i in 0..10 {
            match server.try_submit("deepseek_moe", i) {
                Ok(()) => {}
                Err(ServeError::Overloaded { depth, .. }) => {
                    assert_eq!(depth, 4);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(server.pending(), 4, "queue never exceeds the budget");
        let mm = &server.metrics.per_model["deepseek_moe"];
        assert_eq!(mm.admitted, 4);
        assert_eq!(mm.rejected, 6);
        assert_eq!(mm.queue_hwm, 4);
    }

    #[test]
    fn refill_is_round_robin_fair_across_models() {
        let cfg = ServerConfig { max_batch: 2, ..ServerConfig::default() };
        let mut server =
            Server::start_sim(&sim_models(&["deepseek_moe", "llama4_mlp"]), cfg).unwrap();
        server.set_service_ticks("deepseek_moe", 4).unwrap();
        server.set_service_ticks("llama4_mlp", 4).unwrap();
        for i in 0..8 {
            server.try_submit("deepseek_moe", i).unwrap();
        }
        for i in 0..2 {
            server.try_submit("llama4_mlp", 100 + i).unwrap();
        }
        server.step().unwrap();
        // Two slots, two models: one each, despite the 8-deep moe queue.
        assert_eq!(server.metrics.per_model["deepseek_moe"].requests, 1);
        assert_eq!(server.metrics.per_model["llama4_mlp"].requests, 1);
    }

    #[test]
    fn max_wait_flushes_partial_batches() {
        // min_fill 4 but only 2 requests ever arrive: without the max-wait
        // tick they would sit until drain(); with it they dispatch (and
        // the forced flush is counted).
        let cfg = ServerConfig { min_fill: 4, max_wait_ticks: 3, ..ServerConfig::default() };
        let mut server = Server::start_sim(&sim_models(&["deepseek_moe"]), cfg).unwrap();
        server.try_submit("deepseek_moe", 0).unwrap();
        server.try_submit("deepseek_moe", 1).unwrap();
        for _ in 0..2 {
            server.step().unwrap();
            assert_eq!(server.metrics.per_model["deepseek_moe"].requests, 0, "below min_fill");
        }
        server.step().unwrap(); // wait ≥ max_wait_ticks: forced flush
        let mm = &server.metrics.per_model["deepseek_moe"];
        assert_eq!(mm.requests, 2);
        assert!(mm.partial_dispatches >= 1, "forced flush is counted");
        server.drain().unwrap();
        assert_eq!(server.metrics.per_model["deepseek_moe"].request_latencies.seen(), 2);
    }

    #[test]
    fn deadline_evicts_stale_queue_entries() {
        // One slot, long service: the queue backs up and entries past the
        // deadline are evicted rather than served arbitrarily late.
        let cfg = ServerConfig { max_batch: 1, max_queue_ticks: 3, ..ServerConfig::default() };
        let mut server = Server::start_sim(&sim_models(&["deepseek_moe"]), cfg).unwrap();
        server.set_service_ticks("deepseek_moe", 10).unwrap();
        for i in 0..5 {
            server.try_submit("deepseek_moe", i).unwrap();
        }
        server.drain().unwrap();
        let mm = &server.metrics.per_model["deepseek_moe"];
        assert!(mm.evicted > 0, "stale entries evicted");
        assert_eq!(mm.admitted, 5);
        assert_eq!(mm.requests as u64 + mm.evicted, 5, "every request served or evicted");
    }
}
