//! The model-serving loop (the "efficient model serving" of the title).
//!
//! A dynamic-batching request server over the PJRT executables: requests
//! queue per model; the dispatcher drains up to `max_batch` requests per
//! model and executes them (artifact graphs are fixed-shape, so batching
//! here means amortizing dispatch over back-to-back executions, the same
//! way a compiled-kernel server amortizes launch overhead). The tuned
//! schedules from the search reduce the *kernel* cost; this loop
//! demonstrates the serving stack those kernels live in.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use anyhow::Result;

use crate::db::Database;
use crate::obs;
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Pcg;

use super::metrics::ServerMetrics;

/// The best-known tuned schedule for a served model, looked up from the
/// tuning database when one is attached.
#[derive(Debug, Clone)]
pub struct BestSchedule {
    /// Speedup over the unoptimized baseline on the record's platform.
    pub speedup: f64,
    /// Platform the schedule was tuned for.
    pub platform: String,
    /// Search strategy that found it.
    pub strategy: String,
    /// Transformation-trace length.
    pub trace_len: usize,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub model: String,
    pub seed: u64,
    pub arrived: Instant,
}

/// Dynamic-batching configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 8 }
    }
}

/// The serving engine: compiled executables + per-model request queues.
pub struct Server {
    runtime: Runtime,
    queues: std::collections::BTreeMap<String, VecDeque<Request>>,
    pub metrics: ServerMetrics,
    pub config: ServerConfig,
    /// Best-known tuned schedule per model, populated by
    /// [`Server::attach_tuning_db`].
    best_known: BTreeMap<String, BestSchedule>,
}

impl Server {
    /// Load every artifact and stand up the server.
    pub fn start(manifest: &Manifest, config: ServerConfig) -> Result<Server> {
        let mut runtime = Runtime::cpu()?;
        runtime.load_all(manifest)?;
        let queues = manifest
            .artifacts
            .keys()
            .map(|k| (k.clone(), VecDeque::new()))
            .collect();
        Ok(Server {
            runtime,
            queues,
            metrics: ServerMetrics::default(),
            config,
            best_known: BTreeMap::new(),
        })
    }

    /// Attach the tuning database: every served model with a recorded run
    /// gets annotated with its best-known schedule (the serving half of
    /// "never pay for the same measurement twice"). Returns how many models
    /// matched a record.
    pub fn attach_tuning_db(&mut self, db: &Database) -> usize {
        let mut n = 0;
        for model in self.queues.keys() {
            if let Some(rec) = db.best_for_workload(model) {
                self.best_known.insert(
                    model.clone(),
                    BestSchedule {
                        speedup: rec.speedup(),
                        platform: rec.platform.clone(),
                        strategy: rec.strategy.clone(),
                        trace_len: rec.trace.len(),
                    },
                );
                n += 1;
            }
        }
        n
    }

    /// Best-known schedule for a model, if the database had one.
    pub fn best_schedule(&self, model: &str) -> Option<&BestSchedule> {
        self.best_known.get(model)
    }

    /// One line per model describing its best-known schedule (or lack of
    /// one) — printed by `rcc serve`.
    pub fn schedule_summary(&self) -> String {
        let mut out = String::new();
        for model in self.queues.keys() {
            match self.best_known.get(model) {
                Some(b) => out.push_str(&format!(
                    "{:<18} {:>6.2}x via {} on {} ({} transforms)\n",
                    model, b.speedup, b.strategy, b.platform, b.trace_len
                )),
                None => out.push_str(&format!("{model:<18} (no tuning record)\n")),
            }
        }
        out
    }

    /// Enqueue a request.
    pub fn submit(&mut self, model: &str, seed: u64) -> Result<()> {
        let q = self
            .queues
            .get_mut(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        q.push_back(Request {
            model: model.to_string(),
            seed,
            arrived: Instant::now(),
        });
        obs::instant(obs::EventKind::ServeEnqueue, q.len() as u64);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Drain one batch from the deepest queue; returns the number of
    /// requests served (0 when idle).
    pub fn step(&mut self) -> Result<usize> {
        let Some((model, _)) = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .max_by_key(|(_, q)| q.len())
            .map(|(k, q)| (k.clone(), q.len()))
        else {
            return Ok(0);
        };
        let batch: Vec<Request> = {
            let q = self.queues.get_mut(&model).unwrap();
            let n = q.len().min(self.config.max_batch);
            q.drain(..n).collect()
        };
        let exe = self
            .runtime
            .get(&model)
            .ok_or_else(|| anyhow::anyhow!("{model} not loaded"))?;

        let _sp = obs::span(obs::EventKind::ServeBatch, batch.len() as u64);
        let t0 = Instant::now();
        for req in &batch {
            let inputs = exe.random_inputs(req.seed);
            let out = exe.run(&inputs)?;
            debug_assert!(out.outputs[0].iter().all(|x| x.is_finite()));
        }
        let exec_latency = t0.elapsed().as_secs_f64();

        let waits: Vec<f64> = batch
            .iter()
            .map(|r| r.arrived.elapsed().as_secs_f64() - exec_latency)
            .map(|w| w.max(0.0))
            .collect();
        self.metrics
            .model(&model)
            .record_batch(batch.len(), exec_latency, &waits);
        Ok(batch.len())
    }

    /// Run until all queues drain.
    pub fn drain(&mut self) -> Result<u64> {
        let mut served = 0u64;
        while self.pending() > 0 {
            served += self.step()? as u64;
        }
        Ok(served)
    }

    /// Drive a synthetic open-loop workload: `total` requests spread over
    /// the loaded models (weighted toward the first ones), serving as they
    /// arrive — the demo behind `rcc serve` and `examples/serve_llama.rs`.
    pub fn run_synthetic(&mut self, total: usize, seed: u64) -> Result<()> {
        let models: Vec<String> = self.queues.keys().cloned().collect();
        let mut rng = Pcg::new(seed);
        for i in 0..total {
            let m = &models[rng.gen_range(models.len())];
            self.submit(m, i as u64)?;
            // Keep queues bounded: serve a batch every few arrivals.
            if i % 4 == 3 {
                self.step()?;
            }
        }
        self.drain()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::discover().ok()
    }

    #[test]
    fn serves_batches_and_tracks_metrics() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        if !cfg!(feature = "xla") {
            eprintln!("skipping: built without the xla feature");
            return;
        }
        let mut server = Server::start(&m, ServerConfig { max_batch: 4 }).unwrap();
        for i in 0..10 {
            server.submit("deepseek_moe", i).unwrap();
        }
        let served = server.drain().unwrap();
        assert_eq!(served, 10);
        let mm = &server.metrics.per_model["deepseek_moe"];
        assert_eq!(mm.requests, 10);
        assert!(mm.batches >= 3); // 4+4+2
        assert!(mm.p50() > 0.0);
    }

    #[test]
    fn unknown_model_rejected() {
        let Some(m) = manifest() else { return };
        if !cfg!(feature = "xla") {
            return;
        }
        let mut server = Server::start(&m, ServerConfig::default()).unwrap();
        assert!(server.submit("nope", 0).is_err());
    }

    #[test]
    fn synthetic_workload_drains() {
        let Some(m) = manifest() else { return };
        if !cfg!(feature = "xla") {
            return;
        }
        let mut server = Server::start(&m, ServerConfig::default()).unwrap();
        server.run_synthetic(12, 3).unwrap();
        assert_eq!(server.pending(), 0);
        assert_eq!(server.metrics.total_requests(), 12);
    }
}
