//! L3 coordinator: the framework around the search — typed configuration,
//! repeated tuning sessions with the paper's statistical protocol (which
//! open, warm-start from and commit to the persistent tuning database),
//! the end-to-end multi-task driver, and the dynamic-batching serving loop
//! over PJRT executables annotated with their best-known schedules.

pub mod config;
pub mod journal;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod tuner;

pub use config::{Strategy, TuneConfig, DEFAULT_DB_PATH};
pub use journal::{JournalEntry, JournalHeader, SessionJournal};
pub use registry::{Registry, RunRecord};
pub use server::{BestSchedule, Server, ServerConfig};
pub use tuner::{run_e2e, run_once, run_once_warm, run_session, run_session_on,
    run_session_on_with, tune_models, E2eResult, FleetResult, SearchHints, SessionResult,
    SessionTelemetry};
