//! L3 coordinator: the framework around the search — typed configuration,
//! repeated tuning sessions with the paper's statistical protocol (which
//! open, warm-start from and commit to the persistent tuning database),
//! the multi-model fleet and end-to-end drivers, and the continuous-
//! batching serving plane with admission control over executables
//! annotated with their best-known schedules.

pub mod config;
pub mod fleet;
pub mod journal;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod session;
pub mod tuner;

pub use config::{Strategy, TuneConfig, DEFAULT_DB_PATH};
pub use journal::{JournalEntry, JournalHeader, SessionJournal};
pub use registry::{Registry, RunRecord};
pub use server::{BestSchedule, ServeError, Server, ServerConfig};
pub use tuner::{run_e2e, run_once, run_once_warm, run_session, run_session_on,
    run_session_on_with, tune_models, tune_models_on, E2eResult, FleetResult, SearchHints,
    SessionResult, SessionTelemetry};
