//! L3 coordinator: the framework around the search — typed configuration,
//! repeated tuning sessions with the paper's statistical protocol, the
//! end-to-end multi-task driver, and the dynamic-batching serving loop
//! over PJRT executables.

pub mod config;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod tuner;

pub use config::{Strategy, TuneConfig};
pub use registry::{Registry, RunRecord};
pub use server::{Server, ServerConfig};
pub use tuner::{run_e2e, run_once, run_session, E2eResult, SessionResult};
