//! Framework configuration: typed view over the TOML-subset files in
//! `configs/`, with CLI overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::tomlmini::Doc;

/// Which search strategy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Evolutionary,
    Mcts,
    LlmMcts,
}

impl Strategy {
    pub fn from_name(s: &str) -> Option<Strategy> {
        match s {
            "evolutionary" | "es" | "tvm" => Some(Strategy::Evolutionary),
            "mcts" => Some(Strategy::Mcts),
            "llm_mcts" | "rc" | "reasoning" => Some(Strategy::LlmMcts),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Evolutionary => "evolutionary",
            Strategy::Mcts => "mcts",
            Strategy::LlmMcts => "llm_mcts",
        }
    }

    pub fn display(&self) -> &'static str {
        match self {
            Strategy::Evolutionary => "Evolutionary Search",
            Strategy::Mcts => "MCTS",
            Strategy::LlmMcts => "REASONING COMPILER",
        }
    }
}

/// One tuning run's configuration.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub strategy: Strategy,
    pub workload: String,
    pub platform: String,
    /// Hardware-measurement budget (samples).
    pub budget: usize,
    /// Statistical repeats (paper: 20).
    pub repeats: usize,
    pub seed: u64,
    /// LLM model profile name (llm_mcts only).
    pub model: String,
    /// Prompt history depth: 2 = parent+grandparent (paper default).
    pub history_depth: usize,
    /// MCTS branching factor (paper: B = 2).
    pub branching: usize,
    /// UCT exploration constant (paper: sqrt(2)).
    pub exploration_c: f64,
    pub rollout_len: usize,
    pub max_trace_len: usize,
    /// Path to the persistent tuning-record database (JSONL). `None`
    /// disables persistence, warm starts and the measurement cache.
    pub db_path: Option<String>,
    /// Seed searches from the best database records for the workload
    /// (ignored when `db_path` is None; the measurement cache stays active
    /// either way once a database is attached).
    pub warm_start: bool,
    /// How many top database records to warm-start from.
    pub warm_top_k: usize,
    /// Cross-workload transfer tuning (ignored without a database): rebase
    /// traces recorded for structurally similar workloads into extra
    /// warm-start candidates and feed few-shot exemplars into LLM prompts.
    /// `--no-transfer` disables; `--transfer` re-enables.
    pub transfer: bool,
    /// How many transfer matches to rebase into warm starts / exemplars.
    pub transfer_top_k: usize,
    /// Attach the ANN transfer index (`transfer::index`) to the session's
    /// database so similarity retrieval goes sublinear on large databases.
    /// Small databases stay on the exact scan regardless (see
    /// `transfer_index_threshold`). `--no-transfer-index` disables;
    /// `--transfer-index` re-enables.
    pub transfer_index: bool,
    /// Minimum committed record count before retrieval switches from the
    /// exact linear scan to the ANN index. Below it results are
    /// bit-identical to the scan by construction.
    pub transfer_index_threshold: usize,
    /// Share one measurement cache across the session's repeats
    /// (`--share-repeat-cache`): repeats answer each other's measurements,
    /// saving samples at the cost of the 20-repeat independence contract
    /// (a repeat may reuse another repeat's seeded measurement). The
    /// session then runs its repeats serially in seed order — sharing is
    /// order-dependent, so a parallel repeat pool would make results vary
    /// with thread timing. Default off, preserving the paper's protocol.
    pub share_repeat_cache: bool,
    /// Total parallelism of the session's one persistent work-stealing
    /// executor (`util::executor`). Every parallel site — session repeats,
    /// each run's batched evaluation, `serve --tune`'s concurrent model
    /// sessions — runs as task groups on that single executor, so nested
    /// sites share this budget instead of multiplying thread pools.
    /// `0` = auto (`RCC_WORKERS` env var if set, else the machine's
    /// available parallelism). Any value yields identical results —
    /// workers only change wall-clock; `1` forces the fully serial
    /// inline path.
    pub workers: usize,
    /// MCTS leaves expanded + measured per iteration (leaf-parallel batch
    /// width). `1` (the default) is the original serial trajectory and
    /// keeps results machine-independent; `>1` changes the search
    /// trajectory deterministically per seed; `0` = match the resolved
    /// worker count. Evolutionary search ignores this knob.
    pub eval_batch: usize,
    /// Write a Chrome trace-event (Perfetto) JSON of the run to this path
    /// (`[obs] trace` in TOML, `--trace` / `RCC_TRACE` on the CLI; CLI and
    /// env win over the file). `None` leaves the recorder disabled —
    /// tracing never changes results either way, only wall-clock.
    pub trace_path: Option<String>,
    /// Append the decision-provenance audit log (JSONL; see `obs::audit`)
    /// to this path (`[obs] audit` in TOML, `--audit` / `RCC_AUDIT` on the
    /// CLI; CLI and env win over the file). `None` leaves the audit plane
    /// disarmed — auditing never changes results either way.
    pub audit_path: Option<String>,
    /// Checkpoint the session to this crash-safe JSONL journal
    /// (`[session] journal` in TOML, `--journal` on the CLI): one fsynced
    /// entry per completed repeat, so a killed session can be resumed
    /// bit-identically. Journaling serializes the repeat pool (each
    /// repeat's inner evaluation fan-out keeps the full executor, so this
    /// is wall-clock only — the workers contract guarantees identical
    /// results).
    pub journal_path: Option<String>,
    /// Resume a killed session from its journal (`--resume <journal>`):
    /// journaled repeats are replayed verbatim, the rest run fresh, and
    /// new checkpoints append to the same file. The journal header must
    /// match this session's parameters exactly.
    pub resume_from: Option<String>,
    /// Deterministic fault-injection spec (`[faults] spec` in TOML,
    /// `--faults` / `RCC_FAULTS` on the CLI; CLI wins over env wins over
    /// the file), e.g. `"llm_error=0.05,measure_fail=0.03,seed=7"`. See
    /// `util::faults::FaultPlan::parse`. `None` / empty leaves the
    /// harness disarmed — stock runs are bit-identical to a build
    /// without it.
    pub faults_spec: Option<String>,
}

/// Conventional database location used by the CLI when `--db` is not given.
pub const DEFAULT_DB_PATH: &str = "results/tuning_db.jsonl";

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            strategy: Strategy::LlmMcts,
            workload: "deepseek_moe".to_string(),
            platform: "core_i9".to_string(),
            budget: 200,
            repeats: 5,
            seed: 42,
            model: "gpt4o_mini".to_string(),
            history_depth: 2,
            branching: 2,
            exploration_c: std::f64::consts::SQRT_2,
            rollout_len: 4,
            max_trace_len: 24,
            db_path: None,
            warm_start: true,
            warm_top_k: 8,
            transfer: true,
            transfer_top_k: 4,
            transfer_index: true,
            transfer_index_threshold: 256,
            share_repeat_cache: false,
            workers: 0,
            eval_batch: 1,
            trace_path: None,
            audit_path: None,
            journal_path: None,
            resume_from: None,
            faults_spec: None,
        }
    }
}

impl TuneConfig {
    /// The concrete worker count: the explicit knob, else `RCC_WORKERS`,
    /// else the machine's available parallelism.
    pub fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        if let Some(n) = std::env::var("RCC_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            return n;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// The concrete MCTS evaluation-batch width (`0` = match workers).
    pub fn resolved_eval_batch(&self) -> usize {
        if self.eval_batch > 0 {
            self.eval_batch
        } else {
            self.resolved_workers()
        }
    }
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<TuneConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Doc::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Self::from_doc(&doc))
    }

    pub fn from_doc(doc: &Doc) -> TuneConfig {
        let d = TuneConfig::default();
        TuneConfig {
            strategy: Strategy::from_name(doc.get_str("search.strategy", d.strategy.name()))
                .unwrap_or(d.strategy),
            workload: doc.get_str("workload", &d.workload).to_string(),
            platform: doc.get_str("platform", &d.platform).to_string(),
            budget: doc.get_usize("search.budget", d.budget),
            repeats: doc.get_usize("search.repeats", d.repeats),
            seed: doc.get_usize("search.seed", d.seed as usize) as u64,
            model: doc.get_str("llm.model", &d.model).to_string(),
            history_depth: doc.get_usize("llm.history_depth", d.history_depth),
            branching: doc.get_usize("mcts.branching", d.branching),
            exploration_c: doc.get_f64("mcts.exploration_c", d.exploration_c),
            rollout_len: doc.get_usize("mcts.rollout_len", d.rollout_len),
            max_trace_len: doc.get_usize("search.max_trace_len", d.max_trace_len),
            db_path: match doc.get_str("db.path", "") {
                "" => d.db_path,
                p => Some(p.to_string()),
            },
            warm_start: doc.get_bool("db.warm_start", d.warm_start),
            warm_top_k: doc.get_usize("db.warm_top_k", d.warm_top_k),
            transfer: doc.get_bool("db.transfer", d.transfer),
            transfer_top_k: doc.get_usize("db.transfer_top_k", d.transfer_top_k),
            transfer_index: doc.get_bool("db.transfer_index", d.transfer_index),
            transfer_index_threshold: doc
                .get_usize("db.transfer_index_threshold", d.transfer_index_threshold),
            share_repeat_cache: doc
                .get_bool("db.share_repeat_cache", d.share_repeat_cache),
            workers: doc.get_usize("search.workers", d.workers),
            eval_batch: doc.get_usize("search.eval_batch", d.eval_batch),
            trace_path: match doc.get_str("obs.trace", "") {
                "" => d.trace_path,
                p => Some(p.to_string()),
            },
            audit_path: match doc.get_str("obs.audit", "") {
                "" => d.audit_path,
                p => Some(p.to_string()),
            },
            journal_path: match doc.get_str("session.journal", "") {
                "" => d.journal_path,
                p => Some(p.to_string()),
            },
            // Resuming is an operator action on a specific journal file,
            // not a standing configuration — CLI only.
            resume_from: d.resume_from,
            faults_spec: match doc.get_str("faults.spec", "") {
                "" => d.faults_spec,
                p => Some(p.to_string()),
            },
        }
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_cli(&mut self, args: &crate::util::cli::Args) {
        if let Some(s) = args.opt("strategy").and_then(Strategy::from_name) {
            self.strategy = s;
        }
        if let Some(w) = args.opt("workload") {
            self.workload = w.to_string();
        }
        if let Some(p) = args.opt("platform") {
            self.platform = p.to_string();
        }
        self.budget = args.opt_usize("budget", self.budget);
        self.repeats = args.opt_usize("repeats", self.repeats);
        self.seed = args.opt_u64("seed", self.seed);
        if let Some(m) = args.opt("model") {
            self.model = m.to_string();
        }
        self.history_depth = args.opt_usize("history-depth", self.history_depth);
        self.branching = args.opt_usize("branching", self.branching);
        self.exploration_c = args.opt_f64("exploration-c", self.exploration_c);
        if let Some(p) = args.opt("db") {
            self.db_path = Some(p.to_string());
        }
        if args.has_flag("no-db") {
            self.db_path = None;
        }
        if args.has_flag("no-warm-start") {
            self.warm_start = false;
        }
        self.warm_top_k = args.opt_usize("warm-top-k", self.warm_top_k);
        if args.has_flag("transfer") {
            self.transfer = true;
        }
        if args.has_flag("no-transfer") {
            self.transfer = false;
        }
        self.transfer_top_k = args.opt_usize("transfer-top-k", self.transfer_top_k);
        if args.has_flag("transfer-index") {
            self.transfer_index = true;
        }
        if args.has_flag("no-transfer-index") {
            self.transfer_index = false;
        }
        self.transfer_index_threshold =
            args.opt_usize("transfer-index-threshold", self.transfer_index_threshold);
        if args.has_flag("share-repeat-cache") {
            self.share_repeat_cache = true;
        }
        self.workers = args.opt_usize("workers", self.workers);
        self.eval_batch = args.opt_usize("eval-batch", self.eval_batch);
        if let Some(p) = args.opt("trace") {
            self.trace_path = Some(p.to_string());
        }
        if let Some(p) = args.opt("audit") {
            self.audit_path = Some(p.to_string());
        }
        if let Some(p) = args.opt("journal") {
            self.journal_path = Some(p.to_string());
        }
        if let Some(p) = args.opt("resume") {
            self.resume_from = Some(p.to_string());
        }
        if let Some(f) = args.opt("faults") {
            self.faults_spec = Some(f.to_string());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn defaults_match_paper() {
        let c = TuneConfig::default();
        assert_eq!(c.branching, 2);
        assert!((c.exploration_c - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(c.history_depth, 2);
        assert_eq!(c.model, "gpt4o_mini");
    }

    #[test]
    fn from_doc_overrides() {
        let doc = Doc::parse(
            r#"
workload = "flux_conv"
platform = "m2_pro"
[search]
strategy = "es"
budget = 500
[mcts]
branching = 4
[llm]
model = "llama33_70b"
history_depth = 3
"#,
        )
        .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.strategy, Strategy::Evolutionary);
        assert_eq!(c.workload, "flux_conv");
        assert_eq!(c.platform, "m2_pro");
        assert_eq!(c.budget, 500);
        assert_eq!(c.branching, 4);
        assert_eq!(c.model, "llama33_70b");
        assert_eq!(c.history_depth, 3);
    }

    #[test]
    fn cli_overrides() {
        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --strategy mcts --budget 99 --platform graviton2 --history-depth 3"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert_eq!(c.strategy, Strategy::Mcts);
        assert_eq!(c.budget, 99);
        assert_eq!(c.platform, "graviton2");
        assert_eq!(c.history_depth, 3);
    }

    #[test]
    fn db_knobs_parse_and_override() {
        let c = TuneConfig::default();
        assert_eq!(c.db_path, None);
        assert!(c.warm_start);
        assert_eq!(c.warm_top_k, 8);

        let doc = Doc::parse(
            "[db]\npath = \"results/tuning_db.jsonl\"\nwarm_start = false\nwarm_top_k = 4\n",
        )
        .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.db_path.as_deref(), Some("results/tuning_db.jsonl"));
        assert!(!c.warm_start);
        assert_eq!(c.warm_top_k, 4);

        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --db /tmp/db.jsonl --no-warm-start --warm-top-k 3"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert_eq!(c.db_path.as_deref(), Some("/tmp/db.jsonl"));
        assert!(!c.warm_start);
        assert_eq!(c.warm_top_k, 3);

        let args = Args::parse("tune --no-db".split_whitespace().map(String::from));
        c.apply_cli(&args);
        assert_eq!(c.db_path, None);
    }

    #[test]
    fn transfer_knobs_parse_and_override() {
        let c = TuneConfig::default();
        assert!(c.transfer, "transfer defaults on (no-op without similar records)");
        assert_eq!(c.transfer_top_k, 4);
        assert!(!c.share_repeat_cache, "repeat independence is the default");

        let doc = Doc::parse(
            "[db]\ntransfer = false\ntransfer_top_k = 2\nshare_repeat_cache = true\n",
        )
        .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert!(!c.transfer);
        assert_eq!(c.transfer_top_k, 2);
        assert!(c.share_repeat_cache);

        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --no-transfer --transfer-top-k 7 --share-repeat-cache"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert!(!c.transfer);
        assert_eq!(c.transfer_top_k, 7);
        assert!(c.share_repeat_cache);

        let args = Args::parse("tune --transfer".split_whitespace().map(String::from));
        c.apply_cli(&args);
        assert!(c.transfer, "--transfer re-enables after --no-transfer");
    }

    #[test]
    fn transfer_index_knobs_parse_and_override() {
        let c = TuneConfig::default();
        assert!(c.transfer_index, "index defaults on (scan below threshold)");
        assert_eq!(c.transfer_index_threshold, 256);

        let doc =
            Doc::parse("[db]\ntransfer_index = false\ntransfer_index_threshold = 64\n")
                .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert!(!c.transfer_index);
        assert_eq!(c.transfer_index_threshold, 64);

        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --no-transfer-index --transfer-index-threshold 32"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert!(!c.transfer_index);
        assert_eq!(c.transfer_index_threshold, 32);

        let args =
            Args::parse("tune --transfer-index".split_whitespace().map(String::from));
        c.apply_cli(&args);
        assert!(c.transfer_index, "--transfer-index re-enables");
    }

    #[test]
    fn parallelism_knobs_parse_and_resolve() {
        let c = TuneConfig::default();
        assert_eq!(c.workers, 0, "default is auto");
        assert_eq!(c.eval_batch, 1, "default trajectory is serial");
        assert!(c.resolved_workers() >= 1);
        assert_eq!(c.resolved_eval_batch(), 1);

        let doc = Doc::parse("[search]\nworkers = 3\neval_batch = 2\n").unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.workers, 3);
        assert_eq!(c.resolved_workers(), 3, "explicit knob wins over env/auto");
        assert_eq!(c.resolved_eval_batch(), 2);

        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --workers 4 --eval-batch 0".split_whitespace().map(String::from),
        );
        c.apply_cli(&args);
        assert_eq!(c.resolved_workers(), 4);
        assert_eq!(c.resolved_eval_batch(), 4, "eval_batch=0 follows workers");
    }

    #[test]
    fn trace_knob_parses_and_overrides() {
        assert_eq!(TuneConfig::default().trace_path, None);
        let doc = Doc::parse("[obs]\ntrace = \"out/trace.json\"\n").unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.trace_path.as_deref(), Some("out/trace.json"));

        let mut c = TuneConfig::default();
        let args =
            Args::parse("tune --trace /tmp/t.json".split_whitespace().map(String::from));
        c.apply_cli(&args);
        assert_eq!(c.trace_path.as_deref(), Some("/tmp/t.json"));
    }

    #[test]
    fn audit_knob_parses_and_overrides() {
        assert_eq!(TuneConfig::default().audit_path, None);
        let doc = Doc::parse("[obs]\naudit = \"out/audit.jsonl\"\n").unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.audit_path.as_deref(), Some("out/audit.jsonl"));

        let mut c = TuneConfig::default();
        let args =
            Args::parse("tune --audit /tmp/a.jsonl".split_whitespace().map(String::from));
        c.apply_cli(&args);
        assert_eq!(c.audit_path.as_deref(), Some("/tmp/a.jsonl"));
    }

    #[test]
    fn resilience_knobs_parse_and_override() {
        let c = TuneConfig::default();
        assert_eq!(c.journal_path, None);
        assert_eq!(c.resume_from, None);
        assert_eq!(c.faults_spec, None);

        let doc = Doc::parse(
            "[session]\njournal = \"results/session.jsonl\"\n[faults]\nspec = \"llm_error=0.1\"\n",
        )
        .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.journal_path.as_deref(), Some("results/session.jsonl"));
        assert_eq!(c.faults_spec.as_deref(), Some("llm_error=0.1"));
        assert_eq!(c.resume_from, None, "resume is CLI-only");

        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --journal /tmp/j.jsonl --resume /tmp/j.jsonl --faults measure_fail=0.2,seed=9"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert_eq!(c.journal_path.as_deref(), Some("/tmp/j.jsonl"));
        assert_eq!(c.resume_from.as_deref(), Some("/tmp/j.jsonl"));
        assert_eq!(c.faults_spec.as_deref(), Some("measure_fail=0.2,seed=9"));
    }

    #[test]
    fn strategy_aliases() {
        assert_eq!(Strategy::from_name("tvm"), Some(Strategy::Evolutionary));
        assert_eq!(Strategy::from_name("rc"), Some(Strategy::LlmMcts));
        assert_eq!(Strategy::from_name("xx"), None);
    }
}
