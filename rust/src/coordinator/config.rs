//! Framework configuration: typed view over the TOML-subset files in
//! `configs/`, with CLI overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::tomlmini::Doc;

/// Which search strategy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Evolutionary,
    Mcts,
    LlmMcts,
}

impl Strategy {
    pub fn from_name(s: &str) -> Option<Strategy> {
        match s {
            "evolutionary" | "es" | "tvm" => Some(Strategy::Evolutionary),
            "mcts" => Some(Strategy::Mcts),
            "llm_mcts" | "rc" | "reasoning" => Some(Strategy::LlmMcts),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Evolutionary => "evolutionary",
            Strategy::Mcts => "mcts",
            Strategy::LlmMcts => "llm_mcts",
        }
    }

    pub fn display(&self) -> &'static str {
        match self {
            Strategy::Evolutionary => "Evolutionary Search",
            Strategy::Mcts => "MCTS",
            Strategy::LlmMcts => "REASONING COMPILER",
        }
    }
}

/// One tuning run's configuration.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub strategy: Strategy,
    pub workload: String,
    pub platform: String,
    /// Hardware-measurement budget (samples).
    pub budget: usize,
    /// Statistical repeats (paper: 20).
    pub repeats: usize,
    pub seed: u64,
    /// LLM model profile name (llm_mcts only).
    pub model: String,
    /// Prompt history depth: 2 = parent+grandparent (paper default).
    pub history_depth: usize,
    /// MCTS branching factor (paper: B = 2).
    pub branching: usize,
    /// UCT exploration constant (paper: sqrt(2)).
    pub exploration_c: f64,
    pub rollout_len: usize,
    pub max_trace_len: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            strategy: Strategy::LlmMcts,
            workload: "deepseek_moe".to_string(),
            platform: "core_i9".to_string(),
            budget: 200,
            repeats: 5,
            seed: 42,
            model: "gpt4o_mini".to_string(),
            history_depth: 2,
            branching: 2,
            exploration_c: std::f64::consts::SQRT_2,
            rollout_len: 4,
            max_trace_len: 24,
        }
    }
}

impl TuneConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<TuneConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Doc::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Self::from_doc(&doc))
    }

    pub fn from_doc(doc: &Doc) -> TuneConfig {
        let d = TuneConfig::default();
        TuneConfig {
            strategy: Strategy::from_name(doc.get_str("search.strategy", d.strategy.name()))
                .unwrap_or(d.strategy),
            workload: doc.get_str("workload", &d.workload).to_string(),
            platform: doc.get_str("platform", &d.platform).to_string(),
            budget: doc.get_usize("search.budget", d.budget),
            repeats: doc.get_usize("search.repeats", d.repeats),
            seed: doc.get_usize("search.seed", d.seed as usize) as u64,
            model: doc.get_str("llm.model", &d.model).to_string(),
            history_depth: doc.get_usize("llm.history_depth", d.history_depth),
            branching: doc.get_usize("mcts.branching", d.branching),
            exploration_c: doc.get_f64("mcts.exploration_c", d.exploration_c),
            rollout_len: doc.get_usize("mcts.rollout_len", d.rollout_len),
            max_trace_len: doc.get_usize("search.max_trace_len", d.max_trace_len),
        }
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_cli(&mut self, args: &crate::util::cli::Args) {
        if let Some(s) = args.opt("strategy").and_then(Strategy::from_name) {
            self.strategy = s;
        }
        if let Some(w) = args.opt("workload") {
            self.workload = w.to_string();
        }
        if let Some(p) = args.opt("platform") {
            self.platform = p.to_string();
        }
        self.budget = args.opt_usize("budget", self.budget);
        self.repeats = args.opt_usize("repeats", self.repeats);
        self.seed = args.opt_u64("seed", self.seed);
        if let Some(m) = args.opt("model") {
            self.model = m.to_string();
        }
        self.history_depth = args.opt_usize("history-depth", self.history_depth);
        self.branching = args.opt_usize("branching", self.branching);
        self.exploration_c = args.opt_f64("exploration-c", self.exploration_c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn defaults_match_paper() {
        let c = TuneConfig::default();
        assert_eq!(c.branching, 2);
        assert!((c.exploration_c - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(c.history_depth, 2);
        assert_eq!(c.model, "gpt4o_mini");
    }

    #[test]
    fn from_doc_overrides() {
        let doc = Doc::parse(
            r#"
workload = "flux_conv"
platform = "m2_pro"
[search]
strategy = "es"
budget = 500
[mcts]
branching = 4
[llm]
model = "llama33_70b"
history_depth = 3
"#,
        )
        .unwrap();
        let c = TuneConfig::from_doc(&doc);
        assert_eq!(c.strategy, Strategy::Evolutionary);
        assert_eq!(c.workload, "flux_conv");
        assert_eq!(c.platform, "m2_pro");
        assert_eq!(c.budget, 500);
        assert_eq!(c.branching, 4);
        assert_eq!(c.model, "llama33_70b");
        assert_eq!(c.history_depth, 3);
    }

    #[test]
    fn cli_overrides() {
        let mut c = TuneConfig::default();
        let args = Args::parse(
            "tune --strategy mcts --budget 99 --platform graviton2 --history-depth 3"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args);
        assert_eq!(c.strategy, Strategy::Mcts);
        assert_eq!(c.budget, 99);
        assert_eq!(c.platform, "graviton2");
        assert_eq!(c.history_depth, 3);
    }

    #[test]
    fn strategy_aliases() {
        assert_eq!(Strategy::from_name("tvm"), Some(Strategy::Evolutionary));
        assert_eq!(Strategy::from_name("rc"), Some(Strategy::LlmMcts));
        assert_eq!(Strategy::from_name("xx"), None);
    }
}
