//! Schedule transformations (the action space `O` of the paper's MDP).
//!
//! Every transform is a semantics-preserving rewrite of a stage's loop nest
//! (or a performance annotation). The names mirror the set the paper's
//! prompts expose: `TileSize`, `Reorder`, `Fuse`, `Parallel`, `Vectorize`,
//! `Unroll`, `ComputeLocation`, `CacheWrite`.

use crate::tir::expr::Expr;
use crate::tir::program::{LoopDef, LoopKind, Program, Stage};

/// One transformation. `stage` indexes `Program::stages`; `loop_idx`
/// indexes the stage's *current* loop nest (outermost = 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Transform {
    /// Split the loop into `(extent/factor, factor)`; `factor` must divide
    /// the extent. This is MetaSchedule's `sample_perfect_tile` step.
    TileSize { stage: usize, loop_idx: usize, factor: i64 },
    /// Permute the loop nest. `perm[i]` = old index of the loop now at `i`.
    Reorder { stage: usize, perm: Vec<usize> },
    /// Fuse loops `loop_idx` and `loop_idx + 1` into one.
    Fuse { stage: usize, loop_idx: usize },
    /// Mark a loop parallel (binds to worker threads).
    Parallel { stage: usize, loop_idx: usize },
    /// Mark a loop SIMD-vectorized (must be the innermost loop).
    Vectorize { stage: usize, loop_idx: usize },
    /// Mark a loop fully unrolled.
    Unroll { stage: usize, loop_idx: usize },
    /// Hoist output-tile init/write-back to the given loop depth.
    ComputeLocation { stage: usize, depth: usize },
    /// Accumulate into a register/L1-local buffer, write back once.
    CacheWrite { stage: usize },
}

/// Why a transform could not be applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ApplyError {
    BadStage(usize),
    BadLoop(usize),
    BadFactor { factor: i64, extent: i64 },
    TrivialFactor(i64),
    BadPerm(String),
    WrongKind { action: &'static str, kind: &'static str },
    ParallelReduction,
    ParallelNotPrefix,
    VectorizeReduction,
    VectorizeNotInnermost,
    VectorizeTooWide(i64),
    FuseNotSerial,
    BadDepth(usize),
    CacheWriteTwice,
    UnrollTooWide(i64),
}

// Hand-written Display/Error impls: proc-macro crates (thiserror) are kept
// out of the dependency tree so the crate builds in offline environments.
impl std::fmt::Display for ApplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApplyError::BadStage(i) => write!(f, "stage index {i} out of range"),
            ApplyError::BadLoop(i) => write!(f, "loop index {i} out of range"),
            ApplyError::BadFactor { factor, extent } => {
                write!(f, "factor {factor} does not divide extent {extent}")
            }
            ApplyError::TrivialFactor(x) => write!(f, "factor must be in 2..extent, got {x}"),
            ApplyError::BadPerm(why) => write!(f, "reorder permutation invalid: {why}"),
            ApplyError::WrongKind { action, kind } => {
                write!(f, "cannot {action} a {kind} loop")
            }
            ApplyError::ParallelReduction => write!(f, "cannot parallelize a reduction loop"),
            ApplyError::ParallelNotPrefix => {
                write!(f, "parallel loops must form an outermost prefix")
            }
            ApplyError::VectorizeReduction => write!(f, "cannot vectorize a reduction loop"),
            ApplyError::VectorizeNotInnermost => write!(f, "vectorized loop must be innermost"),
            ApplyError::VectorizeTooWide(x) => {
                write!(f, "vectorize extent {x} too large (max 64)")
            }
            ApplyError::FuseNotSerial => write!(f, "fuse requires two adjacent serial loops"),
            ApplyError::BadDepth(d) => write!(f, "compute location depth {d} out of range"),
            ApplyError::CacheWriteTwice => write!(f, "cache_write already applied"),
            ApplyError::UnrollTooWide(x) => write!(f, "unroll extent {x} too large (max 64)"),
        }
    }
}

impl std::error::Error for ApplyError {}

impl Transform {
    pub fn stage(&self) -> usize {
        match self {
            Transform::TileSize { stage, .. }
            | Transform::Reorder { stage, .. }
            | Transform::Fuse { stage, .. }
            | Transform::Parallel { stage, .. }
            | Transform::Vectorize { stage, .. }
            | Transform::Unroll { stage, .. }
            | Transform::ComputeLocation { stage, .. }
            | Transform::CacheWrite { stage } => *stage,
        }
    }

    /// Paper-facing operation name (what prompts list and the LLM emits).
    pub fn op_name(&self) -> &'static str {
        match self {
            Transform::TileSize { .. } => "TileSize",
            Transform::Reorder { .. } => "Reorder",
            Transform::Fuse { .. } => "Fuse",
            Transform::Parallel { .. } => "Parallel",
            Transform::Vectorize { .. } => "Vectorize",
            Transform::Unroll { .. } => "Unroll",
            Transform::ComputeLocation { .. } => "ComputeLocation",
            Transform::CacheWrite { .. } => "CacheWrite",
        }
    }

    /// All operation names, in the order prompts list them.
    pub const OP_NAMES: [&'static str; 8] = [
        "TileSize",
        "Reorder",
        "Fuse",
        "Parallel",
        "Vectorize",
        "Unroll",
        "ComputeLocation",
        "CacheWrite",
    ];

    /// Human-readable rendering used in traces and prompts, e.g.
    /// `TileSize(stage=moe, loop=j, factor=64)`.
    pub fn render(&self, program: &Program) -> String {
        let stage_name = |s: usize| {
            program
                .stages
                .get(s)
                .map(|st| st.name.clone())
                .unwrap_or_else(|| format!("#{s}"))
        };
        let loop_name = |s: usize, l: usize| {
            program
                .stages
                .get(s)
                .and_then(|st| st.loops.get(l))
                .map(|ld| ld.name.clone())
                .unwrap_or_else(|| format!("#{l}"))
        };
        match self {
            Transform::TileSize { stage, loop_idx, factor } => format!(
                "TileSize(stage={}, loop={}, factor={})",
                stage_name(*stage),
                loop_name(*stage, *loop_idx),
                factor
            ),
            Transform::Reorder { stage, perm } => {
                format!("Reorder(stage={}, perm={:?})", stage_name(*stage), perm)
            }
            Transform::Fuse { stage, loop_idx } => format!(
                "Fuse(stage={}, loops=[{}, {}])",
                stage_name(*stage),
                loop_name(*stage, *loop_idx),
                loop_name(*stage, *loop_idx + 1)
            ),
            Transform::Parallel { stage, loop_idx } => format!(
                "Parallel(stage={}, loop={})",
                stage_name(*stage),
                loop_name(*stage, *loop_idx)
            ),
            Transform::Vectorize { stage, loop_idx } => format!(
                "Vectorize(stage={}, loop={})",
                stage_name(*stage),
                loop_name(*stage, *loop_idx)
            ),
            Transform::Unroll { stage, loop_idx } => format!(
                "Unroll(stage={}, loop={})",
                stage_name(*stage),
                loop_name(*stage, *loop_idx)
            ),
            Transform::ComputeLocation { stage, depth } => format!(
                "ComputeLocation(stage={}, depth={})",
                stage_name(*stage),
                depth
            ),
            Transform::CacheWrite { stage } => {
                format!("CacheWrite(stage={})", stage_name(*stage))
            }
        }
    }

    /// Apply to a program, producing the transformed variant.
    ///
    /// Copy-on-write: cloning the program bumps `Arc` refcounts and only
    /// the touched stage is actually copied (`Stage::cow_mut`), so one tree
    /// edge costs O(stage), not O(program) — every untouched stage stays
    /// shared with the parent and all sibling variants.
    pub fn apply(&self, program: &Program) -> Result<Program, ApplyError> {
        let mut p = program.clone();
        let si = self.stage();
        if si >= p.stages.len() {
            return Err(ApplyError::BadStage(si));
        }
        let stage = Stage::cow_mut(&mut p.stages[si]);
        match self {
            Transform::TileSize { loop_idx, factor, .. } => {
                apply_tile(stage, *loop_idx, *factor)?
            }
            Transform::Reorder { perm, .. } => apply_reorder(stage, perm)?,
            Transform::Fuse { loop_idx, .. } => apply_fuse(stage, *loop_idx)?,
            Transform::Parallel { loop_idx, .. } => apply_parallel(stage, *loop_idx)?,
            Transform::Vectorize { loop_idx, .. } => apply_vectorize(stage, *loop_idx)?,
            Transform::Unroll { loop_idx, .. } => apply_unroll(stage, *loop_idx)?,
            Transform::ComputeLocation { depth, .. } => {
                if *depth > stage.loops.len() {
                    return Err(ApplyError::BadDepth(*depth));
                }
                stage.compute_at = Some(*depth);
            }
            Transform::CacheWrite { .. } => {
                if stage.cache_write {
                    return Err(ApplyError::CacheWriteTwice);
                }
                stage.cache_write = true;
            }
        }
        debug_assert!(p.validate().is_ok(), "transform broke invariants: {self:?}");
        Ok(p)
    }
}

fn apply_tile(stage: &mut Stage, loop_idx: usize, factor: i64) -> Result<(), ApplyError> {
    let l = stage
        .loops
        .get(loop_idx)
        .ok_or(ApplyError::BadLoop(loop_idx))?
        .clone();
    if l.kind != LoopKind::Serial {
        return Err(ApplyError::WrongKind { action: "tile", kind: l.kind.label() });
    }
    if factor < 2 || factor >= l.extent {
        return Err(ApplyError::TrivialFactor(factor));
    }
    if l.extent % factor != 0 {
        return Err(ApplyError::BadFactor { factor, extent: l.extent });
    }
    let outer_ext = l.extent / factor;
    let vo = stage.fresh_var(outer_ext);
    let vi = stage.fresh_var(factor);
    // old var := vo * factor + vi
    let replacement = Expr::add(Expr::mul(Expr::var(vo), factor), Expr::var(vi));
    for e in stage.axis_exprs.iter_mut() {
        *e = e.subst(l.var, &replacement);
    }
    let outer = LoopDef {
        var: vo,
        name: format!("{}_0", l.name),
        extent: outer_ext,
        kind: LoopKind::Serial,
    };
    let inner = LoopDef {
        var: vi,
        name: format!("{}_1", l.name),
        extent: factor,
        kind: LoopKind::Serial,
    };
    stage.loops.splice(loop_idx..=loop_idx, [outer, inner]);
    // compute_at depths beyond the split point shift by one.
    if let Some(d) = stage.compute_at {
        if d > loop_idx {
            stage.compute_at = Some(d + 1);
        }
    }
    Ok(())
}

fn apply_reorder(stage: &mut Stage, perm: &[usize]) -> Result<(), ApplyError> {
    let n = stage.loops.len();
    if perm.len() != n {
        return Err(ApplyError::BadPerm(format!("length {} != {}", perm.len(), n)));
    }
    let mut seen = vec![false; n];
    for &i in perm {
        if i >= n || seen[i] {
            return Err(ApplyError::BadPerm(format!("bad element {i}")));
        }
        seen[i] = true;
    }
    let new_loops: Vec<LoopDef> = perm.iter().map(|&i| stage.loops[i].clone()).collect();
    // Vectorized loops must stay innermost; parallel loops must stay an
    // outermost prefix (mirrors TVM's structural constraints).
    for (pos, l) in new_loops.iter().enumerate() {
        if l.kind == LoopKind::Vectorized && pos != n - 1 {
            return Err(ApplyError::VectorizeNotInnermost);
        }
    }
    let par_count = new_loops.iter().filter(|l| l.kind == LoopKind::Parallel).count();
    if par_count > 0 && !new_loops[..par_count].iter().all(|l| l.kind == LoopKind::Parallel) {
        return Err(ApplyError::ParallelNotPrefix);
    }
    stage.loops = new_loops;
    // Reorder invalidates a previously chosen compute location (TVM resets it).
    stage.compute_at = None;
    Ok(())
}

fn apply_fuse(stage: &mut Stage, loop_idx: usize) -> Result<(), ApplyError> {
    if loop_idx + 1 >= stage.loops.len() {
        return Err(ApplyError::BadLoop(loop_idx + 1));
    }
    let l1 = stage.loops[loop_idx].clone();
    let l2 = stage.loops[loop_idx + 1].clone();
    if l1.kind != LoopKind::Serial || l2.kind != LoopKind::Serial {
        return Err(ApplyError::FuseNotSerial);
    }
    let fused_ext = l1.extent * l2.extent;
    let vf = stage.fresh_var(fused_ext);
    // l1 := vf / e2 ; l2 := vf % e2
    let r1 = Expr::div(Expr::var(vf), l2.extent);
    let r2 = Expr::modulo(Expr::var(vf), l2.extent);
    for e in stage.axis_exprs.iter_mut() {
        *e = e.subst(l1.var, &r1).subst(l2.var, &r2);
    }
    let fused = LoopDef {
        var: vf,
        name: format!("{}_{}_f", l1.name, l2.name),
        extent: fused_ext,
        kind: LoopKind::Serial,
    };
    stage.loops.splice(loop_idx..=loop_idx + 1, [fused]);
    if let Some(d) = stage.compute_at {
        if d > loop_idx {
            stage.compute_at = Some(d.saturating_sub(1));
        }
    }
    Ok(())
}

fn apply_parallel(stage: &mut Stage, loop_idx: usize) -> Result<(), ApplyError> {
    let n = stage.loops.len();
    if loop_idx >= n {
        return Err(ApplyError::BadLoop(loop_idx));
    }
    if stage.loop_is_reduction(loop_idx) {
        return Err(ApplyError::ParallelReduction);
    }
    let l = &stage.loops[loop_idx];
    if l.kind != LoopKind::Serial {
        return Err(ApplyError::WrongKind { action: "parallelize", kind: l.kind.label() });
    }
    // Must extend the parallel prefix: every loop outside must already be parallel.
    if !stage.loops[..loop_idx].iter().all(|l| l.kind == LoopKind::Parallel) {
        return Err(ApplyError::ParallelNotPrefix);
    }
    stage.loops[loop_idx].kind = LoopKind::Parallel;
    Ok(())
}

fn apply_vectorize(stage: &mut Stage, loop_idx: usize) -> Result<(), ApplyError> {
    let n = stage.loops.len();
    if loop_idx >= n {
        return Err(ApplyError::BadLoop(loop_idx));
    }
    if loop_idx != n - 1 {
        return Err(ApplyError::VectorizeNotInnermost);
    }
    if stage.loop_is_reduction(loop_idx) {
        return Err(ApplyError::VectorizeReduction);
    }
    let l = &stage.loops[loop_idx];
    if l.kind != LoopKind::Serial {
        return Err(ApplyError::WrongKind { action: "vectorize", kind: l.kind.label() });
    }
    if l.extent > 64 {
        return Err(ApplyError::VectorizeTooWide(l.extent));
    }
    stage.loops[loop_idx].kind = LoopKind::Vectorized;
    Ok(())
}

fn apply_unroll(stage: &mut Stage, loop_idx: usize) -> Result<(), ApplyError> {
    let l = stage
        .loops
        .get(loop_idx)
        .ok_or(ApplyError::BadLoop(loop_idx))?;
    if l.kind != LoopKind::Serial {
        return Err(ApplyError::WrongKind { action: "unroll", kind: l.kind.label() });
    }
    if l.extent > 64 {
        return Err(ApplyError::UnrollTooWide(l.extent));
    }
    stage.loops[loop_idx].kind = LoopKind::Unrolled;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::interp;
    use crate::tir::workload;

    fn moe() -> Program {
        workload::moe_matmul("m", 4, 6, 8)
    }

    #[test]
    fn tile_splits_loop_and_preserves_semantics() {
        let p = moe();
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 3 }
            .apply(&p)
            .unwrap();
        assert_eq!(q.stages[0].loops.len(), 4);
        assert_eq!(q.stages[0].loops[1].name, "j_0");
        assert_eq!(q.stages[0].loops[2].name, "j_1");
        assert_eq!(q.stages[0].loops[1].extent, 2);
        assert_eq!(q.stages[0].loops[2].extent, 3);
        q.validate().unwrap();
        interp::iteration_space(&q.stages[0]).unwrap();
        assert!(interp::outputs_close(
            &interp::run_seeded(&p, 5),
            &interp::run_seeded(&q, 5),
            1e-4
        ));
    }

    #[test]
    fn tile_rejects_nondivisor_and_trivial() {
        let p = moe();
        assert_eq!(
            Transform::TileSize { stage: 0, loop_idx: 1, factor: 4 }.apply(&p).unwrap_err(),
            ApplyError::BadFactor { factor: 4, extent: 6 }
        );
        assert_eq!(
            Transform::TileSize { stage: 0, loop_idx: 1, factor: 1 }.apply(&p).unwrap_err(),
            ApplyError::TrivialFactor(1)
        );
        assert_eq!(
            Transform::TileSize { stage: 0, loop_idx: 1, factor: 6 }.apply(&p).unwrap_err(),
            ApplyError::TrivialFactor(6)
        );
    }

    #[test]
    fn reorder_permutes_and_preserves_semantics() {
        let p = moe();
        let q = Transform::Reorder { stage: 0, perm: vec![2, 0, 1] }
            .apply(&p)
            .unwrap();
        assert_eq!(q.stages[0].loops[0].name, "k");
        interp::iteration_space(&q.stages[0]).unwrap();
        assert!(interp::outputs_close(
            &interp::run_seeded(&p, 6),
            &interp::run_seeded(&q, 6),
            1e-4
        ));
    }

    #[test]
    fn reorder_rejects_bad_perm() {
        let p = moe();
        assert!(Transform::Reorder { stage: 0, perm: vec![0, 1] }.apply(&p).is_err());
        assert!(Transform::Reorder { stage: 0, perm: vec![0, 0, 1] }.apply(&p).is_err());
    }

    #[test]
    fn fuse_preserves_semantics() {
        let p = moe();
        let q = Transform::Fuse { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        assert_eq!(q.stages[0].loops.len(), 2);
        assert_eq!(q.stages[0].loops[0].extent, 24);
        interp::iteration_space(&q.stages[0]).unwrap();
        assert!(interp::outputs_close(
            &interp::run_seeded(&p, 7),
            &interp::run_seeded(&q, 7),
            1e-4
        ));
    }

    #[test]
    fn parallel_requires_prefix_and_non_reduction() {
        let p = moe();
        // k (idx 2) is reduction.
        assert_eq!(
            Transform::Parallel { stage: 0, loop_idx: 2 }.apply(&p).unwrap_err(),
            ApplyError::ParallelReduction
        );
        // j (idx 1) without t parallel first: not a prefix.
        assert_eq!(
            Transform::Parallel { stage: 0, loop_idx: 1 }.apply(&p).unwrap_err(),
            ApplyError::ParallelNotPrefix
        );
        // t then j: fine.
        let q = Transform::Parallel { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        let q = Transform::Parallel { stage: 0, loop_idx: 1 }.apply(&q).unwrap();
        assert_eq!(q.stages[0].loops[1].kind, LoopKind::Parallel);
    }

    #[test]
    fn vectorize_innermost_only_non_reduction() {
        let p = moe();
        // Innermost is k, a reduction: rejected.
        assert_eq!(
            Transform::Vectorize { stage: 0, loop_idx: 2 }.apply(&p).unwrap_err(),
            ApplyError::VectorizeReduction
        );
        // Move j innermost, then vectorize.
        let q = Transform::Reorder { stage: 0, perm: vec![0, 2, 1] }.apply(&p).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 2 }.apply(&q).unwrap();
        assert_eq!(q.stages[0].loops[2].kind, LoopKind::Vectorized);
        // Not innermost: rejected.
        assert_eq!(
            Transform::Vectorize { stage: 0, loop_idx: 0 }.apply(&p).unwrap_err(),
            ApplyError::VectorizeNotInnermost
        );
    }

    #[test]
    fn reorder_keeps_vectorized_innermost() {
        let p = moe();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 2, 1] }.apply(&p).unwrap();
        let q = Transform::Vectorize { stage: 0, loop_idx: 2 }.apply(&q).unwrap();
        // Moving the vectorized loop out is illegal.
        assert_eq!(
            Transform::Reorder { stage: 0, perm: vec![2, 0, 1] }.apply(&q).unwrap_err(),
            ApplyError::VectorizeNotInnermost
        );
    }

    #[test]
    fn unroll_limits() {
        let p = moe();
        let q = Transform::Unroll { stage: 0, loop_idx: 0 }.apply(&p).unwrap();
        assert_eq!(q.stages[0].loops[0].kind, LoopKind::Unrolled);
        let big = workload::moe_matmul("big", 4, 6, 128);
        assert_eq!(
            Transform::Unroll { stage: 0, loop_idx: 2 }.apply(&big).unwrap_err(),
            ApplyError::UnrollTooWide(128)
        );
    }

    #[test]
    fn cache_write_once() {
        let p = moe();
        let q = Transform::CacheWrite { stage: 0 }.apply(&p).unwrap();
        assert!(q.stages[0].cache_write);
        assert_eq!(
            Transform::CacheWrite { stage: 0 }.apply(&q).unwrap_err(),
            ApplyError::CacheWriteTwice
        );
    }

    #[test]
    fn compute_location_bounds() {
        let p = moe();
        assert!(Transform::ComputeLocation { stage: 0, depth: 2 }.apply(&p).is_ok());
        assert!(Transform::ComputeLocation { stage: 0, depth: 9 }.apply(&p).is_err());
    }

    #[test]
    fn tile_then_fuse_chain_preserves_semantics() {
        let p = moe();
        let q = Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }.apply(&p).unwrap();
        let q = Transform::TileSize { stage: 0, loop_idx: 1, factor: 2 }.apply(&q).unwrap();
        let q = Transform::Reorder { stage: 0, perm: vec![0, 1, 3, 2, 4] }.apply(&q).unwrap();
        let q = Transform::Fuse { stage: 0, loop_idx: 0 }.apply(&q).unwrap();
        q.validate().unwrap();
        interp::iteration_space(&q.stages[0]).unwrap();
        assert!(interp::outputs_close(
            &interp::run_seeded(&p, 8),
            &interp::run_seeded(&q, 8),
            1e-4
        ));
    }

    #[test]
    fn render_names_loops() {
        let p = moe();
        let t = Transform::TileSize { stage: 0, loop_idx: 1, factor: 3 };
        assert_eq!(t.render(&p), "TileSize(stage=moe, loop=j, factor=3)");
    }
}
