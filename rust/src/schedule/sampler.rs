//! Random legal-transform sampling.
//!
//! This is the *uninformed* proposal policy: vanilla MCTS expansion and
//! rollouts, Evolutionary Search mutation, and the fallback path when all
//! LLM proposals are invalid (Appendix G) all draw from here.

use crate::tir::program::{LoopKind, Program, Stage};
use crate::util::rng::Pcg;

use super::transform::Transform;

/// Proper divisors d of n with 2 <= d < n.
pub fn divisors(n: i64) -> Vec<i64> {
    let mut out = Vec::new();
    let mut d = 2;
    while d < n {
        if n % d == 0 {
            out.push(d);
        }
        d += 1;
        if d > 512 {
            // Large extents: cap the scan, keep power-of-two-ish factors.
            let mut k = 512;
            while k < n {
                if n % k == 0 {
                    out.push(k);
                }
                k *= 2;
            }
            break;
        }
    }
    out
}

/// Enumerate every legal transform for the program, bounded per category so
/// the list stays small for big nests (Reorder alternatives are sampled, not
/// enumerated exhaustively).
pub fn legal_transforms(program: &Program, rng: &mut Pcg) -> Vec<Transform> {
    let mut out = Vec::new();
    for (si, stage) in program.stages.iter().enumerate() {
        legal_for_stage(program, stage, si, rng, &mut out);
    }
    out
}

fn legal_for_stage(
    _program: &Program,
    stage: &Stage,
    si: usize,
    rng: &mut Pcg,
    out: &mut Vec<Transform>,
) {
    let n = stage.loops.len();

    // TileSize: every serial loop x a few divisors.
    for (li, l) in stage.loops.iter().enumerate() {
        if l.kind != LoopKind::Serial {
            continue;
        }
        let divs = divisors(l.extent);
        if divs.is_empty() {
            continue;
        }
        // Keep at most 4 candidate factors per loop to bound the action set.
        if divs.len() <= 4 {
            for f in divs {
                out.push(Transform::TileSize { stage: si, loop_idx: li, factor: f });
            }
        } else {
            let mut picked = std::collections::BTreeSet::new();
            // Always include a small and a large factor, then random fill.
            picked.insert(divs[0]);
            picked.insert(divs[divs.len() - 1]);
            while picked.len() < 4 {
                picked.insert(*rng.choose(&divs));
            }
            for f in picked {
                out.push(Transform::TileSize { stage: si, loop_idx: li, factor: f });
            }
        }
    }

    // Reorder: a handful of random legal permutations (plus reduction-
    // outward and reduction-inward canonical moves).
    if n >= 2 {
        for _ in 0..3 {
            let perm = random_legal_perm(stage, rng);
            if perm.iter().enumerate().any(|(i, &p)| i != p) {
                out.push(Transform::Reorder { stage: si, perm });
            }
        }
    }

    // Fuse: adjacent serial pairs.
    for li in 0..n.saturating_sub(1) {
        if stage.loops[li].kind == LoopKind::Serial && stage.loops[li + 1].kind == LoopKind::Serial
        {
            out.push(Transform::Fuse { stage: si, loop_idx: li });
        }
    }

    // Parallel: the first non-parallel loop, if legal.
    let prefix = stage
        .loops
        .iter()
        .take_while(|l| l.kind == LoopKind::Parallel)
        .count();
    if prefix < n
        && stage.loops[prefix].kind == LoopKind::Serial
        && !stage.loop_is_reduction(prefix)
    {
        out.push(Transform::Parallel { stage: si, loop_idx: prefix });
    }

    // Vectorize: innermost loop.
    if n > 0 {
        let li = n - 1;
        let l = &stage.loops[li];
        if l.kind == LoopKind::Serial && !stage.loop_is_reduction(li) && l.extent <= 64 {
            out.push(Transform::Vectorize { stage: si, loop_idx: li });
        }
    }

    // Unroll: small serial loops.
    for (li, l) in stage.loops.iter().enumerate() {
        if l.kind == LoopKind::Serial && l.extent <= 64 {
            out.push(Transform::Unroll { stage: si, loop_idx: li });
        }
    }

    // ComputeLocation: a few depths.
    for depth in [n / 2, n.saturating_sub(1)] {
        if depth > 0 && depth <= n && stage.compute_at != Some(depth) {
            out.push(Transform::ComputeLocation { stage: si, depth });
        }
    }

    // CacheWrite.
    if !stage.cache_write {
        out.push(Transform::CacheWrite { stage: si });
    }
}

/// A random permutation that respects the structural constraints:
/// parallel prefix stays in place, vectorized loop stays innermost.
fn random_legal_perm(stage: &Stage, rng: &mut Pcg) -> Vec<usize> {
    let n = stage.loops.len();
    let prefix = stage
        .loops
        .iter()
        .take_while(|l| l.kind == LoopKind::Parallel)
        .count();
    let vec_tail = usize::from(n > 0 && stage.loops[n - 1].kind == LoopKind::Vectorized);
    let mut middle: Vec<usize> = (prefix..n - vec_tail).collect();
    rng.shuffle(&mut middle);
    let mut perm: Vec<usize> = (0..prefix).collect();
    perm.extend(middle);
    perm.extend(n - vec_tail..n);
    perm
}

/// Draw one random legal transform. Returns None only if the action set is
/// empty (fully annotated nest — practically unreachable).
pub fn random_transform(program: &Program, rng: &mut Pcg) -> Option<Transform> {
    let actions = legal_transforms(program, rng);
    if actions.is_empty() {
        return None;
    }
    Some(rng.choose(&actions).clone())
}

/// Draw a random sequence of `len` legal transforms, applying as it goes so
/// every element is legal in context (the MCTS rollout policy).
pub fn random_sequence(program: &Program, len: usize, rng: &mut Pcg) -> Vec<Transform> {
    let mut out = Vec::with_capacity(len);
    let mut cur = program.clone();
    for _ in 0..len {
        match random_transform(&cur, rng) {
            Some(t) => match t.apply(&cur) {
                Ok(next) => {
                    cur = next;
                    out.push(t);
                }
                Err(_) => continue,
            },
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload;
    use crate::util::rng::Pcg;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(12), vec![2, 3, 4, 6]);
        assert_eq!(divisors(7), Vec::<i64>::new());
        assert_eq!(divisors(2), Vec::<i64>::new());
    }

    #[test]
    fn divisors_large_extent_capped() {
        let d = divisors(7168);
        assert!(!d.is_empty());
        assert!(d.iter().all(|&x| 7168 % x == 0 && x >= 2 && x < 7168));
    }

    #[test]
    fn all_enumerated_transforms_apply_cleanly() {
        let mut rng = Pcg::new(1);
        for w in workload::WorkloadId::ALL {
            let p = w.build_test();
            for t in legal_transforms(&p, &mut rng) {
                t.apply(&p)
                    .unwrap_or_else(|e| panic!("{}: {t:?} illegal: {e}", w.name()));
            }
        }
    }

    #[test]
    fn random_sequence_all_legal() {
        let mut rng = Pcg::new(2);
        let p = workload::WorkloadId::DeepSeekMoe.build_test();
        for _ in 0..10 {
            let seq = random_sequence(&p, 6, &mut rng);
            // Apply the whole sequence: every element must be legal in order.
            let mut cur = p.clone();
            for t in &seq {
                cur = t.apply(&cur).expect("sequence element illegal");
            }
            cur.validate().unwrap();
        }
    }

    #[test]
    fn random_sequences_differ_across_seeds() {
        let p = workload::WorkloadId::Llama4Mlp.build_test();
        let a = random_sequence(&p, 5, &mut Pcg::new(3));
        let b = random_sequence(&p, 5, &mut Pcg::new(4));
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = workload::WorkloadId::FluxConv.build_test();
        let a = random_sequence(&p, 5, &mut Pcg::new(11));
        let b = random_sequence(&p, 5, &mut Pcg::new(11));
        assert_eq!(a, b);
    }
}
