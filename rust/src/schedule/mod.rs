//! Schedule engine: the transformation algebra over TIR programs.
//!
//! [`Transform`] is the action space of the paper's MDP; [`Schedule`] pairs
//! a base program with its transformation trace (replayable, fingerprinted
//! for MCTS dedup); [`sampler`] provides the uninformed random policy used
//! by vanilla MCTS, ES mutation, rollouts and the LLM fallback path.

pub mod sampler;
pub mod trace;
pub mod transform;

pub use trace::Schedule;
pub use transform::{ApplyError, Transform};
