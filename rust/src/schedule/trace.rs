//! Transformation traces: the ordered sequence `S_i` of transforms that
//! produced a program variant, with deterministic replay.
//!
//! Traces are the genome of Evolutionary Search, the path labels of the
//! MCTS tree, and the "applied schedule history" serialized into prompts.

use std::sync::Arc;

use crate::tir::Program;
use crate::util::pvec::PVec;

use super::transform::{ApplyError, Transform};

/// A schedule: the original program, the transform sequence applied so far,
/// and the resulting current program.
///
/// Cloning a schedule happens on every search-tree edge, so all three
/// pieces are structurally shared: the base program sits behind an `Arc`,
/// `current` is a CoW program (untouched stages shared with the parent and
/// every sibling), and the trace + its rendered text are persistent chunked
/// vectors ([`PVec`]) whose immutable prefix is shared — extending a
/// depth-L trace costs O(L/chunk) reference bumps, not O(L) deep copies
/// (the former O(L²) growth; see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Shared, immutable original program (Arc: schedules are cloned on
    /// every tree edge, so the base must not be deep-copied each time).
    pub base: Arc<Program>,
    pub trace: PVec<Transform>,
    pub current: Program,
    /// Human-readable rendering of each trace step against the program it
    /// was applied to, built incrementally at apply time so prompts don't
    /// replay the whole trace.
    trace_text: PVec<String>,
}

impl Schedule {
    pub fn new(base: Program) -> Schedule {
        Schedule {
            current: base.clone(),
            base: Arc::new(base),
            trace: PVec::new(),
            trace_text: PVec::new(),
        }
    }

    /// Build from an already-shared base (avoids re-wrapping).
    pub fn new_shared(base: Arc<Program>) -> Schedule {
        Schedule {
            current: (*base).clone(),
            base,
            trace: PVec::new(),
            trace_text: PVec::new(),
        }
    }

    /// Apply one transform, extending the trace (`S_{i+1} = S_i ++ [o]`).
    pub fn apply(&self, t: Transform) -> Result<Schedule, ApplyError> {
        let next = t.apply(&self.current)?;
        let mut trace = self.trace.clone();
        let mut trace_text = self.trace_text.clone();
        trace_text.push(t.render(&self.current));
        trace.push(t);
        Ok(Schedule { base: self.base.clone(), trace, current: next, trace_text })
    }

    /// Apply a sequence; stops at the first failure, returning how many
    /// transforms were applied (partial application is how ES mutation and
    /// MCTS rollouts tolerate invalid tails).
    pub fn apply_all(&self, ts: &[Transform]) -> (Schedule, usize) {
        let mut cur = self.clone();
        let mut applied = 0;
        for t in ts {
            match cur.apply(t.clone()) {
                Ok(next) => {
                    cur = next;
                    applied += 1;
                }
                Err(_) => break,
            }
        }
        (cur, applied)
    }

    /// Replay the trace from the base program; must reproduce `current`.
    pub fn replay(&self) -> Result<Program, ApplyError> {
        let mut p = (*self.base).clone();
        for t in self.trace.iter() {
            p = t.apply(&p)?;
        }
        Ok(p)
    }

    pub fn len(&self) -> usize {
        self.trace.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trace.is_empty()
    }

    /// Render the trace as numbered lines for prompts/logs. Each step was
    /// rendered at apply time against the program state it actually saw, so
    /// this is O(L) string work, not O(L) transform replays.
    pub fn render_trace(&self) -> String {
        if self.trace.is_empty() {
            return "  (no transformations applied)".to_string();
        }
        self.trace_text
            .iter()
            .enumerate()
            .map(|(i, t)| format!("  {}. {t}", i + 1))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Structural fingerprint of the current program — used by MCTS to
    /// detect that a proposed child already exists (the tree must stay
    /// acyclic / deduplicated, §3.2).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut feed = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3);
        };
        for s in &self.current.stages {
            for l in &s.loops {
                feed(l.extent as u64);
                feed(l.kind as u64 + 1);
                for b in l.name.bytes() {
                    feed(b as u64);
                }
            }
            feed(s.cache_write as u64 + 17);
            feed(s.compute_at.map(|d| d as u64 + 1).unwrap_or(0));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload;

    fn sched() -> Schedule {
        Schedule::new(workload::moe_matmul("m", 4, 6, 8))
    }

    #[test]
    fn apply_extends_trace() {
        let s = sched();
        let s1 = s
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        assert_eq!(s1.len(), 1);
        assert_eq!(s1.current.stages[0].loops.len(), 4);
        // Parent unchanged.
        assert_eq!(s.len(), 0);
        assert_eq!(s.current.stages[0].loops.len(), 3);
    }

    #[test]
    fn replay_reproduces_current() {
        let s = sched()
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap()
            .apply(Transform::Reorder { stage: 0, perm: vec![0, 2, 1, 3] })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        let replayed = s.replay().unwrap();
        // Same loop structure.
        let a: Vec<_> = replayed.stages[0].loops.iter().map(|l| (l.name.clone(), l.extent, l.kind)).collect();
        let b: Vec<_> = s.current.stages[0].loops.iter().map(|l| (l.name.clone(), l.extent, l.kind)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn apply_all_partial() {
        let s = sched();
        let ts = vec![
            Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 },
            Transform::TileSize { stage: 0, loop_idx: 99, factor: 2 }, // invalid
            Transform::Parallel { stage: 0, loop_idx: 0 },
        ];
        let (out, applied) = s.apply_all(&ts);
        assert_eq!(applied, 1);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn fingerprint_distinguishes_schedules() {
        let s = sched();
        let s1 = s.apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }).unwrap();
        let s2 = s.apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 2 }).unwrap();
        assert_ne!(s.fingerprint(), s1.fingerprint());
        assert_ne!(s1.fingerprint(), s2.fingerprint());
        // Same sequence -> same fingerprint.
        let s1b = s.apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }).unwrap();
        assert_eq!(s1.fingerprint(), s1b.fingerprint());
    }

    #[test]
    fn deep_chain_crosses_chunk_boundaries_and_replays() {
        // Deep traces exercise the persistent-vector chunk seams: a chain
        // well past one chunk must keep trace, text and replay coherent.
        use crate::schedule::sampler;
        use crate::util::rng::Pcg;
        let mut s = Schedule::new(workload::moe_matmul("m", 64, 96, 128));
        let mut rng = Pcg::new(3);
        let mut guard = 0;
        while s.len() < 40 && guard < 4000 {
            guard += 1;
            if let Some(t) = sampler::random_transform(&s.current, &mut rng) {
                if let Ok(next) = s.apply(t) {
                    s = next;
                }
            }
        }
        assert!(s.len() >= 40, "could not build a deep trace (got {})", s.len());
        let lines = s.render_trace();
        assert_eq!(lines.lines().count(), s.len(), "one rendered line per step");
        let replayed = s.replay().unwrap();
        let a: Vec<_> = replayed.stages[0].loops.iter().map(|l| (l.extent, l.kind)).collect();
        let b: Vec<_> = s.current.stages[0].loops.iter().map(|l| (l.extent, l.kind)).collect();
        assert_eq!(a, b, "replay must reproduce the deep schedule");
    }

    #[test]
    fn render_trace_numbered() {
        let s = sched()
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        let text = s.render_trace();
        assert!(text.contains("1. TileSize(stage=moe, loop=k, factor=4)"));
        assert!(text.contains("2. Parallel(stage=moe, loop=t)"));
        assert!(sched().render_trace().contains("no transformations"));
    }
}
