//! HNSW-style approximate-nearest-neighbor index over tuning-record
//! feature vectors, plus the record-aging policy shared by retrieval
//! and `db gc`.
//!
//! The linear scan in [`super::similarity`] is exact but O(records) on
//! every session start; at fleet scale (ROADMAP item 4) the db holds
//! millions of records and the scan dominates session startup. This
//! module indexes records **per `(shape_class, platform)` partition**
//! — so every candidate the graph returns is already a legal rebase
//! target — over the raw per-axis log2-extent vector of each record
//! (role-agnostic: computable from a record's `extents` alone, without
//! a target program). Queries navigate the graph to collect an
//! `ef`-wide candidate set; the caller re-ranks those candidates with
//! the *exact* role-aware feature distance, so whenever the candidate
//! set covers the true top-k the results are bit-identical to the
//! scan. Partitions no larger than the candidate width are searched
//! exhaustively, which makes small-db retrieval exactly equal to the
//! scan by construction.
//!
//! ## Determinism
//!
//! Nothing here touches wall clocks or RNG state. Layer assignment
//! hashes the node ordinal (splitmix64 trailing zeros), every heap
//! tie breaks on node index, and candidates are returned in file
//! (position) order so the downstream stable sort reproduces the
//! scan's tie-breaks.
//!
//! ## Sidecar persistence
//!
//! The graph is persisted as a JSON sidecar next to the JSONL db
//! (`<db>.idx`). The db stays the only source of truth: the sidecar
//! stores just the adjacency lists and per-partition entry points,
//! stamped with the db's byte length and record count. On load,
//! vectors, latencies and aging flags are re-derived from the live
//! records and every stored position is re-validated; any mismatch —
//! stale stamp, malformed JSON, out-of-range position, eligibility
//! drift — silently falls back to a full rebuild. Losing or
//! corrupting the sidecar can never lose data or fail a command.
//!
//! ## Aging
//!
//! A record is *superseded* when a fresher record (later timestamp,
//! position as tie-break) of the same `(workload_fp, platform)` pair
//! reached an equal-or-lower latency. Superseded records stay in the
//! db and the index but carry [`STALE_DISTANCE_PENALTY`] at ranking
//! time, so a stale record never outranks its successor at equal
//! shape distance; `rcc db gc --reap-dominated` drops them for real.
//! Both retrieval paths (scan and index) compute the flag from the
//! same relation — the scan via [`dominated_positions`], the index
//! incrementally as entries register — so rankings agree.

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::path::{Path, PathBuf};

use crate::db::TuningRecord;
use crate::util::json::{self, Json};

/// Max neighbors kept per node on the upper layers.
const M: usize = 8;
/// Max neighbors kept per node on the base layer.
const M0: usize = 16;
/// Candidate-list width while building the graph.
const EF_CONSTRUCTION: usize = 40;
/// Minimum candidate-list width at query time (grows with k).
const EF_SEARCH: usize = 64;
/// Hard cap on layer assignment (log4 of any plausible record count).
const MAX_LEVEL: u32 = 12;

/// Distance penalty added at ranking time to superseded records.
/// Structural distances are small (log2-extent space), so one full
/// unit reliably demotes a stale record behind its fresher successor
/// without ejecting it from the candidate list entirely.
pub const STALE_DISTANCE_PENALTY: f64 = 1.0;

/// A record is eligible for the index when it carries real transfer
/// metadata (PR 4+) and a non-empty trace. The same predicate gates
/// the scan path's aging flags and `db gc --reap-dominated`.
pub fn record_eligible(r: &TuningRecord) -> bool {
    r.shape_class != 0 && !r.extents.is_empty() && !r.trace.is_empty()
}

/// Records persisted before PR 4 decode with sentinel shape metadata;
/// they can never be rebased, so the index excludes them (counted,
/// warned about once — never per record).
pub fn record_is_sentinel(r: &TuningRecord) -> bool {
    r.shape_class == 0 || r.extents.is_empty()
}

/// Role-agnostic navigation vector: per-axis log2 extents, flattened
/// in stage order. This is the prefix of the exact feature vector in
/// `similarity.rs` (which appends role-aware per-stage sums that need
/// a target program); it is computable from a record's `extents`
/// alone, which is what lets the index build without any query.
pub fn raw_log_vector(extents: &[Vec<i64>]) -> Vec<f64> {
    let mut v = Vec::with_capacity(extents.iter().map(Vec::len).sum());
    for stage in extents {
        for &e in stage {
            v.push((e.max(1) as f64).log2());
        }
    }
    v
}

/// Positions of records strictly dominated by a fresher record of the
/// same `(workload_fp, platform)` pair — the exact-scan counterpart of
/// the index's incremental flags, also used by `db gc
/// --reap-dominated`. Only eligible records participate (a sentinel or
/// trace-less record neither dominates nor is reaped).
pub fn dominated_positions(records: &[TuningRecord]) -> BTreeSet<usize> {
    let mut groups: BTreeMap<(u64, &str), Vec<usize>> = BTreeMap::new();
    for (i, r) in records.iter().enumerate() {
        if record_eligible(r) {
            groups.entry((r.workload_fp, r.platform.as_str())).or_default().push(i);
        }
    }
    let mut out = BTreeSet::new();
    for idxs in groups.values() {
        let mut order = idxs.clone();
        order.sort_by_key(|&i| (records[i].timestamp, i));
        let mut best_fresher = f64::INFINITY;
        for &i in order.iter().rev() {
            if best_fresher <= records[i].latency {
                out.insert(i);
            }
            if records[i].latency < best_fresher {
                best_fresher = records[i].latency;
            }
        }
    }
    out
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic geometric layer assignment (p = 1/4 per level) from
/// the node's insertion ordinal — no RNG state, no wall clock.
fn assign_level(ordinal: u32) -> u32 {
    (splitmix64(ordinal as u64).trailing_zeros() / 2).min(MAX_LEVEL)
}

fn l2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Heap element: distance with a node-index tie-break so every
/// ordering decision is total and deterministic.
#[derive(Clone, Copy, PartialEq)]
struct Scored(f64, u32);

impl Eq for Scored {}

impl Ord for Scored {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl PartialOrd for Scored {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Clone)]
struct Entry {
    /// Record position in the db (file order).
    pos: u32,
    fp: u64,
    latency: f64,
    timestamp: u64,
    superseded: bool,
    vec: Vec<f64>,
}

#[derive(Debug, Clone)]
struct Node {
    /// Index into `TransferIndex::entries`.
    entry: u32,
    level: u32,
    /// `neighbors[l]` = node indices adjacent at layer `l` (0..=level).
    neighbors: Vec<Vec<u32>>,
}

#[derive(Debug, Clone, Default)]
struct Partition {
    dims: usize,
    nodes: Vec<Node>,
    entry_point: u32,
    max_level: u32,
    /// Entry indices grouped by workload fingerprint — drives the
    /// incremental superseded-flag maintenance on insert.
    by_fp: BTreeMap<u64, Vec<u32>>,
}

impl Partition {
    fn greedy_descend(&self, entries: &[Entry], q: &[f64], mut ep: u32, level: usize) -> u32 {
        let mut best = l2(q, &entries[self.nodes[ep as usize].entry as usize].vec);
        loop {
            let mut improved = false;
            for &nb in &self.nodes[ep as usize].neighbors[level] {
                let d = l2(q, &entries[self.nodes[nb as usize].entry as usize].vec);
                if d < best {
                    best = d;
                    ep = nb;
                    improved = true;
                }
            }
            if !improved {
                return ep;
            }
        }
    }

    /// Best-first beam search at one layer; returns up to `ef` nodes
    /// sorted by (distance, node index).
    fn search_layer(&self, entries: &[Entry], q: &[f64], eps: &[u32], ef: usize, level: usize) -> Vec<Scored> {
        let mut visited = vec![false; self.nodes.len()];
        let mut frontier: BinaryHeap<Reverse<Scored>> = BinaryHeap::new();
        let mut best: BinaryHeap<Scored> = BinaryHeap::new();
        for &ep in eps {
            if std::mem::replace(&mut visited[ep as usize], true) {
                continue;
            }
            let d = l2(q, &entries[self.nodes[ep as usize].entry as usize].vec);
            frontier.push(Reverse(Scored(d, ep)));
            best.push(Scored(d, ep));
            if best.len() > ef {
                best.pop();
            }
        }
        while let Some(Reverse(Scored(d, n))) = frontier.pop() {
            let worst = best.peek().map_or(f64::INFINITY, |s| s.0);
            if best.len() >= ef && d > worst {
                break;
            }
            for &nb in &self.nodes[n as usize].neighbors[level] {
                if std::mem::replace(&mut visited[nb as usize], true) {
                    continue;
                }
                let dn = l2(q, &entries[self.nodes[nb as usize].entry as usize].vec);
                let worst = best.peek().map_or(f64::INFINITY, |s| s.0);
                if best.len() < ef || dn < worst {
                    frontier.push(Reverse(Scored(dn, nb)));
                    best.push(Scored(dn, nb));
                    if best.len() > ef {
                        best.pop();
                    }
                }
            }
        }
        let mut out = best.into_vec();
        out.sort();
        out
    }

    fn insert_node(&mut self, entries: &[Entry], entry_idx: u32) {
        let ordinal = self.nodes.len() as u32;
        let level = assign_level(ordinal);
        let mut neighbors: Vec<Vec<u32>> = vec![Vec::new(); level as usize + 1];
        if self.nodes.is_empty() {
            self.nodes.push(Node { entry: entry_idx, level, neighbors });
            self.entry_point = 0;
            self.max_level = level;
            return;
        }
        let q = entries[entry_idx as usize].vec.clone();
        let mut ep = self.entry_point;
        let mut lvl = self.max_level;
        while lvl > level {
            ep = self.greedy_descend(entries, &q, ep, lvl as usize);
            lvl -= 1;
        }
        let top = level.min(self.max_level);
        let mut eps = vec![ep];
        for l in (0..=top).rev() {
            let found = self.search_layer(entries, &q, &eps, EF_CONSTRUCTION, l as usize);
            let cap = if l == 0 { M0 } else { M };
            neighbors[l as usize] = found.iter().take(cap).map(|s| s.1).collect();
            eps = found.iter().map(|s| s.1).collect();
        }
        self.nodes.push(Node { entry: entry_idx, level, neighbors });
        for l in 0..=top {
            let cap = if l == 0 { M0 } else { M };
            for nb in self.nodes[ordinal as usize].neighbors[l as usize].clone() {
                let mut list = self.nodes[nb as usize].neighbors[l as usize].clone();
                list.push(ordinal);
                if list.len() > cap {
                    let nb_vec = &entries[self.nodes[nb as usize].entry as usize].vec;
                    let mut scored: Vec<Scored> = list
                        .iter()
                        .map(|&m| Scored(l2(nb_vec, &entries[self.nodes[m as usize].entry as usize].vec), m))
                        .collect();
                    scored.sort();
                    list = scored.into_iter().take(cap).map(|s| s.1).collect();
                }
                self.nodes[nb as usize].neighbors[l as usize] = list;
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry_point = ordinal;
        }
    }
}

/// Candidate returned by [`TransferIndex::query`]: a record position
/// plus its aging flag, in file order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub pos: usize,
    pub superseded: bool,
}

#[derive(Debug, Clone)]
pub struct TransferIndex {
    threshold: usize,
    /// Number of db records processed so far (file order), including
    /// skipped ones — the incremental high-water mark.
    covered: usize,
    sentinel_skipped: usize,
    layout_skipped: usize,
    loaded_from_sidecar: bool,
    entries: Vec<Entry>,
    parts: BTreeMap<(u64, String), Partition>,
}

impl TransferIndex {
    /// Build from scratch over the given records.
    pub fn build(records: &[TuningRecord], threshold: usize) -> TransferIndex {
        let mut ix = TransferIndex {
            threshold,
            covered: 0,
            sentinel_skipped: 0,
            layout_skipped: 0,
            loaded_from_sidecar: false,
            entries: Vec::new(),
            parts: BTreeMap::new(),
        };
        ix.extend_from(records);
        ix
    }

    /// Index every record not yet covered (`records[self.covered..]`)
    /// — called after each db commit so the index grows with the file.
    pub fn extend_from(&mut self, records: &[TuningRecord]) {
        for pos in self.covered..records.len() {
            self.insert_record(records, pos);
        }
        self.covered = records.len();
    }

    fn insert_record(&mut self, records: &[TuningRecord], pos: usize) {
        let r = &records[pos];
        if record_is_sentinel(r) {
            self.sentinel_skipped += 1;
            return;
        }
        if r.trace.is_empty() {
            return; // nothing to transfer; never a match candidate
        }
        let vec = raw_log_vector(&r.extents);
        let part = self.parts.entry((r.shape_class, r.platform.clone())).or_default();
        if part.nodes.is_empty() {
            part.dims = vec.len();
        } else if part.dims != vec.len() {
            self.layout_skipped += 1;
            return;
        }
        let entry = Entry {
            pos: pos as u32,
            fp: r.workload_fp,
            latency: r.latency,
            timestamp: r.timestamp,
            superseded: false,
            vec,
        };
        let entry_idx = register_entry(&mut self.entries, part, entry);
        part.insert_node(&self.entries, entry_idx);
    }

    /// Candidate positions for a query vector, in file order. Exact
    /// (exhaustive) for partitions no larger than the search width;
    /// graph-navigated beyond that. The caller re-ranks with the exact
    /// feature distance.
    pub fn query(&self, class: u64, platform: &str, qvec: &[f64], k: usize) -> Vec<Candidate> {
        let Some(part) = self.parts.get(&(class, platform.to_string())) else {
            return Vec::new();
        };
        if part.nodes.is_empty() || part.dims != qvec.len() {
            return Vec::new();
        }
        let ef = EF_SEARCH.max(k.saturating_mul(4));
        let found: Vec<u32> = if part.nodes.len() <= ef {
            (0..part.nodes.len() as u32).collect()
        } else {
            let mut ep = part.entry_point;
            let mut lvl = part.max_level;
            while lvl > 0 {
                ep = part.greedy_descend(&self.entries, qvec, ep, lvl as usize);
                lvl -= 1;
            }
            part.search_layer(&self.entries, qvec, &[ep], ef, 0)
                .into_iter()
                .map(|s| s.1)
                .collect()
        };
        let mut out: Vec<Candidate> = found
            .iter()
            .map(|&n| {
                let e = &self.entries[part.nodes[n as usize].entry as usize];
                Candidate { pos: e.pos as usize, superseded: e.superseded }
            })
            .collect();
        out.sort_by_key(|c| c.pos);
        out
    }

    /// Records indexed (eligible entries, not raw db length).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }

    pub fn covered(&self) -> usize {
        self.covered
    }

    pub fn sentinel_skipped(&self) -> usize {
        self.sentinel_skipped
    }

    pub fn loaded_from_sidecar(&self) -> bool {
        self.loaded_from_sidecar
    }

    pub fn partitions(&self) -> usize {
        self.parts.len()
    }

    /// Persist the graph as a sidecar next to the db file. Stores only
    /// adjacency + entry points; vectors and aging flags are re-derived
    /// from the db on load, which stays the single source of truth.
    pub fn save(&self, db_path: &Path) -> std::io::Result<()> {
        let db_bytes = std::fs::metadata(db_path).map(|m| m.len()).unwrap_or(0);
        let mut root = Json::obj();
        root.set("rcc_transfer_index", json::num(1.0));
        root.set("db_bytes", json::num(db_bytes as f64));
        root.set("records", json::num(self.covered as f64));
        root.set("sentinel_skipped", json::num(self.sentinel_skipped as f64));
        root.set("layout_skipped", json::num(self.layout_skipped as f64));
        let parts: Vec<Json> = self
            .parts
            .iter()
            .map(|((class, platform), p)| {
                let mut pj = Json::obj();
                pj.set("class", json::s(&format!("{class:016x}")));
                pj.set("platform", json::s(platform));
                pj.set("entry_point", json::num(p.entry_point as f64));
                pj.set("max_level", json::num(p.max_level as f64));
                let nodes: Vec<Json> = p
                    .nodes
                    .iter()
                    .map(|n| {
                        let mut nj = Json::obj();
                        nj.set("pos", json::num(self.entries[n.entry as usize].pos as f64));
                        nj.set("level", json::num(n.level as f64));
                        nj.set(
                            "nbrs",
                            json::arr(
                                n.neighbors
                                    .iter()
                                    .map(|l| json::arr(l.iter().map(|&x| json::num(x as f64)).collect()))
                                    .collect(),
                            ),
                        );
                        nj
                    })
                    .collect();
                pj.set("nodes", json::arr(nodes));
                pj
            })
            .collect();
        root.set("parts", json::arr(parts));
        // Temp sibling + atomic rename: a crash mid-save must never leave
        // a torn sidecar. (`load` would reject one anyway and rebuild, but
        // a half-written file that happens to parse is the failure mode
        // worth closing off for good.)
        let path = sidecar_path(db_path);
        let tmp = path.with_extension("idx.tmp");
        std::fs::write(&tmp, root.to_string())?;
        std::fs::rename(&tmp, &path)
    }

    /// Load the sidecar, re-validating it against the live records.
    /// Returns `None` — caller rebuilds — on any staleness or
    /// malformation: this path must never be fatal.
    pub fn load(db_path: &Path, records: &[TuningRecord], threshold: usize) -> Option<TransferIndex> {
        let raw = std::fs::read_to_string(sidecar_path(db_path)).ok()?;
        let root = Json::parse(&raw)?;
        if root.get("rcc_transfer_index")?.as_f64()? != 1.0 {
            return None;
        }
        let db_bytes = std::fs::metadata(db_path).ok()?.len();
        if root.get("db_bytes")?.as_f64()? != db_bytes as f64 {
            return None;
        }
        if root.get("records")?.as_f64()? != records.len() as f64 {
            return None;
        }
        let stored_layout_skipped = root.get("layout_skipped")?.as_f64()? as usize;
        let stored_sentinel_skipped = root.get("sentinel_skipped")?.as_f64()? as usize;
        let mut ix = TransferIndex {
            threshold,
            covered: records.len(),
            sentinel_skipped: 0,
            layout_skipped: stored_layout_skipped,
            loaded_from_sidecar: true,
            entries: Vec::new(),
            parts: BTreeMap::new(),
        };
        let mut seen_pos: BTreeSet<usize> = BTreeSet::new();
        for pj in root.get("parts")?.as_arr()? {
            let class = u64::from_str_radix(pj.get("class")?.as_str()?, 16).ok()?;
            let platform = pj.get("platform")?.as_str()?.to_string();
            let nodes_json = pj.get("nodes")?.as_arr()?;
            let mut part = Partition {
                entry_point: pj.get("entry_point")?.as_f64()? as u32,
                max_level: pj.get("max_level")?.as_f64()? as u32,
                ..Partition::default()
            };
            let node_count = nodes_json.len();
            for nj in nodes_json {
                let pos = nj.get("pos")?.as_f64()? as usize;
                let r = records.get(pos)?;
                if !record_eligible(r) || r.shape_class != class || r.platform != platform {
                    return None;
                }
                if !seen_pos.insert(pos) {
                    return None;
                }
                let vec = raw_log_vector(&r.extents);
                if part.nodes.is_empty() {
                    part.dims = vec.len();
                } else if part.dims != vec.len() {
                    return None;
                }
                let level = nj.get("level")?.as_f64()? as u32;
                let mut neighbors: Vec<Vec<u32>> = Vec::new();
                for lj in nj.get("nbrs")?.as_arr()? {
                    let mut layer = Vec::new();
                    for x in lj.as_arr()? {
                        let idx = x.as_f64()? as usize;
                        if idx >= node_count {
                            return None;
                        }
                        layer.push(idx as u32);
                    }
                    neighbors.push(layer);
                }
                if neighbors.len() != level as usize + 1 {
                    return None;
                }
                let entry = Entry {
                    pos: pos as u32,
                    fp: r.workload_fp,
                    latency: r.latency,
                    timestamp: r.timestamp,
                    superseded: false,
                    vec,
                };
                let entry_idx = register_entry(&mut ix.entries, &mut part, entry);
                part.nodes.push(Node { entry: entry_idx, level, neighbors });
            }
            if !part.nodes.is_empty() && part.entry_point as usize >= part.nodes.len() {
                return None;
            }
            if ix.parts.insert((class, platform), part).is_some() {
                return None;
            }
        }
        // The eligible set must match the db exactly — a record added,
        // dropped or rewritten since the save invalidates the graph.
        let mut want_entries = 0usize;
        let mut want_sentinels = 0usize;
        for r in records {
            if record_is_sentinel(r) {
                want_sentinels += 1;
            } else if !r.trace.is_empty() {
                want_entries += 1;
            }
        }
        if ix.entries.len() + stored_layout_skipped != want_entries
            || stored_sentinel_skipped != want_sentinels
        {
            return None;
        }
        ix.sentinel_skipped = want_sentinels;
        Some(ix)
    }
}

/// Append an entry, updating aging flags pairwise within its
/// `(workload_fp, platform)` group. Order-independent: each pair is
/// compared exactly once with explicit (timestamp, position)
/// freshness, so build, load and incremental insert all converge on
/// the same flags as [`dominated_positions`].
fn register_entry(entries: &mut Vec<Entry>, part: &mut Partition, mut entry: Entry) -> u32 {
    let idx = entries.len() as u32;
    let group = part.by_fp.entry(entry.fp).or_default();
    for &old in group.iter() {
        let o = &mut entries[old as usize];
        let new_fresher = (entry.timestamp, entry.pos) > (o.timestamp, o.pos);
        if new_fresher {
            if entry.latency <= o.latency {
                o.superseded = true;
            }
        } else if o.latency <= entry.latency {
            entry.superseded = true;
        }
    }
    group.push(idx);
    entries.push(entry);
    idx
}

/// `<db>.idx` — the sidecar lives next to the JSONL file it indexes.
pub fn sidecar_path(db_path: &Path) -> PathBuf {
    let mut name = db_path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".idx");
    db_path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;

    fn rec(fp: u64, platform: &str, class: u64, extents: Vec<Vec<i64>>, latency: f64, ts: u64) -> TuningRecord {
        TuningRecord {
            workload_fp: fp,
            workload: format!("w{fp:x}"),
            platform: platform.into(),
            strategy: "test".into(),
            trace: vec![Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }],
            latency,
            baseline_latency: 10.0,
            seed: 0,
            timestamp: ts,
            shape_class: class,
            extents,
        }
    }

    fn grid_records(n: usize, platform: &str) -> Vec<TuningRecord> {
        (0..n)
            .map(|i| {
                let a = 1 << (i % 10);
                let b = 1 << ((i / 10) % 10);
                let c = 1 << ((i / 100) % 10);
                rec(0x1000 + i as u64, platform, 0xC1A55, vec![vec![a, b, c]], 1.0 + i as f64, i as u64)
            })
            .collect()
    }

    #[test]
    fn raw_log_vector_flattens_per_axis_logs() {
        let v = raw_log_vector(&[vec![8, 2], vec![16]]);
        assert_eq!(v, vec![3.0, 1.0, 4.0]);
        // Degenerate extents clamp to zero instead of -inf.
        assert_eq!(raw_log_vector(&[vec![0]]), vec![0.0]);
    }

    #[test]
    fn level_assignment_is_deterministic_and_bounded() {
        for ord in 0..10_000u32 {
            let l = assign_level(ord);
            assert_eq!(l, assign_level(ord));
            assert!(l <= MAX_LEVEL);
        }
        // The distribution actually uses more than one layer.
        assert!((0..10_000u32).any(|o| assign_level(o) > 0));
    }

    #[test]
    fn small_partition_query_is_exhaustive_in_file_order() {
        let records = grid_records(12, "core_i9");
        let ix = TransferIndex::build(&records, 0);
        assert_eq!(ix.len(), 12);
        let got = ix.query(0xC1A55, "core_i9", &raw_log_vector(&[vec![4, 4, 4]]), 4);
        let pos: Vec<usize> = got.iter().map(|c| c.pos).collect();
        assert_eq!(pos, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn partitions_split_by_class_and_platform() {
        let mut records = grid_records(4, "core_i9");
        records.extend(grid_records(4, "graviton2"));
        records.push(rec(0x9999, "core_i9", 0xD00D, vec![vec![2, 2]], 1.0, 0));
        let ix = TransferIndex::build(&records, 0);
        assert_eq!(ix.partitions(), 3);
        assert!(ix.query(0xC1A55, "graviton2", &raw_log_vector(&[vec![4, 4, 4]]), 4).len() == 4);
        assert!(ix.query(0xD00D, "core_i9", &raw_log_vector(&[vec![2, 2]]), 4).len() == 1);
        // Unknown partition or mismatched query layout: empty, not a panic.
        assert!(ix.query(0xBEEF, "core_i9", &[0.0], 4).is_empty());
        assert!(ix.query(0xC1A55, "core_i9", &[0.0], 4).is_empty());
    }

    #[test]
    fn sentinel_records_are_counted_not_indexed() {
        let mut records = grid_records(3, "core_i9");
        records.push(rec(0x1, "core_i9", 0, Vec::new(), 1.0, 0));
        let mut legacy = rec(0x2, "core_i9", 0xC1A55, Vec::new(), 1.0, 0);
        legacy.extents = Vec::new();
        records.push(legacy);
        let ix = TransferIndex::build(&records, 0);
        assert_eq!(ix.len(), 3);
        assert_eq!(ix.sentinel_skipped(), 2);
    }

    #[test]
    fn aging_flags_match_dominated_positions_in_any_insert_order() {
        // Same fp, out-of-order timestamps: the fresher (ts=200) record
        // at an equal latency supersedes the older one regardless of
        // file position.
        let records = vec![
            rec(0xAA, "core_i9", 0xC1A55, vec![vec![4, 4, 4]], 5.0, 200),
            rec(0xAA, "core_i9", 0xC1A55, vec![vec![4, 4, 4]], 5.0, 100),
            rec(0xAA, "core_i9", 0xC1A55, vec![vec![4, 4, 4]], 4.0, 150),
            rec(0xBB, "core_i9", 0xC1A55, vec![vec![8, 8, 8]], 9.0, 50),
        ];
        let dominated = dominated_positions(&records);
        // pos1 (ts=100, 5.0): superseded by pos2 (ts=150, 4.0) and pos0.
        // pos2 (ts=150, 4.0): no fresher record at <= 4.0. pos0
        // (ts=200, 5.0): freshest of its group. pos3: alone.
        assert_eq!(dominated.into_iter().collect::<Vec<_>>(), vec![1]);
        let ix = TransferIndex::build(&records, 0);
        let flags: Vec<bool> = ix
            .query(0xC1A55, "core_i9", &raw_log_vector(&[vec![4, 4, 4]]), 8)
            .iter()
            .map(|c| c.superseded)
            .collect();
        assert_eq!(flags, vec![false, true, false, false]);
    }

    #[test]
    fn graph_query_recalls_brute_force_neighbors_at_scale() {
        let records = grid_records(600, "core_i9");
        let ix = TransferIndex::build(&records, 0);
        let q = raw_log_vector(&[vec![16, 32, 2]]);
        let got = ix.query(0xC1A55, "core_i9", &q, 8);
        assert!(got.len() >= 8 && got.len() <= 600);
        // Deterministic: same query, same candidates.
        assert_eq!(got, ix.query(0xC1A55, "core_i9", &q, 8));
        // The exact nearest neighbor must be in the candidate set.
        let best = (0..records.len())
            .min_by(|&a, &b| {
                l2(&q, &raw_log_vector(&records[a].extents))
                    .total_cmp(&l2(&q, &raw_log_vector(&records[b].extents)))
                    .then(a.cmp(&b))
            })
            .unwrap();
        assert!(got.iter().any(|c| c.pos == best));
    }

    #[test]
    fn sidecar_roundtrip_and_staleness() {
        let dir = std::env::temp_dir().join(format!("rcc_idx_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let db_path = dir.join("db.jsonl");
        std::fs::write(&db_path, b"fake-db-bytes\n").unwrap();
        let mut records = grid_records(30, "core_i9");
        let ix = TransferIndex::build(&records, 7);
        ix.save(&db_path).unwrap();
        let loaded = TransferIndex::load(&db_path, &records, 7).expect("fresh sidecar loads");
        assert!(loaded.loaded_from_sidecar());
        assert_eq!(loaded.len(), ix.len());
        assert_eq!(loaded.threshold(), 7);
        let q = raw_log_vector(&[vec![4, 2, 1]]);
        assert_eq!(loaded.query(0xC1A55, "core_i9", &q, 5), ix.query(0xC1A55, "core_i9", &q, 5));
        // Record count drift -> stale -> rebuild.
        records.push(rec(0x7777, "core_i9", 0xC1A55, vec![vec![2, 2, 2]], 1.0, 99));
        assert!(TransferIndex::load(&db_path, &records, 7).is_none());
        records.pop();
        // Db byte drift -> stale.
        std::fs::write(&db_path, b"fake-db-bytes-grew\n").unwrap();
        assert!(TransferIndex::load(&db_path, &records, 7).is_none());
        std::fs::write(&db_path, b"fake-db-bytes\n").unwrap();
        assert!(TransferIndex::load(&db_path, &records, 7).is_some());
        // Garbage sidecar -> rebuild, never fatal.
        std::fs::write(sidecar_path(&db_path), b"{not json").unwrap();
        assert!(TransferIndex::load(&db_path, &records, 7).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn extend_from_matches_full_rebuild() {
        let records = grid_records(50, "core_i9");
        let mut incremental = TransferIndex::build(&records[..20], 0);
        incremental.extend_from(&records);
        let full = TransferIndex::build(&records, 0);
        let q = raw_log_vector(&[vec![8, 8, 8]]);
        assert_eq!(incremental.covered(), full.covered());
        assert_eq!(incremental.len(), full.len());
        assert_eq!(
            incremental.query(0xC1A55, "core_i9", &q, 6),
            full.query(0xC1A55, "core_i9", &q, 6)
        );
    }
}
