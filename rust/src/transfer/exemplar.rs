//! Few-shot exemplar engine for the LLM proposal policy.
//!
//! The paper's sample-efficiency argument rests on the proposal mechanism
//! conditioning on accumulated performance feedback. This module turns the
//! tuning database into that feedback: for the target workload's shape
//! class it selects the top-k *diverse* (workload, trace, speedup) triples,
//! rebases each trace onto the target program (so every exemplar the model
//! sees is legal where it stands), and renders them as the prompt block
//! `reasoning::prompt::render_with` embeds. The simulated engine
//! additionally grounds proposals directly in exemplar traces
//! (`reasoning::engine`), closing the loop the paper prescribes.
//!
//! **Selection policy** (deterministic): candidates come from
//! [`super::similarity::find_matches`] ordered by feature distance then
//! recorded speedup; one exemplar per source workload fingerprint is taken
//! first (diversity across workloads), then remaining slots fill with
//! distinct rebased traces from already-used workloads. Exemplars whose
//! trace rebases to nothing are skipped.
//!
//! **Bottleneck conditioning**: when the platform is known, matches are
//! first bucketed by whether their trace attacks the target's dominant
//! cost-model bottleneck — compute-bound programs (arithmetic intensity
//! above the platform's roofline ridge) prefer exemplars containing
//! parallelize/vectorize/unroll steps, traffic-bound programs prefer
//! tiling/reordering/fusion/locality steps — with the distance/speedup
//! ranking preserved *within* each bucket (stable sort), so shape
//! similarity still decides among equally relevant exemplars.

use crate::cost::{features, Platform};
use crate::db::Database;
use crate::schedule::{Schedule, Transform};
use crate::tir::Program;

use super::rebase::rebase_trace;
use super::similarity::{find_matches, TransferMatch};

/// One few-shot exemplar: a proven optimization from a structurally
/// similar workload, rebased onto the target program.
#[derive(Debug, Clone)]
pub struct Exemplar {
    /// Source workload name (display only; selection keys on fingerprints).
    pub workload: String,
    /// Speedup the source run measured for the original trace.
    pub speedup: f64,
    /// Feature distance between source and target workloads.
    pub distance: f64,
    /// The trace rebased onto the target program — applies fully there.
    pub trace: Vec<Transform>,
    /// Human-readable numbered rendering of `trace` against the target.
    pub rendered: String,
}

/// Which side of the platform roofline the target program sits on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    /// Arithmetic intensity at or above the ridge point: FLOP-limited.
    Compute,
    /// Below the ridge: DRAM-traffic-limited.
    Traffic,
}

/// Classify the target's dominant bottleneck against the platform's
/// roofline ridge point (peak FLOP/s over DRAM bytes/s). Deterministic
/// and read-only — reuses the cost model's feature extraction.
pub fn classify_bottleneck(program: &Program, platform: &Platform) -> Bottleneck {
    let f = features::extract(program, platform);
    let peak_flops = platform.cores as f64
        * platform.simd_lanes as f64
        * platform.fma_ports as f64
        * 2.0
        * platform.freq_ghz
        * 1e9;
    let ridge = peak_flops / (platform.dram_gbps * 1e9);
    if f.arithmetic_intensity >= ridge {
        Bottleneck::Compute
    } else {
        Bottleneck::Traffic
    }
}

/// Does this transform primarily attack the given bottleneck? Tiling,
/// reordering, fusion and locality transforms reshape memory traffic;
/// parallelization, vectorization and unrolling raise compute
/// throughput.
fn attacks(t: &Transform, b: Bottleneck) -> bool {
    let traffic = matches!(
        t,
        Transform::TileSize { .. }
            | Transform::Reorder { .. }
            | Transform::Fuse { .. }
            | Transform::ComputeLocation { .. }
            | Transform::CacheWrite { .. }
    );
    match b {
        Bottleneck::Traffic => traffic,
        Bottleneck::Compute => !traffic,
    }
}

/// Select up to `k` diverse exemplars for `target` on `platform`,
/// bottleneck-conditioned when the platform is a known hardware model.
pub fn select_exemplars(
    db: &Database,
    target: &Program,
    platform: &str,
    k: usize,
) -> Vec<Exemplar> {
    // Over-fetch so dropped/duplicate rebases don't starve the selection.
    let matches = find_matches(db, target, platform, k.saturating_mul(4).max(8));
    match Platform::by_name(platform) {
        Some(p) => exemplars_for(&matches, target, &p, k),
        None => exemplars_from_matches(&matches, target, k),
    }
}

/// [`exemplars_from_matches`] conditioned on the target's dominant
/// cost-model bottleneck: matches whose traces contain at least one
/// transform attacking it are preferred, with the distance/speedup
/// ranking preserved within each bucket (stable sort).
pub fn exemplars_for(
    matches: &[TransferMatch],
    target: &Program,
    platform: &Platform,
    k: usize,
) -> Vec<Exemplar> {
    let bottleneck = classify_bottleneck(target, platform);
    let mut ordered = matches.to_vec();
    ordered.sort_by_key(|m| !m.record.trace.iter().any(|t| attacks(t, bottleneck)));
    exemplars_from_matches(&ordered, target, k)
}

/// [`select_exemplars`] over an already-computed match set — callers that
/// also derive warm starts (`super::derive_hints`) scan and rank the
/// database once and reuse the matches here.
pub fn exemplars_from_matches(
    matches: &[super::similarity::TransferMatch],
    target: &Program,
    k: usize,
) -> Vec<Exemplar> {
    let base = Schedule::new(target.clone());
    let mut out: Vec<Exemplar> = Vec::new();
    let mut used_workloads: Vec<u64> = Vec::new();
    let mut used_traces: Vec<Vec<Transform>> = Vec::new();
    // Pass 1: one exemplar per source workload; pass 2: fill remaining
    // slots with distinct traces regardless of source.
    for workload_diverse in [true, false] {
        for m in matches {
            if out.len() >= k {
                break;
            }
            if workload_diverse && used_workloads.contains(&m.record.workload_fp) {
                continue;
            }
            let rebased = rebase_trace(target, &m.record.trace);
            if rebased.trace.is_empty() || used_traces.contains(&rebased.trace) {
                continue;
            }
            let (replayed, applied) = base.apply_all(&rebased.trace);
            debug_assert_eq!(applied, rebased.trace.len(), "rebase legality contract");
            used_workloads.push(m.record.workload_fp);
            used_traces.push(rebased.trace.clone());
            out.push(Exemplar {
                workload: m.record.workload.clone(),
                speedup: m.record.speedup(),
                distance: m.distance,
                rendered: replayed.render_trace(),
                trace: rebased.trace,
            });
        }
    }
    out
}

/// Render exemplars as the prompt block embedded by
/// `reasoning::prompt::render_with` and printed by `rcc transfer
/// exemplars`. Empty input renders to an empty string.
pub fn render_exemplar_block(exemplars: &[Exemplar]) -> String {
    if exemplars.is_empty() {
        return String::new();
    }
    let mut out = String::from(
        "Accumulated performance feedback from structurally similar workloads \
         (few-shot exemplars, transformation sequences rebased to this program):\n",
    );
    for (i, ex) in exemplars.iter().enumerate() {
        out.push_str(&format!(
            "Exemplar {}: workload {} reached {:.2}x speedup (structural distance {:.2}):\n{}\n",
            i + 1,
            ex.workload,
            ex.speedup,
            ex.distance,
            ex.rendered
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fingerprint::{shape_class, workload_fingerprint};
    use crate::db::TuningRecord;
    use crate::tir::workload;
    use crate::transfer::similarity::workload_extents;

    fn rec(program: &Program, trace: Vec<Transform>, latency: f64) -> TuningRecord {
        TuningRecord {
            workload_fp: workload_fingerprint(program),
            workload: program.name.clone(),
            platform: "core_i9".to_string(),
            strategy: "test".to_string(),
            trace,
            latency,
            baseline_latency: 10.0,
            seed: 1,
            timestamp: 100,
            shape_class: shape_class(program),
            extents: workload_extents(program),
        }
    }

    #[test]
    fn selects_diverse_legal_exemplars() {
        let target = workload::moe_matmul("target", 16, 256, 128);
        let src_a = workload::moe_matmul("src_a", 16, 1024, 512);
        let src_b = workload::moe_matmul("src_b", 32, 512, 256);
        let mut db = Database::in_memory();
        db.add(rec(
            &src_a,
            vec![
                Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 },
                Transform::Parallel { stage: 0, loop_idx: 0 },
            ],
            2.0,
        ));
        db.add(rec(
            &src_a,
            vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 32 }],
            3.0,
        ));
        db.add(rec(
            &src_b,
            vec![Transform::Unroll { stage: 0, loop_idx: 0 }],
            4.0,
        ));

        let ex = select_exemplars(&db, &target, "core_i9", 2);
        assert_eq!(ex.len(), 2);
        // Diversity: the two exemplars come from the two distinct sources,
        // even though src_a has two records.
        let mut names: Vec<&str> = ex.iter().map(|e| e.workload.as_str()).collect();
        names.sort();
        assert_eq!(names, vec!["src_a", "src_b"]);
        // Every exemplar trace applies fully on the target.
        let base = Schedule::new(target.clone());
        for e in &ex {
            let (_, applied) = base.apply_all(&e.trace);
            assert_eq!(applied, e.trace.len());
            assert!(!e.rendered.is_empty());
            assert!(e.speedup > 1.0);
        }

        // With k=3 the second src_a record fills the remaining slot.
        let ex3 = select_exemplars(&db, &target, "core_i9", 3);
        assert_eq!(ex3.len(), 3);
    }

    #[test]
    fn bottleneck_conditioning_prefers_relevant_traces() {
        let target = workload::moe_matmul("target", 16, 256, 128);
        let src_near = workload::moe_matmul("src_near", 16, 512, 256);
        let src_far = workload::moe_matmul("src_far", 64, 2048, 1024);
        let mut db = Database::in_memory();
        // The *nearest* source carries a pure traffic trace, the farther
        // one a pure compute trace — so whichever way the classifier
        // rules, conditioning picks by relevance while the plain
        // selection keeps distance order.
        db.add(rec(
            &src_near,
            vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 }],
            2.0,
        ));
        db.add(rec(
            &src_far,
            vec![Transform::Parallel { stage: 0, loop_idx: 0 }],
            2.0,
        ));
        let platform = Platform::by_name("core_i9").unwrap();
        let matches = find_matches(&db, &target, "core_i9", 8);
        assert_eq!(matches.len(), 2);
        let verdict = classify_bottleneck(&target, &platform);
        assert_eq!(verdict, classify_bottleneck(&target, &platform), "deterministic");
        let ex = exemplars_for(&matches, &target, &platform, 1);
        assert_eq!(ex.len(), 1);
        match verdict {
            Bottleneck::Traffic => assert_eq!(ex[0].workload, "src_near"),
            Bottleneck::Compute => assert_eq!(ex[0].workload, "src_far"),
        }
        // Unconditioned selection keeps pure distance order.
        let plain = exemplars_from_matches(&matches, &target, 1);
        assert_eq!(plain[0].workload, "src_near");
        // Conditioning reorders but never loses exemplars: with room
        // for both, both sources appear.
        assert_eq!(exemplars_for(&matches, &target, &platform, 2).len(), 2);
    }

    #[test]
    fn render_block_lists_speedups() {
        let target = workload::moe_matmul("target", 16, 256, 128);
        let src = workload::moe_matmul("src", 16, 512, 256);
        let mut db = Database::in_memory();
        db.add(rec(
            &src,
            vec![Transform::Parallel { stage: 0, loop_idx: 0 }],
            2.5,
        ));
        let ex = select_exemplars(&db, &target, "core_i9", 4);
        let block = render_exemplar_block(&ex);
        assert!(block.contains("Exemplar 1: workload src reached 4.00x"));
        assert!(block.contains("Parallel"));
        assert!(render_exemplar_block(&[]).is_empty());
    }

    #[test]
    fn empty_db_yields_no_exemplars() {
        let target = workload::moe_matmul("target", 16, 256, 128);
        let db = Database::in_memory();
        assert!(select_exemplars(&db, &target, "core_i9", 4).is_empty());
    }
}
