//! Trace rebasing: replay a recorded transform trace onto a structurally
//! similar but differently-sized program.
//!
//! A trace recorded on `matmul 1024^3` does not replay verbatim on
//! `matmul 512^3`: tile factors may no longer divide the new extents,
//! loop/stage indices may dangle after a dropped step, and annotation
//! limits (vectorize/unroll width ≤ 64) bind at different sizes. The
//! rebaser walks the trace step by step against the *target* program and
//! produces the longest legal adaptation:
//!
//! - **TileSize** factors that no longer divide the target loop's extent
//!   are rescaled to the nearest legal divisor (counted in
//!   [`RebaseOutcome::adjusted`]); loops too small to tile drop the step.
//! - Steps referencing a stage or loop the target does not have are
//!   dropped ([`RebaseOutcome::dropped`]) — a dangling reference is never
//!   emitted.
//! - Every surviving step is validated through `Transform::apply`, which
//!   enforces all remaining legality rules (reorder permutation arity,
//!   parallel-prefix, vectorize-innermost, the ≤ 64 vectorize/unroll width
//!   caps). Steps it rejects are dropped.
//!
//! The output trace therefore **always replays fully** on the target
//! program — `Schedule::apply_all(&outcome.trace)` applies every step —
//! which is the legality contract `rust/tests/transfer_tuning.rs` pins
//! with a property test over random traces and shapes.

use crate::schedule::{sampler, Transform};
use crate::tir::Program;

/// Result of rebasing one trace onto a target program.
#[derive(Debug, Clone, Default)]
pub struct RebaseOutcome {
    /// The adapted trace; applies fully on the target by construction.
    pub trace: Vec<Transform>,
    /// Steps dropped because no legal adaptation existed.
    pub dropped: usize,
    /// TileSize steps whose factor was rescaled to a target divisor.
    pub adjusted: usize,
}

/// Nearest legal tile factor for a loop of `extent`: the proper divisor
/// (in `2..extent`) minimizing `|divisor - want|`, smaller divisor on ties
/// for determinism. `None` when the extent has no proper divisor.
fn nearest_divisor(extent: i64, want: i64) -> Option<i64> {
    // Foreign records can carry arbitrary factors; clamp before the
    // distance arithmetic so extreme values cannot overflow.
    let want = want.clamp(1, extent.max(1));
    sampler::divisors(extent)
        .into_iter()
        .min_by_key(|&f| ((f - want).abs(), f))
}

/// Rebase `trace` onto `target`. See the module docs for the policy; the
/// returned trace is always fully legal on `target`.
pub fn rebase_trace(target: &Program, trace: &[Transform]) -> RebaseOutcome {
    let mut cur = target.clone();
    let mut out = RebaseOutcome::default();
    for step in trace {
        // Stage references beyond the target's stage count can never apply.
        if step.stage() >= cur.stages.len() {
            out.dropped += 1;
            continue;
        }
        let adapted = match step {
            Transform::TileSize { stage, loop_idx, factor } => {
                let Some(l) = cur.stages[*stage].loops.get(*loop_idx) else {
                    out.dropped += 1;
                    continue;
                };
                let extent = l.extent;
                let legal =
                    *factor >= 2 && *factor < extent && extent % *factor == 0;
                let factor = if legal {
                    *factor
                } else {
                    match nearest_divisor(extent, *factor) {
                        Some(f) => {
                            out.adjusted += 1;
                            f
                        }
                        None => {
                            out.dropped += 1;
                            continue;
                        }
                    }
                };
                Transform::TileSize { stage: *stage, loop_idx: *loop_idx, factor }
            }
            other => other.clone(),
        };
        match adapted.apply(&cur) {
            Ok(next) => {
                cur = next;
                out.trace.push(adapted);
            }
            Err(_) => out.dropped += 1,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::tir::workload;

    /// The rebased trace must replay fully and leave a valid program.
    fn assert_fully_legal(target: &Program, out: &RebaseOutcome) {
        let sched = Schedule::new(target.clone());
        let (replayed, applied) = sched.apply_all(&out.trace);
        assert_eq!(
            applied,
            out.trace.len(),
            "rebased trace must apply fully on the target"
        );
        replayed.current.validate().unwrap();
    }

    #[test]
    fn identical_shape_replays_verbatim() {
        let src = workload::moe_matmul("s", 16, 512, 512);
        let trace = vec![
            Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 },
            Transform::Parallel { stage: 0, loop_idx: 0 },
        ];
        let out = rebase_trace(&src, &trace);
        assert_eq!(out.trace, trace);
        assert_eq!((out.dropped, out.adjusted), (0, 0));
        assert_fully_legal(&src, &out);
    }

    #[test]
    fn tile_factors_rescale_to_target_divisors() {
        // factor 64 divides the source j=512 but the target j=96 needs the
        // nearest divisor of 96 (48).
        let target = workload::moe_matmul("t", 16, 96, 128);
        let trace = vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 }];
        let out = rebase_trace(&target, &trace);
        assert_eq!(out.adjusted, 1);
        assert_eq!(
            out.trace,
            vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 48 }]
        );
        assert_fully_legal(&target, &out);
    }

    #[test]
    fn oversized_factor_clamps_into_range() {
        // factor 128 exceeds the target extent 8 entirely: nearest proper
        // divisor is 4.
        let target = workload::moe_matmul("t", 8, 8, 8);
        let out = rebase_trace(
            &target,
            &[Transform::TileSize { stage: 0, loop_idx: 0, factor: 128 }],
        );
        assert_eq!(out.trace.len(), 1);
        match out.trace[0] {
            Transform::TileSize { factor, .. } => {
                assert!((2..8).contains(&factor) && 8 % factor == 0)
            }
            _ => panic!("expected TileSize"),
        }
        assert_fully_legal(&target, &out);
    }

    #[test]
    fn untileable_and_dangling_steps_drop() {
        let target = workload::moe_matmul("t", 2, 6, 8); // t=2 has no proper divisor
        let out = rebase_trace(
            &target,
            &[
                Transform::TileSize { stage: 0, loop_idx: 0, factor: 4 }, // extent 2
                Transform::TileSize { stage: 3, loop_idx: 0, factor: 2 }, // dangling stage
                Transform::Unroll { stage: 0, loop_idx: 9 },              // dangling loop
                Transform::Parallel { stage: 0, loop_idx: 0 },            // fine
            ],
        );
        assert_eq!(out.dropped, 3);
        assert_eq!(out.trace, vec![Transform::Parallel { stage: 0, loop_idx: 0 }]);
        assert_fully_legal(&target, &out);
    }

    #[test]
    fn cross_stage_trace_rebases_onto_fewer_stages() {
        // A 2-stage attention trace rebased onto a 1-stage matmul: stage-1
        // steps drop, stage-0 steps adapt — and nothing panics.
        let trace = vec![
            Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 },
            Transform::CacheWrite { stage: 1 },
            Transform::Parallel { stage: 0, loop_idx: 0 },
        ];
        let target = workload::moe_matmul("t", 16, 512, 512);
        let out = rebase_trace(&target, &trace);
        assert_eq!(out.dropped, 1, "stage-1 step has nowhere to go");
        assert_eq!(out.trace.len(), 2);
        assert_fully_legal(&target, &out);
    }

    #[test]
    fn annotation_limits_enforced_via_apply() {
        // Vectorizing a 512-wide innermost loop is illegal (> 64 lanes);
        // the rebaser drops it rather than emit an illegal step.
        let target = workload::moe_matmul("t", 16, 512, 512);
        // Move j innermost then vectorize — legal on a source whose j <= 64,
        // illegal here.
        let trace = vec![
            Transform::Reorder { stage: 0, perm: vec![0, 2, 1] },
            Transform::Vectorize { stage: 0, loop_idx: 2 },
        ];
        let out = rebase_trace(&target, &trace);
        assert_eq!(out.dropped, 1);
        assert_eq!(out.trace.len(), 1);
        assert_fully_legal(&target, &out);
    }
}
