//! Workload similarity index over the tuning database.
//!
//! Two layers of matching turn the database from a same-workload cache into
//! a cross-workload knowledge base:
//!
//! 1. **Shape class** (`db::fingerprint::shape_class`): an
//!    extent-abstracted structural fingerprint. Records whose shape class
//!    equals the target's are the same computation at a different size —
//!    the only pool a recorded trace can meaningfully rebase into.
//! 2. **Feature distance**: within a shape class, candidates are ranked by
//!    an L2 distance over per-stage, extent-derived analysis features —
//!    log2 of every original-axis extent plus per-stage log-spatial and
//!    log-reduction volumes (the axis roles come from the target's stage
//!    structure, which the shape-class match guarantees is shared). A
//!    `matmul 512^3` therefore prefers records from `matmul 1024^3`
//!    (distance √3·1) over `matmul 8192x16x5120`.
//!
//! Matching is read-only over `Database::records()` and fully
//! deterministic: ties break on recorded speedup (higher first) and then on
//! file order via the stable sort.
//!
//! Retrieval has two physical paths with one logical contract. Small
//! dbs use the exact linear scan below; once a [`TransferIndex`]
//! (`transfer::index`) is attached to the db *and* the record count
//! reaches its fallback threshold, candidates come from the ANN graph
//! instead and only those are re-ranked with the exact feature
//! distance — identical output whenever the candidate set covers the
//! true top-k, which is guaranteed for partitions the graph searches
//! exhaustively. Both paths apply the same record-aging penalty
//! ([`index::STALE_DISTANCE_PENALTY`]) to superseded records and emit
//! one `transfer_query` observability span per call.

use crate::db::fingerprint::{shape_class, workload_fingerprint};
use crate::db::{Database, TuningRecord};
use crate::obs;
use crate::tir::Program;

use super::index::{self, dominated_positions, raw_log_vector};

/// Per-stage original-axis extents of a program, the structural summary
/// persisted in every `TuningRecord` for later similarity matching.
pub fn workload_extents(p: &Program) -> Vec<Vec<i64>> {
    p.stages
        .iter()
        .map(|s| s.axes.iter().map(|a| a.extent).collect())
        .collect()
}

/// One database record matched to a target workload by shape class.
#[derive(Debug, Clone)]
pub struct TransferMatch<'a> {
    pub record: &'a TuningRecord,
    /// Feature distance to the target (0 = identical extents).
    pub distance: f64,
    /// A fresher record of the same workload/platform pair reached an
    /// equal-or-lower latency; ranked with a distance penalty.
    pub superseded: bool,
}

impl TransferMatch<'_> {
    /// Aging-adjusted ranking distance: superseded records carry
    /// [`index::STALE_DISTANCE_PENALTY`] so a stale record never
    /// outranks its fresher successor at equal shape distance.
    pub fn effective_distance(&self) -> f64 {
        self.distance + if self.superseded { index::STALE_DISTANCE_PENALTY } else { 0.0 }
    }
}

/// Extent-derived feature vector of one workload: per axis `log2(extent)`,
/// plus per stage the log-spatial and log-reduction volumes. The reduction
/// roles come from `reference` (the target program), which shares stage
/// structure with any extent source of the same shape class. Returns `None`
/// when the extent layout does not line up with the reference (foreign or
/// truncated record metadata).
fn feature_vector(reference: &Program, extents: &[Vec<i64>]) -> Option<Vec<f64>> {
    if extents.len() != reference.stages.len() {
        return None;
    }
    let mut out = Vec::new();
    for (stage, stage_extents) in reference.stages.iter().zip(extents) {
        if stage_extents.len() != stage.axes.len() {
            return None;
        }
        let mut spatial = 0.0;
        let mut reduction = 0.0;
        for (axis, &extent) in stage.axes.iter().zip(stage_extents) {
            let log = (extent.max(1) as f64).log2();
            out.push(log);
            if axis.is_reduction {
                reduction += log;
            } else {
                spatial += log;
            }
        }
        out.push(spatial);
        out.push(reduction);
    }
    Some(out)
}

/// L2 distance between two equal-length feature vectors.
fn l2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// L2 feature distance between the target program and a recorded extent
/// summary; `None` when the record's metadata does not line up.
pub fn feature_distance(target: &Program, record_extents: &[Vec<i64>]) -> Option<f64> {
    let a = feature_vector(target, &workload_extents(target))?;
    let b = feature_vector(target, record_extents)?;
    Some(l2(&a, &b))
}

/// True when retrieval will go through the ANN index: one is attached
/// to the db, it covers every record (no uncommitted tail), and the
/// record count has reached its fallback threshold — small dbs stay on
/// the exact scan, bit-identical to pre-index behavior.
pub fn uses_index(db: &Database) -> bool {
    db.transfer_index()
        .map_or(false, |ix| ix.covered() == db.len() && db.len() >= ix.threshold())
}

/// The `k` database records most similar to `target` on `platform`:
/// same shape class, *different* workload fingerprint (bit-identical
/// workloads are already served by the plain warm start), ranked by
/// aging-adjusted feature distance, then recorded speedup, then file
/// order. Records without transfer metadata (shape class 0 / missing
/// extents) are skipped. Candidates come from the attached ANN index
/// when [`uses_index`] holds, from the exact linear scan otherwise.
pub fn find_matches<'a>(
    db: &'a Database,
    target: &Program,
    platform: &str,
    k: usize,
) -> Vec<TransferMatch<'a>> {
    let class = shape_class(target);
    let fp = workload_fingerprint(target);
    let target_extents = workload_extents(target);
    // The target's own feature vector is the same for every candidate;
    // compute it once, not per record.
    let Some(target_vec) = feature_vector(target, &target_extents) else {
        return Vec::new();
    };
    let mut sp = obs::span2(obs::EventKind::TransferQuery, 0, 0);
    let via_index = uses_index(db);
    // Both arms yield candidates in file order, so the stable sort
    // below reproduces identical tie-breaks on either path.
    let mut matches: Vec<TransferMatch<'a>> = if via_index {
        let ix = db.transfer_index().expect("uses_index implies an attached index");
        ix.query(class, platform, &raw_log_vector(&target_extents), k)
            .into_iter()
            .filter_map(|c| {
                let r = &db.records()[c.pos];
                if r.workload_fp == fp {
                    return None;
                }
                feature_vector(target, &r.extents).map(|v| TransferMatch {
                    record: r,
                    distance: l2(&target_vec, &v),
                    superseded: c.superseded,
                })
            })
            .collect()
    } else {
        let stale = dominated_positions(db.records());
        db.records()
            .iter()
            .enumerate()
            .filter(|(_, r)| {
                r.platform == platform
                    && r.shape_class == class
                    && r.shape_class != 0
                    && r.workload_fp != fp
                    && !r.trace.is_empty()
            })
            .filter_map(|(i, r)| {
                feature_vector(target, &r.extents).map(|v| TransferMatch {
                    record: r,
                    distance: l2(&target_vec, &v),
                    superseded: stale.contains(&i),
                })
            })
            .collect()
    };
    let considered = matches.len();
    matches.sort_by(|a, b| {
        a.effective_distance()
            .partial_cmp(&b.effective_distance())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                b.record
                    .speedup()
                    .partial_cmp(&a.record.speedup())
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
    });
    matches.truncate(k);
    sp.set_args(considered as u64, via_index as u64);
    matches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload;

    fn rec(program: &Program, platform: &str, latency: f64, factor: i64) -> TuningRecord {
        TuningRecord {
            workload_fp: workload_fingerprint(program),
            workload: program.name.clone(),
            platform: platform.to_string(),
            strategy: "test".to_string(),
            trace: vec![Transform::TileSize { stage: 0, loop_idx: 2, factor }],
            latency,
            baseline_latency: 10.0,
            seed: 1,
            timestamp: 100,
            shape_class: shape_class(program),
            extents: workload_extents(program),
        }
    }

    #[test]
    fn extents_and_distance_track_shapes() {
        let a = workload::moe_matmul("a", 16, 512, 512);
        assert_eq!(workload_extents(&a), vec![vec![16, 512, 512]]);
        // Identical extents: distance 0.
        let same = workload::moe_matmul("b", 16, 512, 512);
        assert_eq!(feature_distance(&a, &workload_extents(&same)), Some(0.0));
        // Doubling every extent moves each coordinate by 1 in log2 space.
        let double = workload::moe_matmul("c", 32, 1024, 1024);
        let d = feature_distance(&a, &workload_extents(&double)).unwrap();
        assert!(d > 0.0);
        // Mismatched layout: None, not a bogus distance.
        assert_eq!(feature_distance(&a, &[]), None);
        assert_eq!(feature_distance(&a, &[vec![16, 512]]), None);
    }

    #[test]
    fn closer_extents_mean_smaller_distance() {
        let target = workload::moe_matmul("t", 16, 512, 512);
        let near = workload::moe_matmul("n", 16, 1024, 512);
        let far = workload::moe_matmul("f", 128, 8192, 4096);
        let dn = feature_distance(&target, &workload_extents(&near)).unwrap();
        let df = feature_distance(&target, &workload_extents(&far)).unwrap();
        assert!(dn < df, "near {dn} must rank before far {df}");
    }

    #[test]
    fn find_matches_filters_and_ranks() {
        let target = workload::moe_matmul("target", 16, 512, 512);
        let near = workload::moe_matmul("near", 16, 1024, 512);
        let far = workload::moe_matmul("far", 128, 8192, 4096);
        let conv = workload::conv2d("conv", 8, 8, 16, 16, 3);

        let mut db = Database::in_memory();
        db.add(rec(&far, "core_i9", 2.0, 64));
        db.add(rec(&near, "core_i9", 2.0, 64));
        db.add(rec(&conv, "core_i9", 1.0, 2)); // different class: excluded
        db.add(rec(&near, "m2_pro", 0.5, 64)); // other platform: excluded
        db.add(rec(&target, "core_i9", 0.1, 64)); // same fp: excluded

        let matches = find_matches(&db, &target, "core_i9", 8);
        assert_eq!(matches.len(), 2);
        assert_eq!(matches[0].record.workload, "near", "distance ranks first");
        assert_eq!(matches[1].record.workload, "far");
        assert!(matches[0].distance < matches[1].distance);

        // k truncates.
        assert_eq!(find_matches(&db, &target, "core_i9", 1).len(), 1);
    }

    #[test]
    fn records_without_metadata_never_match() {
        let target = workload::moe_matmul("target", 16, 512, 512);
        let near = workload::moe_matmul("near", 16, 1024, 512);
        let mut old = rec(&near, "core_i9", 2.0, 64);
        old.shape_class = 0; // pre-transfer record
        old.extents = Vec::new();
        let mut db = Database::in_memory();
        db.add(old);
        assert!(find_matches(&db, &target, "core_i9", 8).is_empty());
    }

    #[test]
    fn superseded_records_rank_behind_fresher_work() {
        let target = workload::moe_matmul("target", 16, 512, 512);
        let src_a = workload::moe_matmul("src_a", 16, 1024, 512); // distance ~1.41
        let src_b = workload::moe_matmul("src_b", 16, 1024, 1024); // distance 2.0
        let mut db = Database::in_memory();
        let old = rec(&src_a, "core_i9", 2.0, 32); // ts=100, superseded below
        db.add(old);
        let mut fresh = rec(&src_a, "core_i9", 1.5, 64);
        fresh.timestamp = 200;
        db.add(fresh);
        db.add(rec(&src_b, "core_i9", 5.0, 64));
        let matches = find_matches(&db, &target, "core_i9", 8);
        assert_eq!(matches.len(), 3);
        // Without aging the stale src_a record (distance 1.41) would
        // outrank src_b (distance 2.0); the penalty demotes it last.
        assert_eq!(matches[0].record.latency, 1.5);
        assert!(!matches[0].superseded);
        assert_eq!(matches[1].record.workload, "src_b");
        assert_eq!(matches[2].record.latency, 2.0);
        assert!(matches[2].superseded);
        assert!(matches[2].effective_distance() > matches[2].distance);
    }

    #[test]
    fn speedup_breaks_distance_ties() {
        let target = workload::moe_matmul("target", 16, 512, 512);
        let src = workload::moe_matmul("src", 16, 1024, 512);
        let mut db = Database::in_memory();
        db.add(rec(&src, "core_i9", 5.0, 32)); // 2x speedup
        db.add(rec(&src, "core_i9", 2.0, 64)); // 5x speedup
        let matches = find_matches(&db, &target, "core_i9", 8);
        assert_eq!(matches.len(), 2);
        assert_eq!(
            matches[0].record.latency, 2.0,
            "equal distance: higher recorded speedup first"
        );
    }
}
