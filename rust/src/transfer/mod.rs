//! Transfer tuning: cross-workload trace rebasing + few-shot exemplars.
//!
//! The tuning database (`crate::db`) makes measurements durable, but a
//! *new* workload still started cold: records only matched bit-identical
//! workload fingerprints. This subsystem makes every search start warm from
//! **related** prior work — the paper's sample-efficiency story applied
//! across workloads:
//!
//! - [`similarity`] — the workload similarity index: an extent-abstracted
//!   *shape class* (`db::fingerprint::shape_class`) groups records of the
//!   same computation at different sizes, and an extent-derived feature
//!   distance ranks them, so `matmul 512^3` finds records from
//!   `matmul 1024^3`.
//! - [`rebase`] — the trace rebaser: replays a recorded trace onto a
//!   structurally similar, differently-sized program — remapping stage/loop
//!   references, rescaling tile factors to the new extents, dropping
//!   inapplicable steps — yielding traces that are always fully legal on
//!   the target.
//! - [`exemplar`] — the few-shot exemplar engine: selects top-k diverse
//!   (workload, trace, speedup) triples for the target's shape class —
//!   conditioned on the target's dominant cost-model bottleneck (compute
//!   vs traffic) when the platform is known — and renders them into the
//!   reasoning engine's prompts (`reasoning::prompt::render_with`), so
//!   `informed_proposals` conditions on accumulated cross-workload
//!   performance feedback.
//! - [`index`] — sublinear retrieval at scale: an HNSW-style ANN index
//!   over per-stage log-extent vectors, partitioned by shape class and
//!   platform, persisted as a `<db>.idx` sidecar and rebuilt whenever
//!   stale — plus the record-aging policy (superseded records are
//!   down-weighted at retrieval and reaped by `rcc db gc
//!   --reap-dominated`). Small dbs fall back to the exact linear scan,
//!   bit-identical to the pre-index behavior.
//!
//! The coordinator wires both products into a session via
//! [`derive_hints`]: rebased traces extend the `SearchContext` warm-start
//! entries (seeded into the MCTS root frontier / evolutionary population
//! and *measured* like any candidate — recorded latencies are never
//! transplanted into the measurement cache, since a latency measured on a
//! different shape proves nothing about this one), and exemplars flow to
//! `reasoning::LlmPolicy`. CLI: `rcc transfer match|rebase|exemplars`.

pub mod exemplar;
pub mod index;
pub mod rebase;
pub mod similarity;

pub use exemplar::{
    classify_bottleneck, exemplars_for, exemplars_from_matches, render_exemplar_block,
    select_exemplars, Bottleneck, Exemplar,
};
pub use index::{sidecar_path, TransferIndex, STALE_DISTANCE_PENALTY};
pub use rebase::{rebase_trace, RebaseOutcome};
pub use similarity::{feature_distance, find_matches, uses_index, workload_extents, TransferMatch};

use crate::cost::Platform;
use crate::db::Database;
use crate::schedule::Transform;
use crate::tir::Program;

/// Everything a tuning session gains from cross-workload transfer.
#[derive(Debug, Clone, Default)]
pub struct TransferHints {
    /// Rebased warm-start traces, best match first, each fully legal on the
    /// target. The paired value is the **source** record's latency — an
    /// ordering prior only; callers must never treat it as a measurement of
    /// the target program.
    pub warm_entries: Vec<(Vec<Transform>, f64)>,
    /// Few-shot exemplars for the LLM proposal policy.
    pub exemplars: Vec<Exemplar>,
    /// How many similar records were considered (diagnostics).
    pub matches: usize,
}

impl TransferHints {
    pub fn is_empty(&self) -> bool {
        self.warm_entries.is_empty() && self.exemplars.is_empty()
    }
}

/// Derive transfer hints for `target` on `platform`: up to `top_k` rebased
/// warm-start traces (deduplicated) and up to `top_k` exemplars.
/// Deterministic for a fixed database file.
pub fn derive_hints(
    db: &Database,
    target: &Program,
    platform: &str,
    top_k: usize,
) -> TransferHints {
    // One database scan serves both products: warm entries and exemplars.
    let matches = find_matches(db, target, platform, top_k.saturating_mul(4).max(8));
    let mut hints = TransferHints { matches: matches.len(), ..Default::default() };
    for m in &matches {
        if hints.warm_entries.len() >= top_k {
            break;
        }
        let rebased = rebase_trace(target, &m.record.trace);
        if rebased.trace.is_empty()
            || hints.warm_entries.iter().any(|(t, _)| *t == rebased.trace)
        {
            continue;
        }
        hints.warm_entries.push((rebased.trace, m.record.latency));
    }
    hints.exemplars = match Platform::by_name(platform) {
        Some(p) => exemplar::exemplars_for(&matches, target, &p, top_k),
        None => exemplar::exemplars_from_matches(&matches, target, top_k),
    };
    hints
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fingerprint::{shape_class, workload_fingerprint};
    use crate::db::TuningRecord;
    use crate::schedule::Schedule;
    use crate::tir::workload;

    #[test]
    fn derive_hints_produces_legal_deduplicated_entries() {
        let target = workload::moe_matmul("target", 16, 256, 128);
        let src = workload::moe_matmul("src", 16, 512, 256);
        let mut db = Database::in_memory();
        for (latency, factor) in [(2.0, 64), (3.0, 128), (4.0, 64)] {
            db.add(TuningRecord {
                workload_fp: workload_fingerprint(&src),
                workload: src.name.clone(),
                platform: "core_i9".to_string(),
                strategy: "test".to_string(),
                trace: vec![Transform::TileSize { stage: 0, loop_idx: 1, factor }],
                latency,
                baseline_latency: 10.0,
                seed: 1,
                timestamp: 100,
                shape_class: shape_class(&src),
                extents: workload_extents(&src),
            });
        }
        let hints = derive_hints(&db, &target, "core_i9", 4);
        assert_eq!(hints.matches, 3);
        // factor 64 appears twice at the same distance; the rebased trace
        // dedups, and factor 128 rescales onto j = 256.
        assert_eq!(hints.warm_entries.len(), 2);
        let base = Schedule::new(target.clone());
        for (trace, _) in &hints.warm_entries {
            let (_, applied) = base.apply_all(trace);
            assert_eq!(applied, trace.len(), "transfer warm entries must be legal");
        }
        assert!(!hints.exemplars.is_empty());
        assert!(!hints.is_empty());

        // No similar records on another platform.
        assert!(derive_hints(&db, &target, "graviton2", 4).is_empty());
        // The source workload itself gets nothing (same fingerprint).
        assert!(derive_hints(&db, &src, "core_i9", 4).is_empty());
    }
}
