//! Index expressions.
//!
//! Two expression families cover everything the schedule engine needs:
//!
//! - [`Expr`] — integer expressions over *loop variables*, used to
//!   reconstruct original-axis values from the (split/fused) loop nest.
//!   `Split` substitutes `v := outer*f + inner`; `Fuse` substitutes
//!   `v1 := f / e2, v2 := f % e2`, so the tree needs Add/Mul/Div/Mod.
//! - [`LinIdx`] — buffer index expressions, *linear* in the original axes
//!   (`sum(axis * stride) + offset`). Matmul, batched matmul and
//!   convolution indexing are all axis-linear, and keeping them linear makes
//!   stride/locality analysis in the cost model exact.

/// Loop-variable id, unique within a [`super::Stage`].
pub type VarId = usize;

/// Integer expression over loop variables.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    /// A loop variable.
    Var(VarId),
    Const(i64),
    Add(Box<Expr>, Box<Expr>),
    /// Multiply by a constant (index expressions never multiply two vars).
    Mul(Box<Expr>, i64),
    /// Floor division by a positive constant.
    Div(Box<Expr>, i64),
    /// Modulo by a positive constant.
    Mod(Box<Expr>, i64),
}

impl Expr {
    pub fn var(v: VarId) -> Expr {
        Expr::Var(v)
    }

    pub fn add(a: Expr, b: Expr) -> Expr {
        match (&a, &b) {
            (Expr::Const(0), _) => b,
            (_, Expr::Const(0)) => a,
            (Expr::Const(x), Expr::Const(y)) => Expr::Const(x + y),
            _ => Expr::Add(Box::new(a), Box::new(b)),
        }
    }

    pub fn mul(a: Expr, k: i64) -> Expr {
        match (&a, k) {
            (_, 0) => Expr::Const(0),
            (_, 1) => a,
            (Expr::Const(x), _) => Expr::Const(x * k),
            _ => Expr::Mul(Box::new(a), k),
        }
    }

    pub fn div(a: Expr, k: i64) -> Expr {
        debug_assert!(k > 0);
        if k == 1 {
            return a;
        }
        if let Expr::Const(x) = a {
            return Expr::Const(x.div_euclid(k));
        }
        Expr::Div(Box::new(a), k)
    }

    pub fn modulo(a: Expr, k: i64) -> Expr {
        debug_assert!(k > 0);
        if k == 1 {
            return Expr::Const(0);
        }
        if let Expr::Const(x) = a {
            return Expr::Const(x.rem_euclid(k));
        }
        Expr::Mod(Box::new(a), k)
    }

    /// Evaluate under an environment mapping loop var id -> value.
    pub fn eval(&self, env: &[i64]) -> i64 {
        match self {
            Expr::Var(v) => env[*v],
            Expr::Const(c) => *c,
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Mul(a, k) => a.eval(env) * k,
            Expr::Div(a, k) => a.eval(env).div_euclid(*k),
            Expr::Mod(a, k) => a.eval(env).rem_euclid(*k),
        }
    }

    /// Substitute `var := replacement` throughout.
    pub fn subst(&self, var: VarId, replacement: &Expr) -> Expr {
        match self {
            Expr::Var(v) if *v == var => replacement.clone(),
            Expr::Var(_) | Expr::Const(_) => self.clone(),
            Expr::Add(a, b) => Expr::add(a.subst(var, replacement), b.subst(var, replacement)),
            Expr::Mul(a, k) => Expr::mul(a.subst(var, replacement), *k),
            Expr::Div(a, k) => Expr::div(a.subst(var, replacement), *k),
            Expr::Mod(a, k) => Expr::modulo(a.subst(var, replacement), *k),
        }
    }

    /// All loop variables referenced.
    pub fn vars(&self, out: &mut Vec<VarId>) {
        match self {
            Expr::Var(v) => {
                if !out.contains(v) {
                    out.push(*v);
                }
            }
            Expr::Const(_) => {}
            Expr::Add(a, b) => {
                a.vars(out);
                b.vars(out);
            }
            Expr::Mul(a, _) | Expr::Div(a, _) | Expr::Mod(a, _) => a.vars(out),
        }
    }

    /// Render with loop-var names.
    pub fn render(&self, names: &dyn Fn(VarId) -> String) -> String {
        match self {
            Expr::Var(v) => names(*v),
            Expr::Const(c) => c.to_string(),
            Expr::Add(a, b) => format!("{} + {}", a.render(names), b.render(names)),
            Expr::Mul(a, k) => format!("{} * {}", paren(a, names), k),
            Expr::Div(a, k) => format!("{} // {}", paren(a, names), k),
            Expr::Mod(a, k) => format!("{} % {}", paren(a, names), k),
        }
    }
}

fn paren(e: &Expr, names: &dyn Fn(VarId) -> String) -> String {
    match e {
        Expr::Var(_) | Expr::Const(_) => e.render(names),
        _ => format!("({})", e.render(names)),
    }
}

/// Axis id, unique within a stage (indexes `Stage::axes`).
pub type AxisId = usize;

/// A buffer index expression, linear in the original axes:
/// `offset + sum_i axes[terms[i].0] * terms[i].1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinIdx {
    pub terms: Vec<(AxisId, i64)>,
    pub offset: i64,
}

impl LinIdx {
    /// Index that is exactly one axis.
    pub fn axis(a: AxisId) -> LinIdx {
        LinIdx { terms: vec![(a, 1)], offset: 0 }
    }

    /// `a + b` (e.g. conv input index `h + kh`).
    pub fn axis_sum(a: AxisId, b: AxisId) -> LinIdx {
        LinIdx { terms: vec![(a, 1), (b, 1)], offset: 0 }
    }

    pub fn scaled(terms: Vec<(AxisId, i64)>) -> LinIdx {
        LinIdx { terms, offset: 0 }
    }

    /// Evaluate under axis values.
    #[inline]
    pub fn eval(&self, axes: &[i64]) -> i64 {
        let mut v = self.offset;
        for &(a, k) in &self.terms {
            v += axes[a] * k;
        }
        v
    }

    /// Coefficient of `axis` (0 if absent).
    pub fn coeff(&self, axis: AxisId) -> i64 {
        self.terms
            .iter()
            .find(|(a, _)| *a == axis)
            .map(|(_, k)| *k)
            .unwrap_or(0)
    }

    pub fn render(&self, axis_name: &dyn Fn(AxisId) -> String) -> String {
        let mut parts: Vec<String> = Vec::new();
        for &(a, k) in &self.terms {
            if k == 1 {
                parts.push(axis_name(a));
            } else {
                parts.push(format!("{} * {}", axis_name(a), k));
            }
        }
        if self.offset != 0 || parts.is_empty() {
            parts.push(self.offset.to_string());
        }
        parts.join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_basic() {
        // v0*4 + v1
        let e = Expr::add(Expr::mul(Expr::var(0), 4), Expr::var(1));
        assert_eq!(e.eval(&[3, 2]), 14);
    }

    #[test]
    fn split_substitution_preserves_value() {
        // original axis j = v0, extent 12. Split v0 into (v1 extent 3, v2 extent 4):
        // v0 := v1*4 + v2. Every (v1, v2) in 3x4 must reproduce each j in 0..12 once.
        let axis = Expr::var(0);
        let substituted = axis.subst(0, &Expr::add(Expr::mul(Expr::var(1), 4), Expr::var(2)));
        let mut seen = vec![false; 12];
        for v1 in 0..3 {
            for v2 in 0..4 {
                let env = vec![0, v1, v2];
                let j = substituted.eval(&env);
                assert!(!seen[j as usize], "duplicate j={j}");
                seen[j as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fuse_substitution_preserves_values() {
        // axes (a=v0 extent 3, b=v1 extent 5) fused into f=v2 extent 15:
        // v0 := f/5, v1 := f%5.
        let a = Expr::var(0).subst(0, &Expr::div(Expr::var(2), 5));
        let b = Expr::var(1).subst(1, &Expr::modulo(Expr::var(2), 5));
        let mut seen = std::collections::HashSet::new();
        for f in 0..15 {
            let env = vec![0, 0, f];
            seen.insert((a.eval(&env), b.eval(&env)));
        }
        assert_eq!(seen.len(), 15);
        for (x, y) in seen {
            assert!((0..3).contains(&x) && (0..5).contains(&y));
        }
    }

    #[test]
    fn simplification_identities() {
        assert_eq!(Expr::mul(Expr::var(0), 1), Expr::var(0));
        assert_eq!(Expr::mul(Expr::var(0), 0), Expr::Const(0));
        assert_eq!(Expr::add(Expr::var(0), Expr::Const(0)), Expr::var(0));
        assert_eq!(Expr::div(Expr::var(0), 1), Expr::var(0));
        assert_eq!(Expr::modulo(Expr::var(0), 1), Expr::Const(0));
        assert_eq!(Expr::add(Expr::Const(2), Expr::Const(3)), Expr::Const(5));
    }

    #[test]
    fn vars_collects_unique() {
        let e = Expr::add(
            Expr::mul(Expr::var(0), 4),
            Expr::add(Expr::var(1), Expr::var(0)),
        );
        let mut vs = Vec::new();
        e.vars(&mut vs);
        vs.sort();
        assert_eq!(vs, vec![0, 1]);
    }

    #[test]
    fn linidx_eval_and_coeff() {
        // in[h + kh] with h=axis0 (coeff 1), kh=axis2 (coeff 1), plus stride row W=64
        let idx = LinIdx::scaled(vec![(0, 64), (2, 1)]);
        assert_eq!(idx.eval(&[3, 0, 5]), 197);
        assert_eq!(idx.coeff(0), 64);
        assert_eq!(idx.coeff(1), 0);
        assert_eq!(idx.coeff(2), 1);
    }

    #[test]
    fn render_readable() {
        let e = Expr::add(Expr::mul(Expr::var(0), 64), Expr::var(1));
        let names = |v: VarId| format!("j_{v}");
        assert_eq!(e.render(&names), "j_0 * 64 + j_1");
        let idx = LinIdx::axis_sum(0, 1);
        let axis_names = |a: AxisId| ["h", "kh"][a].to_string();
        assert_eq!(idx.render(&axis_names), "h + kh");
    }
}
