//! Tensor-program IR — the MetaSchedule substrate.
//!
//! A [`Program`] is one tunable task: buffers + one or more [`Stage`]s, each
//! a perfect loop nest around a single compute [`Block`]. The schedule
//! engine (`crate::schedule`) rewrites loop nests; the interpreter
//! ([`interp`]) provides the semantic-equivalence oracle; [`workload`]
//! builds the paper's five evaluation kernels and the end-to-end Llama-3
//! task set; [`printer`] renders the TVMScript-flavoured text used in LLM
//! prompts.

pub mod expr;
pub mod hash;
pub mod interp;
pub mod printer;
pub mod program;
pub mod workload;

pub use expr::{AxisId, Expr, LinIdx, VarId};
pub use program::{
    Axis, Block, BlockExpr, BufKind, Buffer, LoopDef, LoopKind, Program, ReduceOp, Stage,
};
pub use workload::{E2eTask, WorkloadId};
