//! Structural hashing primitives over TIR.
//!
//! This module hosts the low-level hashing machinery shared by
//! `db::fingerprint` (workload/program fingerprints), the per-stage
//! memoized hash ([`crate::tir::program::Stage::struct_hash`]) and the
//! access-analysis memoization key (`cost::AnalysisCache`). It lives in
//! `tir` so both `cost` and `db` can use it without depending on each
//! other.
//!
//! All hashes are 64-bit FNV-1a-style with per-field tags (so structurally
//! different programs don't collide through commutativity) and a splitmix64
//! avalanche tail.

use super::expr::{Expr, LinIdx};
use super::program::{BlockExpr, Buffer, Stage};

/// Incremental FNV-1a-style hasher over tagged integer fields.
#[derive(Debug, Clone)]
pub struct StructHasher {
    h: u64,
}

impl Default for StructHasher {
    fn default() -> Self {
        StructHasher { h: 0xcbf29ce484222325 }
    }
}

impl StructHasher {
    pub fn new() -> StructHasher {
        StructHasher::default()
    }

    #[inline]
    pub fn feed(&mut self, x: u64) {
        self.h ^= x;
        self.h = self.h.wrapping_mul(0x100000001b3);
    }

    #[inline]
    pub fn feed_i64(&mut self, x: i64) {
        self.feed(x as u64);
    }

    /// Field tag: keeps `[2, 3]` from colliding with `[3, 2]`-shaped feeds
    /// of a different field.
    #[inline]
    pub fn tag(&mut self, t: u64) {
        self.feed(0x9E37_79B9_7F4A_7C15 ^ t);
    }

    pub fn finish(&self) -> u64 {
        // Final avalanche (splitmix64 tail) so nearby inputs spread.
        let mut z = self.h;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

pub fn feed_linidx(h: &mut StructHasher, idx: &LinIdx) {
    h.tag(10);
    h.feed_i64(idx.offset);
    for &(axis, coeff) in &idx.terms {
        h.feed(axis as u64);
        h.feed_i64(coeff);
    }
}

pub fn feed_block_expr(h: &mut StructHasher, e: &BlockExpr) {
    match e {
        BlockExpr::Load(buf, idx) => {
            h.tag(20);
            h.feed(*buf as u64);
            for i in idx {
                feed_linidx(h, i);
            }
        }
        BlockExpr::Const(c) => {
            h.tag(21);
            h.feed(c.to_bits() as u64);
        }
        BlockExpr::Add(a, b) => {
            h.tag(22);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Sub(a, b) => {
            h.tag(23);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Mul(a, b) => {
            h.tag(24);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Max(a, b) => {
            h.tag(25);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
    }
}

pub fn feed_expr(h: &mut StructHasher, e: &Expr) {
    match e {
        Expr::Var(v) => {
            h.tag(30);
            h.feed(*v as u64);
        }
        Expr::Const(c) => {
            h.tag(31);
            h.feed_i64(*c);
        }
        Expr::Add(a, b) => {
            h.tag(32);
            feed_expr(h, a);
            feed_expr(h, b);
        }
        Expr::Mul(a, k) => {
            h.tag(33);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
        Expr::Div(a, k) => {
            h.tag(34);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
        Expr::Mod(a, k) => {
            h.tag(35);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
    }
}

/// Feed the schedule-invariant structure of one stage (axes and block);
/// names are deliberately excluded so fingerprints transfer across
/// identically-shaped programs.
pub fn feed_stage_structure(h: &mut StructHasher, s: &Stage) {
    h.tag(2);
    for a in &s.axes {
        h.feed_i64(a.extent);
        h.feed(a.is_reduction as u64 + 1);
    }
    h.tag(3);
    h.feed(s.block.out as u64);
    for idx in &s.block.out_idx {
        feed_linidx(h, idx);
    }
    feed_block_expr(h, &s.block.rhs);
    h.feed(s.block.reduce as u64 + 1);
}

/// Feed the schedule state of one stage: current loop nest,
/// axis-reconstruction expressions and performance annotations.
pub fn feed_stage_schedule(h: &mut StructHasher, s: &Stage) {
    h.tag(4);
    for l in &s.loops {
        h.feed_i64(l.extent);
        h.feed(l.kind as u64 + 1);
        h.feed(l.var as u64);
    }
    h.tag(5);
    for e in &s.axis_exprs {
        feed_expr(h, e);
    }
    h.feed(s.cache_write as u64 + 17);
    h.feed(s.compute_at.map(|d| d as u64 + 1).unwrap_or(0));
}

/// Full per-stage structural hash: the stage's computation structure plus
/// its current schedule state. This is the value memoized by
/// [`Stage::struct_hash`] and combined by `db::program_fingerprint`; two
/// stages with equal hashes are structurally identical (modulo 64-bit
/// collision), so any pure analysis of them is identical too — the
/// soundness argument behind `cost::AnalysisCache`.
pub fn stage_schedule_hash(s: &Stage) -> u64 {
    let mut h = StructHasher::new();
    feed_stage_structure(&mut h, s);
    feed_stage_schedule(&mut h, s);
    h.finish()
}

/// Feed the buffer table (kinds and shapes; names excluded). Cheap — a few
/// dozen integer feeds — so callers hash it per call while the expensive
/// per-stage part is memoized.
pub fn feed_buffers(h: &mut StructHasher, buffers: &[Buffer]) {
    for b in buffers {
        h.feed(b.kind as u64 + 1);
        h.feed(b.shape.len() as u64);
        for &d in &b.shape {
            h.feed_i64(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Transform;
    use crate::tir::workload;

    #[test]
    fn stage_hash_changes_with_schedule_state() {
        let p = workload::moe_matmul("m", 4, 6, 8);
        let h0 = stage_schedule_hash(&p.stages[0]);
        let q = Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }
            .apply(&p)
            .unwrap();
        let h1 = stage_schedule_hash(&q.stages[0]);
        assert_ne!(h0, h1, "tiling must change the stage hash");
        // Same transform sequence reproduces the same hash.
        let q2 = Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 }
            .apply(&p)
            .unwrap();
        assert_eq!(h1, stage_schedule_hash(&q2.stages[0]));
    }

    #[test]
    fn stage_hash_invariant_to_names() {
        let a = workload::moe_matmul("alpha", 4, 6, 8);
        let b = workload::moe_matmul("beta", 4, 6, 8);
        assert_eq!(
            stage_schedule_hash(&a.stages[0]),
            stage_schedule_hash(&b.stages[0])
        );
    }

    #[test]
    fn buffer_feed_distinguishes_shapes() {
        let a = workload::moe_matmul("m", 4, 6, 8);
        let b = workload::moe_matmul("m", 4, 6, 16);
        let hash = |p: &crate::tir::Program| {
            let mut h = StructHasher::new();
            feed_buffers(&mut h, &p.buffers);
            h.finish()
        };
        assert_ne!(hash(&a), hash(&b));
    }
}
