//! Program structure: buffers, axes, loop nests and compute blocks.
//!
//! A [`Program`] is one tunable tensor computation (a "task" in TVM terms):
//! one or more [`Stage`]s, each a perfect loop nest around a single
//! reduction/elementwise [`Block`]. Schedule transformations rewrite the
//! loop list and the axis-reconstruction expressions but never the block,
//! which is what makes semantic equivalence checkable.
//!
//! **Copy-on-write representation (PR 3).** A program stores its buffer
//! table behind one `Arc` and each stage behind its own `Arc`, so cloning a
//! program (which every `Transform::apply` does) is a handful of reference
//! bumps, and mutating one stage clones only that stage
//! ([`Stage::cow_mut`]) — O(stage) per search-tree edge instead of
//! O(program). Sibling schedules produced by MCTS/ES therefore share every
//! untouched stage. Each stage memoizes its structural hash
//! ([`Stage::struct_hash`]); `cow_mut` clears the memo on every mutable
//! borrow, which is the invalidation invariant the fingerprint and
//! analysis caches rely on (stage hash changes ⇒ memo was cleared ⇒
//! downstream analyses are recomputed).

use std::sync::{Arc, OnceLock};

use super::expr::{AxisId, Expr, LinIdx, VarId};
use super::hash;

/// Buffer role, used by the interpreter and the cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufKind {
    Input,
    Output,
    /// Intermediate produced by one stage and consumed by a later one.
    Intermediate,
}

#[derive(Debug, Clone)]
pub struct Buffer {
    pub name: String,
    pub shape: Vec<i64>,
    pub kind: BufKind,
}

impl Buffer {
    pub fn elems(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<i64> {
        let mut s = vec![1i64; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flatten a multi-dim index (already evaluated) to a linear offset.
    pub fn flat(&self, idx: &[i64]) -> i64 {
        let strides = self.strides();
        idx.iter().zip(&strides).map(|(i, s)| i * s).sum()
    }
}

/// An original iteration axis of the computation (spatial or reduction).
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub extent: i64,
    pub is_reduction: bool,
}

/// How a loop is annotated. Annotations never change semantics, only the
/// cost model's view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopKind {
    Serial,
    Parallel,
    Vectorized,
    Unrolled,
}

impl LoopKind {
    pub fn label(&self) -> &'static str {
        match self {
            LoopKind::Serial => "serial",
            LoopKind::Parallel => "parallel",
            LoopKind::Vectorized => "vectorized",
            LoopKind::Unrolled => "unrolled",
        }
    }
}

/// One loop of the current nest.
#[derive(Debug, Clone)]
pub struct LoopDef {
    /// Loop variable id; index into the stage's var table.
    pub var: VarId,
    /// Human-readable name, e.g. `j_1` (axis j, split level 1).
    pub name: String,
    pub extent: i64,
    pub kind: LoopKind,
}

/// Scalar compute expression inside a block.
#[derive(Debug, Clone)]
pub enum BlockExpr {
    /// Load `buffers[buf][indices...]`; indices are linear in original axes.
    Load(usize, Vec<LinIdx>),
    Const(f32),
    Add(Box<BlockExpr>, Box<BlockExpr>),
    Sub(Box<BlockExpr>, Box<BlockExpr>),
    Mul(Box<BlockExpr>, Box<BlockExpr>),
    Max(Box<BlockExpr>, Box<BlockExpr>),
}

impl BlockExpr {
    pub fn load(buf: usize, indices: Vec<LinIdx>) -> BlockExpr {
        BlockExpr::Load(buf, indices)
    }

    pub fn mul(a: BlockExpr, b: BlockExpr) -> BlockExpr {
        BlockExpr::Mul(Box::new(a), Box::new(b))
    }

    pub fn add(a: BlockExpr, b: BlockExpr) -> BlockExpr {
        BlockExpr::Add(Box::new(a), Box::new(b))
    }

    /// All buffer loads (buffer id, indices).
    pub fn loads<'a>(&'a self, out: &mut Vec<(usize, &'a [LinIdx])>) {
        match self {
            BlockExpr::Load(b, idx) => out.push((*b, idx)),
            BlockExpr::Const(_) => {}
            BlockExpr::Add(a, b) | BlockExpr::Sub(a, b) | BlockExpr::Mul(a, b) | BlockExpr::Max(a, b) => {
                a.loads(out);
                b.loads(out);
            }
        }
    }

    /// Count of arithmetic ops (flops contributed per block execution).
    pub fn flops(&self) -> u64 {
        match self {
            BlockExpr::Load(..) | BlockExpr::Const(_) => 0,
            BlockExpr::Add(a, b) | BlockExpr::Sub(a, b) | BlockExpr::Mul(a, b) | BlockExpr::Max(a, b) => {
                1 + a.flops() + b.flops()
            }
        }
    }
}

/// Reduction combinator for the block update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// `out += rhs` (init 0).
    Sum,
    /// `out = max(out, rhs)` (init -inf).
    Max,
    /// No reduction: `out = rhs` (pure elementwise stage).
    Assign,
}

impl ReduceOp {
    pub fn init_val(&self) -> f32 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
            ReduceOp::Assign => 0.0,
        }
    }
}

/// The single compute block of a stage:
/// `out[out_idx] = reduce(out[out_idx], rhs)` with `T.init()` semantics —
/// the init store fires when all reduction axes are at 0.
#[derive(Debug, Clone)]
pub struct Block {
    pub name: String,
    /// Output buffer id.
    pub out: usize,
    /// Output indices, linear in original axes (must not use reduction axes).
    pub out_idx: Vec<LinIdx>,
    pub rhs: BlockExpr,
    pub reduce: ReduceOp,
}

/// One stage: a perfect loop nest around one block.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    /// Original axes, fixed for the life of the stage.
    pub axes: Vec<Axis>,
    /// Current loop nest, outermost first. Transformed by the scheduler.
    pub loops: Vec<LoopDef>,
    /// Per-axis reconstruction expression over current loop vars.
    pub axis_exprs: Vec<Expr>,
    /// Extent of each loop var ever created (indexed by VarId); needed to
    /// build substitutions and for validation.
    pub var_extents: Vec<i64>,
    pub block: Block,
    /// Accumulate in a register/L1-local buffer, write back at the end
    /// (CacheWrite transform). Performance-only.
    pub cache_write: bool,
    /// Loop depth at which the output tile is initialized / written back
    /// (ComputeLocation transform). None = at the block. Performance-only.
    pub compute_at: Option<usize>,
    /// Memoized structural hash (see [`Stage::struct_hash`]). Cleared by
    /// [`Stage::cow_mut`] on every mutable borrow; preserved by `clone`
    /// (a clone is structurally identical, so the hash stays valid).
    memo: OnceLock<u64>,
}

impl Stage {
    /// Create a stage whose loops are exactly the axes in order.
    pub fn from_axes(name: &str, axes: Vec<Axis>, block: Block) -> Stage {
        let loops: Vec<LoopDef> = axes
            .iter()
            .enumerate()
            .map(|(i, a)| LoopDef {
                var: i,
                name: a.name.clone(),
                extent: a.extent,
                kind: LoopKind::Serial,
            })
            .collect();
        let axis_exprs = (0..axes.len()).map(Expr::var).collect();
        let var_extents = axes.iter().map(|a| a.extent).collect();
        Stage {
            name: name.to_string(),
            axes,
            loops,
            axis_exprs,
            var_extents,
            block,
            cache_write: false,
            compute_at: None,
            memo: OnceLock::new(),
        }
    }

    /// Memoized structural hash of this stage (computation structure +
    /// schedule state, names excluded; see `tir::hash::stage_schedule_hash`).
    /// Computed at most once per stage mutation: [`Stage::cow_mut`] clears
    /// the memo, everything else shares it — including clones. This is the
    /// unit the incremental `db::program_fingerprint` combines and the
    /// `cost::AnalysisCache` keys on.
    pub fn struct_hash(&self) -> u64 {
        *self.memo.get_or_init(|| hash::stage_schedule_hash(self))
    }

    /// Copy-on-write mutable access through a shared handle: clones the
    /// stage only if other programs still reference it, and clears the
    /// memoized structural hash (the borrower may change anything). All
    /// stage mutation must go through here — it is what keeps memoized
    /// hashes sound.
    pub fn cow_mut(this: &mut Arc<Stage>) -> &mut Stage {
        let s = Arc::make_mut(this);
        s.memo = OnceLock::new();
        s
    }

    /// Allocate a fresh loop variable.
    pub fn fresh_var(&mut self, extent: i64) -> VarId {
        self.var_extents.push(extent);
        self.var_extents.len() - 1
    }

    /// Total iteration count of the nest.
    pub fn iter_count(&self) -> i64 {
        self.loops.iter().map(|l| l.extent).product()
    }

    /// Index of the loop named `name`, if present.
    pub fn loop_index(&self, name: &str) -> Option<usize> {
        self.loops.iter().position(|l| l.name == name)
    }

    /// Which original axes a loop variable feeds into.
    pub fn axes_of_var(&self, var: VarId) -> Vec<AxisId> {
        let mut out = Vec::new();
        for (a, e) in self.axis_exprs.iter().enumerate() {
            let mut vs = Vec::new();
            e.vars(&mut vs);
            if vs.contains(&var) {
                out.push(a);
            }
        }
        out
    }

    /// True if the loop at `idx` touches any reduction axis.
    pub fn loop_is_reduction(&self, idx: usize) -> bool {
        self.axes_of_var(self.loops[idx].var)
            .iter()
            .any(|&a| self.axes[a].is_reduction)
    }

    /// Structural invariants; used by debug assertions and property tests.
    pub fn validate(&self) -> Result<(), String> {
        // Loop iteration space must equal axis space.
        let loop_space: i64 = self.loops.iter().map(|l| l.extent).product();
        let axis_space: i64 = self.axes.iter().map(|a| a.extent).product();
        if loop_space != axis_space {
            return Err(format!(
                "stage {}: loop space {} != axis space {}",
                self.name, loop_space, axis_space
            ));
        }
        // Every axis expr must only use live loop vars.
        let live: Vec<VarId> = self.loops.iter().map(|l| l.var).collect();
        for (a, e) in self.axis_exprs.iter().enumerate() {
            let mut vs = Vec::new();
            e.vars(&mut vs);
            for v in vs {
                if !live.contains(&v) {
                    return Err(format!(
                        "stage {}: axis {} references dead var {}",
                        self.name, a, v
                    ));
                }
            }
        }
        // Loop extents must match var extents.
        for l in &self.loops {
            if self.var_extents[l.var] != l.extent {
                return Err(format!(
                    "stage {}: loop {} extent {} != var extent {}",
                    self.name, l.name, l.extent, self.var_extents[l.var]
                ));
            }
        }
        // Output indices must not involve reduction axes.
        for idx in &self.block.out_idx {
            for &(a, _) in &idx.terms {
                if self.axes[a].is_reduction && self.block.reduce != ReduceOp::Assign {
                    return Err(format!(
                        "stage {}: output indexed by reduction axis {}",
                        self.name, self.axes[a].name
                    ));
                }
            }
        }
        // compute_at depth in range.
        if let Some(d) = self.compute_at {
            if d > self.loops.len() {
                return Err(format!("stage {}: compute_at {} out of range", self.name, d));
            }
        }
        Ok(())
    }

    /// Floating-point ops for the whole stage (1 mul + 1 add per reduction
    /// update, etc.).
    pub fn flops(&self) -> u64 {
        let per_iter = self.block.rhs.flops()
            + match self.block.reduce {
                ReduceOp::Sum | ReduceOp::Max => 1,
                ReduceOp::Assign => 0,
            };
        per_iter * self.iter_count() as u64
    }
}

/// A tunable tensor program (one TVM-style task).
///
/// Clone is copy-on-write: the buffer table and each stage sit behind
/// `Arc`s, so cloning bumps reference counts and [`Program::stage_mut`] /
/// [`Stage::cow_mut`] clone only the stage actually mutated.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    /// Buffer table; immutable after construction (transforms never add or
    /// reshape buffers), hence shared by every schedule variant.
    pub buffers: Arc<Vec<Buffer>>,
    pub stages: Vec<Arc<Stage>>,
}

impl Program {
    /// Build a program, wrapping buffers and stages for structural sharing.
    pub fn new(name: &str, buffers: Vec<Buffer>, stages: Vec<Stage>) -> Program {
        Program {
            name: name.to_string(),
            buffers: Arc::new(buffers),
            stages: stages.into_iter().map(Arc::new).collect(),
        }
    }

    /// Copy-on-write mutable access to stage `i` (clones the stage if
    /// shared, clears its memoized hash). Panics on out-of-range `i`.
    pub fn stage_mut(&mut self, i: usize) -> &mut Stage {
        Stage::cow_mut(&mut self.stages[i])
    }

    /// Fully independent copy: fresh buffer and stage allocations, memoized
    /// hashes cleared. The from-scratch oracle the incremental-evaluation
    /// property tests compare the CoW path against; never needed on the
    /// search hot path.
    pub fn deep_clone(&self) -> Program {
        Program {
            name: self.name.clone(),
            buffers: Arc::new((*self.buffers).clone()),
            stages: self
                .stages
                .iter()
                .map(|s| {
                    let mut st = (**s).clone();
                    st.memo = OnceLock::new();
                    Arc::new(st)
                })
                .collect(),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        for s in &self.stages {
            s.validate()?;
            if s.block.out >= self.buffers.len() {
                return Err(format!("stage {}: bad output buffer id", s.name));
            }
            let out_buf = &self.buffers[s.block.out];
            if s.block.out_idx.len() != out_buf.shape.len() {
                return Err(format!(
                    "stage {}: output rank {} != buffer rank {}",
                    s.name,
                    s.block.out_idx.len(),
                    out_buf.shape.len()
                ));
            }
            let mut loads = Vec::new();
            s.block.rhs.loads(&mut loads);
            for (b, idx) in loads {
                if b >= self.buffers.len() {
                    return Err(format!("stage {}: bad load buffer id {}", s.name, b));
                }
                if idx.len() != self.buffers[b].shape.len() {
                    return Err(format!(
                        "stage {}: load rank mismatch on {}",
                        s.name, self.buffers[b].name
                    ));
                }
            }
        }
        Ok(())
    }

    pub fn total_flops(&self) -> u64 {
        self.stages.iter().map(|s| s.flops()).sum()
    }

    /// Sum of input/output footprints in bytes (f32).
    pub fn memory_bytes(&self) -> u64 {
        self.buffers.iter().map(|b| b.elems() as u64 * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matmul_4x4x4() -> Program {
        // C[i,j] = sum_k A[i,k] * B[k,j], 4x4x4
        let buffers = vec![
            Buffer { name: "A".into(), shape: vec![4, 4], kind: BufKind::Input },
            Buffer { name: "B".into(), shape: vec![4, 4], kind: BufKind::Input },
            Buffer { name: "C".into(), shape: vec![4, 4], kind: BufKind::Output },
        ];
        let axes = vec![
            Axis { name: "i".into(), extent: 4, is_reduction: false },
            Axis { name: "j".into(), extent: 4, is_reduction: false },
            Axis { name: "k".into(), extent: 4, is_reduction: true },
        ];
        let block = Block {
            name: "matmul".into(),
            out: 2,
            out_idx: vec![LinIdx::axis(0), LinIdx::axis(1)],
            rhs: BlockExpr::mul(
                BlockExpr::load(0, vec![LinIdx::axis(0), LinIdx::axis(2)]),
                BlockExpr::load(1, vec![LinIdx::axis(2), LinIdx::axis(1)]),
            ),
            reduce: ReduceOp::Sum,
        };
        Program::new("matmul", buffers, vec![Stage::from_axes("matmul", axes, block)])
    }

    #[test]
    fn fresh_program_validates() {
        let p = matmul_4x4x4();
        assert!(p.validate().is_ok());
    }

    #[test]
    fn flops_counted() {
        let p = matmul_4x4x4();
        // 64 iterations x (1 mul + 1 reduce-add)
        assert_eq!(p.total_flops(), 128);
    }

    #[test]
    fn buffer_strides_row_major() {
        let b = Buffer { name: "X".into(), shape: vec![2, 3, 4], kind: BufKind::Input };
        assert_eq!(b.strides(), vec![12, 4, 1]);
        assert_eq!(b.flat(&[1, 2, 3]), 23);
        assert_eq!(b.elems(), 24);
    }

    #[test]
    fn validate_catches_space_mismatch() {
        let mut p = matmul_4x4x4();
        p.stage_mut(0).loops[0].extent = 3; // break the space
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_catches_dead_var() {
        let mut p = matmul_4x4x4();
        p.stage_mut(0).axis_exprs[0] = Expr::var(99);
        p.stage_mut(0).var_extents.resize(100, 1);
        assert!(p.validate().is_err());
    }

    #[test]
    fn loop_is_reduction_detects_k() {
        let p = matmul_4x4x4();
        let s = &p.stages[0];
        assert!(!s.loop_is_reduction(0));
        assert!(!s.loop_is_reduction(1));
        assert!(s.loop_is_reduction(2));
    }

    #[test]
    fn axes_of_var_initial_identity() {
        let p = matmul_4x4x4();
        let s = &p.stages[0];
        assert_eq!(s.axes_of_var(0), vec![0]);
        assert_eq!(s.axes_of_var(2), vec![2]);
    }

    #[test]
    fn reduce_op_inits() {
        assert_eq!(ReduceOp::Sum.init_val(), 0.0);
        assert!(ReduceOp::Max.init_val().is_infinite());
    }

    #[test]
    fn clone_shares_stages_until_mutation() {
        let p = matmul_4x4x4();
        let q = p.clone();
        assert!(Arc::ptr_eq(&p.stages[0], &q.stages[0]), "clone must share stages");
        assert!(Arc::ptr_eq(&p.buffers, &q.buffers), "clone must share buffers");
        let mut r = p.clone();
        r.stage_mut(0).loops[0].kind = LoopKind::Unrolled;
        assert!(!Arc::ptr_eq(&p.stages[0], &r.stages[0]), "mutation must un-share");
        assert_eq!(p.stages[0].loops[0].kind, LoopKind::Serial, "original untouched");
        assert_eq!(r.stages[0].loops[0].kind, LoopKind::Unrolled);
    }

    #[test]
    fn struct_hash_memoized_and_invalidated() {
        let mut p = matmul_4x4x4();
        let h0 = p.stages[0].struct_hash();
        assert_eq!(h0, p.stages[0].struct_hash(), "memo stable across calls");
        assert_eq!(
            h0,
            p.clone().stages[0].struct_hash(),
            "clone carries the memo"
        );
        p.stage_mut(0).loops[0].kind = LoopKind::Parallel;
        let h1 = p.stages[0].struct_hash();
        assert_ne!(h0, h1, "mutation must change the hash");
        // A from-scratch recompute (cleared memo) agrees with the memoized one.
        assert_eq!(p.deep_clone().stages[0].struct_hash(), h1);
    }
}
