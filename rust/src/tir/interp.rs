//! Reference interpreter.
//!
//! Executes a (possibly scheduled) program element-by-element, providing the
//! semantic-equivalence oracle for schedule transformations: for any legal
//! transformation sequence, `execute(scheduled)` must match
//! `execute(original)` up to floating reassociation. Used only in tests on
//! miniature shapes — the search path never touches it.

use std::collections::HashMap;

use super::expr::VarId;
use super::program::{BlockExpr, BufKind, Program, ReduceOp, Stage};

/// Dense f32 storage for every buffer of a program.
#[derive(Debug, Clone)]
pub struct Tensors {
    pub data: Vec<Vec<f32>>,
}

impl Tensors {
    /// Allocate all buffers; inputs filled by a deterministic hash-based
    /// pattern in [-1, 1] so tests are reproducible without an RNG.
    pub fn seeded(program: &Program, seed: u64) -> Tensors {
        let data = program
            .buffers
            .iter()
            .enumerate()
            .map(|(bi, b)| {
                let n = b.elems() as usize;
                match b.kind {
                    BufKind::Input => (0..n)
                        .map(|i| {
                            let h = hash3(seed, bi as u64, i as u64);
                            (h as f64 / u64::MAX as f64 * 2.0 - 1.0) as f32
                        })
                        .collect(),
                    _ => vec![0.0; n],
                }
            })
            .collect();
        Tensors { data }
    }

    pub fn output<'a>(&'a self, program: &Program) -> &'a [f32] {
        let idx = program
            .buffers
            .iter()
            .position(|b| b.kind == BufKind::Output)
            .expect("program has no output buffer");
        &self.data[idx]
    }
}

fn hash3(a: u64, b: u64, c: u64) -> u64 {
    let mut x = a ^ b.rotate_left(21) ^ c.rotate_left(42);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51AFD7ED558CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CEB9FE1A85EC53);
    x ^= x >> 33;
    x
}

/// Execute all stages in order over the given tensors.
pub fn execute(program: &Program, tensors: &mut Tensors) {
    for stage in &program.stages {
        execute_stage(program, stage, tensors);
    }
}

/// Execute one stage by walking its loop nest in nest order.
fn execute_stage(program: &Program, stage: &Stage, tensors: &mut Tensors) {
    let n_loops = stage.loops.len();
    let max_var = stage.var_extents.len();
    let mut env = vec![0i64; max_var];
    let mut axes = vec![0i64; stage.axes.len()];

    // Odometer over loop extents, outermost first (order only matters for
    // float reassociation, which tests tolerate).
    let mut counters = vec![0i64; n_loops];
    let total: i64 = stage.loops.iter().map(|l| l.extent).product();
    let reduce = stage.block.reduce;
    let init_val = reduce.init_val();

    for _ in 0..total {
        for (li, l) in stage.loops.iter().enumerate() {
            env[l.var] = counters[li];
        }
        for (ai, e) in stage.axis_exprs.iter().enumerate() {
            axes[ai] = e.eval(&env);
            debug_assert!(
                axes[ai] >= 0 && axes[ai] < stage.axes[ai].extent,
                "axis {} out of range: {}",
                stage.axes[ai].name,
                axes[ai]
            );
        }

        // T.init() semantics: initialize when all reduction axes are zero.
        let out_buf = stage.block.out;
        let out_flat = {
            let idx: Vec<i64> = stage.block.out_idx.iter().map(|ix| ix.eval(&axes)).collect();
            program.buffers[out_buf].flat(&idx) as usize
        };
        if reduce != ReduceOp::Assign {
            let at_init = stage
                .axes
                .iter()
                .enumerate()
                .filter(|(_, a)| a.is_reduction)
                .all(|(ai, _)| axes[ai] == 0);
            if at_init {
                tensors.data[out_buf][out_flat] = init_val;
            }
        }

        let rhs = eval_expr(&stage.block.rhs, program, tensors, &axes);
        let slot = &mut tensors.data[out_buf][out_flat];
        match reduce {
            ReduceOp::Sum => *slot += rhs,
            ReduceOp::Max => *slot = slot.max(rhs),
            ReduceOp::Assign => *slot = rhs,
        }

        // Advance odometer (innermost fastest).
        for li in (0..n_loops).rev() {
            counters[li] += 1;
            if counters[li] < stage.loops[li].extent {
                break;
            }
            counters[li] = 0;
        }
    }
}

fn eval_expr(e: &BlockExpr, program: &Program, tensors: &Tensors, axes: &[i64]) -> f32 {
    match e {
        BlockExpr::Load(buf, idx) => {
            let i: Vec<i64> = idx.iter().map(|ix| ix.eval(axes)).collect();
            let flat = program.buffers[*buf].flat(&i) as usize;
            tensors.data[*buf][flat]
        }
        BlockExpr::Const(c) => *c,
        BlockExpr::Add(a, b) => {
            eval_expr(a, program, tensors, axes) + eval_expr(b, program, tensors, axes)
        }
        BlockExpr::Sub(a, b) => {
            eval_expr(a, program, tensors, axes) - eval_expr(b, program, tensors, axes)
        }
        BlockExpr::Mul(a, b) => {
            eval_expr(a, program, tensors, axes) * eval_expr(b, program, tensors, axes)
        }
        BlockExpr::Max(a, b) => {
            eval_expr(a, program, tensors, axes).max(eval_expr(b, program, tensors, axes))
        }
    }
}

/// Enumerate the multiset of axis tuples a stage's loop nest visits.
/// For a legal schedule this must be exactly the full product space, each
/// tuple once — the exact (non-float) half of the equivalence oracle.
pub fn iteration_space(stage: &Stage) -> Result<(), String> {
    let total: i64 = stage.loops.iter().map(|l| l.extent).product();
    if total > 4_000_000 {
        return Err(format!("iteration space too large to enumerate: {total}"));
    }
    let mut env = vec![0i64; stage.var_extents.len()];
    let mut counters = vec![0i64; stage.loops.len()];
    let mut seen: HashMap<Vec<i64>, u32> = HashMap::with_capacity(total as usize);
    for _ in 0..total {
        for (li, l) in stage.loops.iter().enumerate() {
            env[l.var] = counters[li];
        }
        let axes: Vec<i64> = stage.axis_exprs.iter().map(|e| e.eval(&env)).collect();
        for (ai, &v) in axes.iter().enumerate() {
            if v < 0 || v >= stage.axes[ai].extent {
                return Err(format!(
                    "axis {} out of range: {} (extent {})",
                    stage.axes[ai].name, v, stage.axes[ai].extent
                ));
            }
        }
        *seen.entry(axes).or_insert(0) += 1;
        for li in (0..stage.loops.len()).rev() {
            counters[li] += 1;
            if counters[li] < stage.loops[li].extent {
                break;
            }
            counters[li] = 0;
        }
    }
    let expected: i64 = stage.axes.iter().map(|a| a.extent).product();
    if seen.len() as i64 != expected {
        return Err(format!(
            "visited {} distinct axis tuples, expected {expected}",
            seen.len()
        ));
    }
    if let Some((tuple, count)) = seen.iter().find(|(_, &c)| c != 1) {
        return Err(format!("axis tuple {tuple:?} visited {count} times"));
    }
    Ok(())
}

/// Compare two runs of (possibly differently scheduled) versions of the same
/// program. Relative tolerance absorbs float reassociation from reordered
/// reductions.
pub fn outputs_close(a: &[f32], b: &[f32], rel_tol: f32) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b).all(|(x, y)| {
        let denom = x.abs().max(y.abs()).max(1.0);
        (x - y).abs() / denom <= rel_tol
    })
}

/// Convenience: run `program` on seeded inputs and return the output copy.
pub fn run_seeded(program: &Program, seed: u64) -> Vec<f32> {
    let mut t = Tensors::seeded(program, seed);
    execute(program, &mut t);
    t.output(program).to_vec()
}

/// Map from loop var to its current value — exposed for diagnostics.
pub type Env = Vec<(VarId, i64)>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn moe_matmul_against_manual() {
        let p = workload::moe_matmul("m", 2, 3, 4);
        let mut t = Tensors::seeded(&p, 1);
        // Manual reference matmul.
        let a = t.data[0].clone();
        let b = t.data[1].clone();
        execute(&p, &mut t);
        for ti in 0..2 {
            for j in 0..3 {
                let mut acc = 0.0f32;
                for k in 0..4 {
                    acc += a[ti * 4 + k] * b[k * 3 + j];
                }
                let got = t.data[2][ti * 3 + j];
                assert!((acc - got).abs() < 1e-5, "C[{ti},{j}]: {acc} vs {got}");
            }
        }
    }

    #[test]
    fn conv_against_manual() {
        let p = workload::conv2d("c", 2, 2, 5, 5, 3);
        let mut t = Tensors::seeded(&p, 2);
        let inp = t.data[0].clone();
        let wt = t.data[1].clone();
        execute(&p, &mut t);
        // O[co,h,w] = sum I[ci,h+kh,w+kw] * W[co,ci,kh,kw]
        for co in 0..2usize {
            for h in 0..3usize {
                for w in 0..3usize {
                    let mut acc = 0.0f32;
                    for ci in 0..2usize {
                        for kh in 0..3usize {
                            for kw in 0..3usize {
                                acc += inp[ci * 25 + (h + kh) * 5 + (w + kw)]
                                    * wt[co * 18 + ci * 9 + kh * 3 + kw];
                            }
                        }
                    }
                    let got = t.data[2][co * 9 + h * 3 + w];
                    assert!((acc - got).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn attention_chains_stages() {
        let p = workload::attention("a", 1, 3, 2);
        let mut t = Tensors::seeded(&p, 3);
        let q = t.data[0].clone();
        let k = t.data[1].clone();
        let v = t.data[2].clone();
        execute(&p, &mut t);
        // S[i,j] = sum_d Q[i,d] K[j,d]; O[i,d] = sum_j S[i,j] V[j,d]
        let mut s = vec![0.0f32; 9];
        for i in 0..3 {
            for j in 0..3 {
                for d in 0..2 {
                    s[i * 3 + j] += q[i * 2 + d] * k[j * 2 + d];
                }
            }
        }
        for i in 0..3 {
            for d in 0..2 {
                let mut acc = 0.0f32;
                for j in 0..3 {
                    acc += s[i * 3 + j] * v[j * 2 + d];
                }
                let got = t.data[4][i * 2 + d];
                assert!((acc - got).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn all_test_workloads_execute() {
        for w in WorkloadId::ALL {
            let p = w.build_test();
            let out = run_seeded(&p, 7);
            assert!(out.iter().all(|x| x.is_finite()), "{}", w.name());
            assert!(out.iter().any(|x| *x != 0.0), "{}", w.name());
        }
    }

    #[test]
    fn iteration_space_fresh_program_ok() {
        for w in WorkloadId::ALL {
            let p = w.build_test();
            for s in &p.stages {
                iteration_space(s).unwrap();
            }
        }
    }

    #[test]
    fn seeded_inputs_deterministic() {
        let p = workload::moe_matmul("m", 2, 3, 4);
        assert_eq!(run_seeded(&p, 9), run_seeded(&p, 9));
        assert_ne!(run_seeded(&p, 9), run_seeded(&p, 10));
    }

    #[test]
    fn outputs_close_tolerances() {
        assert!(outputs_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-4));
        assert!(!outputs_close(&[1.0], &[1.1], 1e-4));
        assert!(!outputs_close(&[1.0], &[1.0, 2.0], 1e-4));
    }
}
