//! The paper's evaluation workloads (§4.1) as TIR programs.
//!
//! Five layer-wise kernels plus the end-to-end Llama-3-8B task set. Each
//! builder is parameterized by shape so tests can run miniature versions
//! through the interpreter while the search uses production shapes; the
//! cost models are analytical, so large extents are free.

use super::expr::LinIdx;
use super::program::{Axis, Block, BlockExpr, BufKind, Buffer, Program, ReduceOp, Stage};

/// The five layer-wise benchmarks of the paper, in Table-1 order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadId {
    Llama3Attention,
    DeepSeekMoe,
    FluxAttention,
    FluxConv,
    Llama4Mlp,
}

impl WorkloadId {
    pub const ALL: [WorkloadId; 5] = [
        WorkloadId::Llama3Attention,
        WorkloadId::DeepSeekMoe,
        WorkloadId::FluxAttention,
        WorkloadId::FluxConv,
        WorkloadId::Llama4Mlp,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadId::Llama3Attention => "llama3_attention",
            WorkloadId::DeepSeekMoe => "deepseek_moe",
            WorkloadId::FluxAttention => "flux_attention",
            WorkloadId::FluxConv => "flux_conv",
            WorkloadId::Llama4Mlp => "llama4_mlp",
        }
    }

    pub fn display(&self) -> &'static str {
        match self {
            WorkloadId::Llama3Attention => "Llama-3-8B Attention Layer",
            WorkloadId::DeepSeekMoe => "DeepSeek-R1 MoE Layer",
            WorkloadId::FluxAttention => "FLUX Attention Layer",
            WorkloadId::FluxConv => "FLUX Convolution Layer",
            WorkloadId::Llama4Mlp => "Llama-4-Scout MLP Layer",
        }
    }

    pub fn from_name(s: &str) -> Option<WorkloadId> {
        WorkloadId::ALL.iter().copied().find(|w| w.name() == s)
    }

    /// Production-shape program used by the search experiments.
    pub fn build(&self) -> Program {
        match self {
            // Llama-3-8B: 32 heads x d=128, scored over seq 1024.
            WorkloadId::Llama3Attention => attention("llama3_attention", 32, 1024, 128),
            // The paper's Appendix-A example: C[1,16,2048] = A[1,16,7168] x B[7168,2048].
            WorkloadId::DeepSeekMoe => moe_matmul("deepseek_moe", 16, 2048, 7168),
            // FLUX DiT: 24 heads x d=128 over 1024 image tokens.
            WorkloadId::FluxAttention => attention("flux_attention", 24, 1024, 128),
            // FLUX conv block: 3x3, 128->128 channels, 64x64 feature map.
            WorkloadId::FluxConv => conv2d("flux_conv", 128, 128, 64, 64, 3),
            // Llama-4-Scout gated MLP: [16,5120] x [5120,8192].
            WorkloadId::Llama4Mlp => moe_matmul("llama4_mlp", 16, 8192, 5120),
        }
    }

    /// Miniature shape for interpreter-backed correctness tests.
    pub fn build_test(&self) -> Program {
        match self {
            WorkloadId::Llama3Attention => attention("llama3_attention_test", 2, 8, 4),
            WorkloadId::DeepSeekMoe => moe_matmul("deepseek_moe_test", 4, 6, 8),
            WorkloadId::FluxAttention => attention("flux_attention_test", 2, 6, 4),
            WorkloadId::FluxConv => conv2d("flux_conv_test", 4, 4, 6, 6, 3),
            WorkloadId::Llama4Mlp => moe_matmul("llama4_mlp_test", 4, 8, 6),
        }
    }
}

/// Batched attention-score + weighted-sum matmuls:
/// S[h,i,j]  = sum_d Q[h,i,d] * K[h,j,d]
/// O[h,i,d]  = sum_j S[h,i,j] * V[h,j,d]
///
/// The softmax between the two matmuls is elementwise and lives in the L1
/// Pallas kernel; schedule tuning (as in TVM task extraction) targets the
/// matmul-dominant nests.
pub fn attention(name: &str, heads: i64, seq: i64, dim: i64) -> Program {
    let buffers = vec![
        Buffer { name: "Q".into(), shape: vec![heads, seq, dim], kind: BufKind::Input },
        Buffer { name: "K".into(), shape: vec![heads, seq, dim], kind: BufKind::Input },
        Buffer { name: "V".into(), shape: vec![heads, seq, dim], kind: BufKind::Input },
        Buffer { name: "S".into(), shape: vec![heads, seq, seq], kind: BufKind::Intermediate },
        Buffer { name: "O".into(), shape: vec![heads, seq, dim], kind: BufKind::Output },
    ];

    // Stage 1: scores.
    let axes1 = vec![
        Axis { name: "h".into(), extent: heads, is_reduction: false },
        Axis { name: "i".into(), extent: seq, is_reduction: false },
        Axis { name: "j".into(), extent: seq, is_reduction: false },
        Axis { name: "d".into(), extent: dim, is_reduction: true },
    ];
    let block1 = Block {
        name: "scores".into(),
        out: 3,
        out_idx: vec![LinIdx::axis(0), LinIdx::axis(1), LinIdx::axis(2)],
        rhs: BlockExpr::mul(
            BlockExpr::load(0, vec![LinIdx::axis(0), LinIdx::axis(1), LinIdx::axis(3)]),
            BlockExpr::load(1, vec![LinIdx::axis(0), LinIdx::axis(2), LinIdx::axis(3)]),
        ),
        reduce: ReduceOp::Sum,
    };

    // Stage 2: output = S @ V.
    let axes2 = vec![
        Axis { name: "h".into(), extent: heads, is_reduction: false },
        Axis { name: "i".into(), extent: seq, is_reduction: false },
        Axis { name: "d".into(), extent: dim, is_reduction: false },
        Axis { name: "j".into(), extent: seq, is_reduction: true },
    ];
    let block2 = Block {
        name: "attn_out".into(),
        out: 4,
        out_idx: vec![LinIdx::axis(0), LinIdx::axis(1), LinIdx::axis(2)],
        rhs: BlockExpr::mul(
            BlockExpr::load(3, vec![LinIdx::axis(0), LinIdx::axis(1), LinIdx::axis(3)]),
            BlockExpr::load(2, vec![LinIdx::axis(0), LinIdx::axis(3), LinIdx::axis(2)]),
        ),
        reduce: ReduceOp::Sum,
    };

    Program::new(
        name,
        buffers,
        vec![
            Stage::from_axes("scores", axes1, block1),
            Stage::from_axes("attn_out", axes2, block2),
        ],
    )
}

/// Token-by-expert matmul (the paper's running example):
/// C[t,j] = sum_k A[t,k] * B[k,j].
pub fn moe_matmul(name: &str, tokens: i64, out_dim: i64, in_dim: i64) -> Program {
    let buffers = vec![
        Buffer { name: "A".into(), shape: vec![tokens, in_dim], kind: BufKind::Input },
        Buffer { name: "B".into(), shape: vec![in_dim, out_dim], kind: BufKind::Input },
        Buffer { name: "C".into(), shape: vec![tokens, out_dim], kind: BufKind::Output },
    ];
    let axes = vec![
        Axis { name: "t".into(), extent: tokens, is_reduction: false },
        Axis { name: "j".into(), extent: out_dim, is_reduction: false },
        Axis { name: "k".into(), extent: in_dim, is_reduction: true },
    ];
    let block = Block {
        name: "moe".into(),
        out: 2,
        out_idx: vec![LinIdx::axis(0), LinIdx::axis(1)],
        rhs: BlockExpr::mul(
            BlockExpr::load(0, vec![LinIdx::axis(0), LinIdx::axis(2)]),
            BlockExpr::load(1, vec![LinIdx::axis(2), LinIdx::axis(1)]),
        ),
        reduce: ReduceOp::Sum,
    };
    Program::new(name, buffers, vec![Stage::from_axes("moe", axes, block)])
}

/// Direct 2-D convolution (stride 1, valid padding):
/// O[co, h, w] = sum_{ci,kh,kw} I[ci, h+kh, w+kw] * W[co, ci, kh, kw].
pub fn conv2d(name: &str, c_out: i64, c_in: i64, height: i64, width: i64, ksize: i64) -> Program {
    let oh = height - ksize + 1;
    let ow = width - ksize + 1;
    let buffers = vec![
        Buffer { name: "I".into(), shape: vec![c_in, height, width], kind: BufKind::Input },
        Buffer { name: "W".into(), shape: vec![c_out, c_in, ksize, ksize], kind: BufKind::Input },
        Buffer { name: "O".into(), shape: vec![c_out, oh, ow], kind: BufKind::Output },
    ];
    let axes = vec![
        Axis { name: "co".into(), extent: c_out, is_reduction: false },
        Axis { name: "h".into(), extent: oh, is_reduction: false },
        Axis { name: "w".into(), extent: ow, is_reduction: false },
        Axis { name: "ci".into(), extent: c_in, is_reduction: true },
        Axis { name: "kh".into(), extent: ksize, is_reduction: true },
        Axis { name: "kw".into(), extent: ksize, is_reduction: true },
    ];
    let block = Block {
        name: "conv2d".into(),
        out: 2,
        out_idx: vec![LinIdx::axis(0), LinIdx::axis(1), LinIdx::axis(2)],
        rhs: BlockExpr::mul(
            BlockExpr::load(
                0,
                vec![
                    LinIdx::axis(3),
                    LinIdx::axis_sum(1, 4),
                    LinIdx::axis_sum(2, 5),
                ],
            ),
            BlockExpr::load(
                1,
                vec![LinIdx::axis(0), LinIdx::axis(3), LinIdx::axis(4), LinIdx::axis(5)],
            ),
        ),
        reduce: ReduceOp::Sum,
    };
    Program::new(name, buffers, vec![Stage::from_axes("conv2d", axes, block)])
}

/// Plain dense matmul task used by the end-to-end decomposition.
pub fn dense(name: &str, m: i64, n: i64, k: i64) -> Program {
    moe_matmul(name, m, n, k)
}

/// One task of an end-to-end model: a program plus how many times it runs
/// per forward pass (its weight in the end-to-end latency).
#[derive(Debug, Clone)]
pub struct E2eTask {
    pub program: Program,
    pub invocations: u64,
}

/// End-to-end Llama-3-8B (one transformer layer's task set; the model is 32
/// identical layers, so per-layer tuning decisions transfer — matching how
/// TVM tunes unique tasks once and reuses them).
///
/// Dimensions follow the public Llama-3-8B config (hidden 4096, heads 32,
/// kv-heads 8, head-dim 128, ffn 14336) with sequence length 256 for the
/// serving scenario; the attention scores use the shared attention builder.
pub fn llama3_e2e(seq: i64) -> Vec<E2eTask> {
    let hidden = 4096;
    let heads = 32;
    let head_dim = 128;
    let kv_hidden = 8 * head_dim; // 8 kv heads
    let ffn = 14336;
    vec![
        E2eTask { program: dense("l3_q_proj", seq, hidden, hidden), invocations: 32 },
        E2eTask { program: dense("l3_kv_proj", seq, kv_hidden, hidden), invocations: 64 },
        E2eTask { program: attention("l3_attention", heads, seq, head_dim), invocations: 32 },
        E2eTask { program: dense("l3_o_proj", seq, hidden, hidden), invocations: 32 },
        E2eTask { program: dense("l3_gate_up", seq, ffn, hidden), invocations: 64 },
        E2eTask { program: dense("l3_down", seq, hidden, ffn), invocations: 32 },
    ]
}

/// Miniature end-to-end task set for tests.
pub fn llama3_e2e_test() -> Vec<E2eTask> {
    vec![
        E2eTask { program: dense("l3_q_proj_t", 4, 8, 8), invocations: 2 },
        E2eTask { program: attention("l3_attention_t", 2, 4, 4), invocations: 2 },
        E2eTask { program: dense("l3_down_t", 4, 8, 6), invocations: 2 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_paper_workloads_validate() {
        for w in WorkloadId::ALL {
            let p = w.build();
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", w.name()));
            let t = w.build_test();
            t.validate().unwrap_or_else(|e| panic!("{} test: {e}", w.name()));
        }
    }

    #[test]
    fn moe_matches_paper_appendix_shape() {
        let p = WorkloadId::DeepSeekMoe.build();
        // C[16,2048] = A[16,7168] x B[7168,2048]
        assert_eq!(p.buffers[0].shape, vec![16, 7168]);
        assert_eq!(p.buffers[1].shape, vec![7168, 2048]);
        assert_eq!(p.buffers[2].shape, vec![16, 2048]);
        // 16*2048*7168 iterations x 2 flops
        assert_eq!(p.total_flops(), 2 * 16 * 2048 * 7168);
    }

    #[test]
    fn attention_two_stages() {
        let p = WorkloadId::Llama3Attention.build();
        assert_eq!(p.stages.len(), 2);
        assert_eq!(p.stages[0].name, "scores");
        assert_eq!(p.stages[1].name, "attn_out");
        // scores: h*i*j*d iterations
        assert_eq!(p.stages[0].iter_count(), 32 * 1024 * 1024 * 128);
    }

    #[test]
    fn conv_output_shape() {
        let p = conv2d("c", 8, 4, 10, 10, 3);
        assert_eq!(p.buffers[2].shape, vec![8, 8, 8]);
        p.validate().unwrap();
    }

    #[test]
    fn e2e_task_set_nonempty_and_valid() {
        let tasks = llama3_e2e(256);
        assert_eq!(tasks.len(), 6);
        for t in &tasks {
            t.program.validate().unwrap();
            assert!(t.invocations > 0);
        }
    }

    #[test]
    fn workload_name_roundtrip() {
        for w in WorkloadId::ALL {
            assert_eq!(WorkloadId::from_name(w.name()), Some(w));
        }
        assert_eq!(WorkloadId::from_name("nope"), None);
    }
}
