//! TVMScript-flavoured textual rendering of programs.
//!
//! The prompt generator (reasoning::prompt) embeds this text verbatim, the
//! same way the paper's Appendix-A prompt embeds the IRModule; it is also
//! what `rcc show` prints. The dialect mirrors the paper's example:
//! `T.grid`, `T.block`, `T.init`.

use super::program::{BlockExpr, LoopKind, Program, ReduceOp, Stage};

/// Render a whole program.
pub fn print_program(p: &Program) -> String {
    let mut out = String::new();
    out.push_str("@tvm.script.ir_module\n");
    out.push_str(&format!("class {}:\n", camel(&p.name)));
    out.push_str("  @T.prim_func\n  def main(\n");
    for b in p.buffers.iter() {
        let dims = b
            .shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        out.push_str(&format!("    {}: T.Buffer(({dims}), \"float32\"),\n", b.name));
    }
    out.push_str("  ):\n");
    for s in &p.stages {
        out.push_str(&print_stage(p, s, 4));
    }
    out
}

/// Render one stage's loop nest + block at the given indent.
pub fn print_stage(p: &Program, s: &Stage, indent: usize) -> String {
    let mut out = String::new();
    let pad = |n: usize| " ".repeat(n);

    // Loop header lines; consecutive serial loops are folded into one
    // T.grid as in TVMScript.
    let mut depth = indent;
    let mut i = 0;
    while i < s.loops.len() {
        let l = &s.loops[i];
        match l.kind {
            LoopKind::Serial => {
                let mut names = vec![l.name.clone()];
                let mut extents = vec![l.extent.to_string()];
                let mut j = i + 1;
                while j < s.loops.len() && s.loops[j].kind == LoopKind::Serial {
                    names.push(s.loops[j].name.clone());
                    extents.push(s.loops[j].extent.to_string());
                    j += 1;
                }
                out.push_str(&format!(
                    "{}for {} in T.grid({}):\n",
                    pad(depth),
                    names.join(", "),
                    extents.join(", ")
                ));
                i = j;
            }
            LoopKind::Parallel => {
                out.push_str(&format!(
                    "{}for {} in T.parallel({}):\n",
                    pad(depth),
                    l.name,
                    l.extent
                ));
                i += 1;
            }
            LoopKind::Vectorized => {
                out.push_str(&format!(
                    "{}for {} in T.vectorized({}):\n",
                    pad(depth),
                    l.name,
                    l.extent
                ));
                i += 1;
            }
            LoopKind::Unrolled => {
                out.push_str(&format!(
                    "{}for {} in T.unroll({}):\n",
                    pad(depth),
                    l.name,
                    l.extent
                ));
                i += 1;
            }
        }
        depth += 2;
    }

    // Block body.
    let name_of = |v: usize| {
        s.loops
            .iter()
            .find(|l| l.var == v)
            .map(|l| l.name.clone())
            .unwrap_or_else(|| format!("v{v}"))
    };
    out.push_str(&format!("{}with T.block(\"{}\"):\n", pad(depth), s.block.name));
    depth += 2;
    for (ai, axis) in s.axes.iter().enumerate() {
        out.push_str(&format!(
            "{}v{} = {}  # {} axis, extent {}\n",
            pad(depth),
            axis.name,
            s.axis_exprs[ai].render(&name_of),
            if axis.is_reduction { "reduce" } else { "spatial" },
            axis.extent
        ));
    }
    let axis_name = |a: usize| format!("v{}", s.axes[a].name);
    let out_buf = &p.buffers[s.block.out];
    let out_idx = s
        .block
        .out_idx
        .iter()
        .map(|ix| ix.render(&axis_name))
        .collect::<Vec<_>>()
        .join(", ");
    if s.block.reduce != ReduceOp::Assign {
        out.push_str(&format!("{}with T.init():\n", pad(depth)));
        out.push_str(&format!(
            "{}{}[{}] = T.float32({})\n",
            pad(depth + 2),
            out_buf.name,
            out_idx,
            s.block.reduce.init_val()
        ));
    }
    let rhs = print_expr(p, &s.block.rhs, &axis_name);
    let op = match s.block.reduce {
        ReduceOp::Sum => format!("{}[{out_idx}] + {rhs}", out_buf.name),
        ReduceOp::Max => format!("T.max({}[{out_idx}], {rhs})", out_buf.name),
        ReduceOp::Assign => rhs.clone(),
    };
    out.push_str(&format!("{}{}[{}] = {}\n", pad(depth), out_buf.name, out_idx, op));

    // Schedule annotations that are not visible in the nest itself.
    if s.cache_write {
        out.push_str(&format!(
            "{}# sch: cache_write({}, \"local\")\n",
            pad(indent),
            s.block.name
        ));
    }
    if let Some(d) = s.compute_at {
        out.push_str(&format!(
            "{}# sch: compute_at(depth={d})\n",
            pad(indent)
        ));
    }
    out
}

fn print_expr(p: &Program, e: &BlockExpr, axis_name: &dyn Fn(usize) -> String) -> String {
    match e {
        BlockExpr::Load(b, idx) => {
            let parts = idx
                .iter()
                .map(|ix| ix.render(axis_name))
                .collect::<Vec<_>>()
                .join(", ");
            format!("{}[{}]", p.buffers[*b].name, parts)
        }
        BlockExpr::Const(c) => format!("T.float32({c})"),
        BlockExpr::Add(a, b) => format!(
            "({} + {})",
            print_expr(p, a, axis_name),
            print_expr(p, b, axis_name)
        ),
        BlockExpr::Sub(a, b) => format!(
            "({} - {})",
            print_expr(p, a, axis_name),
            print_expr(p, b, axis_name)
        ),
        BlockExpr::Mul(a, b) => format!(
            "{} * {}",
            print_expr(p, a, axis_name),
            print_expr(p, b, axis_name)
        ),
        BlockExpr::Max(a, b) => format!(
            "T.max({}, {})",
            print_expr(p, a, axis_name),
            print_expr(p, b, axis_name)
        ),
    }
}

/// Compact one-line summary of a stage's loop structure, e.g.
/// `parallel t(16) . j_0(4) . j_1(8) . k(7168) . vectorized j_2(64)`.
/// Used in prompt diffs.
pub fn loop_signature(s: &Stage) -> String {
    s.loops
        .iter()
        .map(|l| {
            let prefix = match l.kind {
                LoopKind::Serial => "",
                LoopKind::Parallel => "parallel ",
                LoopKind::Vectorized => "vectorized ",
                LoopKind::Unrolled => "unrolled ",
            };
            format!("{prefix}{}({})", l.name, l.extent)
        })
        .collect::<Vec<_>>()
        .join(" . ")
}

fn camel(s: &str) -> String {
    s.split(['_', '-'])
        .map(|w| {
            let mut c = w.chars();
            match c.next() {
                Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
                None => String::new(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload;

    #[test]
    fn moe_prints_paper_like_text() {
        let p = workload::moe_matmul("deepseek_moe", 16, 2048, 7168);
        let text = print_program(&p);
        assert!(text.contains("@tvm.script.ir_module"), "{text}");
        assert!(text.contains("class DeepseekMoe:"));
        assert!(text.contains("A: T.Buffer((16, 7168), \"float32\")"));
        assert!(text.contains("for t, j, k in T.grid(16, 2048, 7168):"));
        assert!(text.contains("with T.block(\"moe\"):"));
        assert!(text.contains("with T.init():"));
        assert!(text.contains("C[vt, vj] = C[vt, vj] + A[vt, vk] * B[vk, vj]"));
    }

    #[test]
    fn conv_prints_summed_indices() {
        let p = workload::conv2d("flux_conv", 4, 4, 8, 8, 3);
        let text = print_program(&p);
        assert!(text.contains("I[vci, vh + vkh, vw + vkw]"), "{text}");
    }

    #[test]
    fn loop_signature_compact() {
        let p = workload::moe_matmul("m", 16, 2048, 7168);
        let sig = loop_signature(&p.stages[0]);
        assert_eq!(sig, "t(16) . j(2048) . k(7168)");
    }

    #[test]
    fn printer_total_for_attention() {
        let p = workload::attention("a", 2, 4, 4);
        let text = print_program(&p);
        // Both stages present.
        assert!(text.contains("T.block(\"scores\")"));
        assert!(text.contains("T.block(\"attn_out\")"));
    }
}
