//! PJRT runtime: load and execute the AOT artifacts produced by the Python
//! build path (`make artifacts`). HLO text in, compiled executables out —
//! see /opt/xla-example/load_hlo for the reference wiring and DESIGN.md for
//! why text (not serialized protos) is the interchange format.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{ExecOutput, Executable, Runtime};
