//! PJRT execution of AOT artifacts.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` compiles HLO text produced by
//! `python/compile/aot.py`; executables run with f32 literal inputs. This
//! is the only place the process touches XLA — Python never runs at serve
//! time.
//!
//! The native XLA library is not available everywhere the search/tuning
//! stack needs to build, so the real client lives behind the `xla` cargo
//! feature. Without it, [`Runtime::cpu`] returns a descriptive error and
//! everything that gates on artifact discovery (tests, serving demos)
//! skips gracefully.

use crate::util::rng::Pcg;

use super::artifacts::ArtifactSpec;

/// Result of one execution.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened f32 payloads, one per declared output.
    pub outputs: Vec<Vec<f32>>,
    pub latency_s: f64,
}

/// Deterministic pseudo-random inputs matching an artifact's shapes
/// (for smoke runs, serving demos and latency measurement).
fn random_inputs_for(spec: &ArtifactSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg::new(seed ^ 0xDA7A);
    spec.inputs
        .iter()
        .map(|s| {
            (0..s.elems())
                .map(|_| (rng.gen_f64() * 2.0 - 1.0) as f32)
                .collect()
        })
        .collect()
}

#[cfg(feature = "xla")]
mod imp {
    use std::collections::BTreeMap;
    use std::time::Instant;

    use anyhow::{Context, Result};

    use super::super::artifacts::{ArtifactSpec, Manifest};
    use super::ExecOutput;

    /// A loaded, compiled artifact ready to execute.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT runtime: one CPU client + a cache of compiled artifacts.
    pub struct Runtime {
        client: xla::PjRtClient,
        loaded: BTreeMap<String, Executable>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, loaded: BTreeMap::new() })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (and cache) one artifact from the manifest.
        pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<&Executable> {
            if !self.loaded.contains_key(name) {
                let spec = manifest.get(name)?.clone();
                let proto = xla::HloModuleProto::from_text_file(
                    spec.hlo_path
                        .to_str()
                        .context("artifact path not valid UTF-8")?,
                )
                .with_context(|| format!("parsing HLO text for {name}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {name}"))?;
                self.loaded
                    .insert(name.to_string(), Executable { spec, exe });
            }
            Ok(&self.loaded[name])
        }

        /// Load every artifact in the manifest.
        pub fn load_all(&mut self, manifest: &Manifest) -> Result<usize> {
            for name in manifest.artifacts.keys() {
                self.load(manifest, name)?;
            }
            Ok(self.loaded.len())
        }

        pub fn get(&self, name: &str) -> Option<&Executable> {
            self.loaded.get(name)
        }
    }

    impl Executable {
        /// Execute with the given flattened f32 inputs (lengths must match the
        /// manifest shapes). Returns per-output payloads + wall latency.
        pub fn run(&self, inputs: &[Vec<f32>]) -> Result<ExecOutput> {
            anyhow::ensure!(
                inputs.len() == self.spec.inputs.len(),
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, spec) in inputs.iter().zip(&self.spec.inputs) {
                anyhow::ensure!(
                    data.len() == spec.elems(),
                    "{}: input payload {} elems, shape wants {}",
                    self.spec.name,
                    data.len(),
                    spec.elems()
                );
                let lit = xla::Literal::vec1(data).reshape(&spec.shape)?;
                literals.push(lit);
            }
            let t0 = Instant::now();
            let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            let latency_s = t0.elapsed().as_secs_f64();
            // aot.py lowers with return_tuple=True: unpack the tuple.
            let tuple = result.decompose_tuple()?;
            let mut outputs = Vec::with_capacity(tuple.len());
            for lit in tuple {
                outputs.push(lit.to_vec::<f32>()?);
            }
            Ok(ExecOutput { outputs, latency_s })
        }

        pub fn random_inputs(&self, seed: u64) -> Vec<Vec<f32>> {
            super::random_inputs_for(&self.spec, seed)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use std::collections::BTreeMap;

    use anyhow::{anyhow, Result};

    use super::super::artifacts::{ArtifactSpec, Manifest};
    use super::ExecOutput;

    fn feature_missing() -> anyhow::Error {
        anyhow!(
            "built without the `xla` feature — rebuild with `cargo build --features xla` \
             (requires the native XLA library) to execute AOT artifacts"
        )
    }

    /// Stub artifact handle (never constructed without the `xla` feature —
    /// [`Runtime::cpu`] is the only way in and it always errors).
    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    /// Stub runtime so the serving/coordinator layers compile and report a
    /// clear error instead of failing to link against libxla.
    pub struct Runtime {
        loaded: BTreeMap<String, Executable>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(feature_missing())
        }

        pub fn platform_name(&self) -> String {
            "stub (no xla feature)".to_string()
        }

        pub fn load(&mut self, _manifest: &Manifest, _name: &str) -> Result<&Executable> {
            Err(feature_missing())
        }

        pub fn load_all(&mut self, _manifest: &Manifest) -> Result<usize> {
            Err(feature_missing())
        }

        pub fn get(&self, name: &str) -> Option<&Executable> {
            self.loaded.get(name)
        }
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Vec<f32>]) -> Result<ExecOutput> {
            Err(feature_missing())
        }

        pub fn random_inputs(&self, seed: u64) -> Vec<Vec<f32>> {
            super::random_inputs_for(&self.spec, seed)
        }
    }
}

pub use imp::{Executable, Runtime};
