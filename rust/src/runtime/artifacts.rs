//! Artifact manifest: what the Python build path produced.
//!
//! `make artifacts` writes `artifacts/<name>.hlo.txt` files plus
//! `manifest.json` describing argument/output shapes. This module parses
//! the manifest (with the in-repo JSON parser) and locates artifact files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one tensor boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<i64>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The full artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        Self::parse(dir, &text)
    }

    /// Default location relative to the repo root / current dir.
    pub fn discover() -> Result<Manifest> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let dir = PathBuf::from(cand);
            if dir.join("manifest.json").exists() {
                return Self::load(&dir);
            }
        }
        Err(anyhow!(
            "no artifacts/manifest.json found — run `make artifacts` first"
        ))
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let json = Json::parse(text).ok_or_else(|| anyhow!("malformed manifest.json"))?;
        let Json::Obj(entries) = &json else {
            return Err(anyhow!("manifest root must be an object"));
        };
        let mut artifacts = BTreeMap::new();
        for (name, entry) in entries {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                let arr = entry
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?;
                arr.iter()
                    .map(|t| {
                        let shape = t
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .ok_or_else(|| anyhow!("{name}: bad shape"))?
                            .iter()
                            .map(|d| d.as_f64().unwrap_or(0.0) as i64)
                            .collect();
                        let dtype = t
                            .get("dtype")
                            .and_then(|d| d.as_str())
                            .unwrap_or("float32")
                            .to_string();
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    hlo_path: dir.join(file),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "deepseek_moe": {
        "file": "deepseek_moe.hlo.txt",
        "inputs": [
          {"shape": [16, 512], "dtype": "float32"},
          {"shape": [4, 512, 256], "dtype": "float32"},
          {"shape": [16, 4], "dtype": "float32"}
        ],
        "outputs": [{"shape": [16, 256], "dtype": "float32"}]
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let a = m.get("deepseek_moe").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![16, 512]);
        assert_eq!(a.inputs[0].elems(), 16 * 512);
        assert_eq!(a.outputs[0].shape, vec![16, 256]);
        assert!(a.hlo_path.ends_with("deepseek_moe.hlo.txt"));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "[1,2]").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Exercised fully in integration tests; here just check discovery
        // doesn't panic.
        let _ = Manifest::discover();
    }
}
