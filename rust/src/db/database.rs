//! The persistent tuning-record database.
//!
//! A [`Database`] is a JSONL file of [`TuningRecord`]s plus an in-memory
//! view. Sessions open it, derive warm-start hints for their workload,
//! append the records their runs produce, and commit — append-only, so
//! concurrent readers never see torn earlier records and a crashed run
//! loses at most its own uncommitted tail. Malformed lines are counted and
//! skipped, never fatal: the database must survive version drift.
//!
//! Writes (commit, [`Database::gc`]) take an advisory file lock — a
//! `<db>.lock` sibling created with `O_CREAT|O_EXCL` semantics — so
//! parallel tuners (threads or separate processes) can share one database
//! file without interleaving partial lines or losing appends. Stale locks
//! left by crashed writers are broken after a timeout.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs;
use crate::schedule::{Schedule, Transform};
use crate::tir::Program;
use crate::transfer::index::{dominated_positions, TransferIndex};
use crate::util::json::{self, Json};

use super::cache::MeasureCache;
use super::fingerprint::{program_fingerprint, workload_fingerprint};
use super::record::TuningRecord;

/// Warm-start hints for one search run: known-good traces (best first) with
/// their previously measured latencies. MCTS seeds root children from
/// these; evolutionary search seeds its initial population.
#[derive(Debug, Clone, Default)]
pub struct WarmStart {
    pub entries: Vec<(Vec<Transform>, f64)>,
}

impl WarmStart {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Aggregate statistics for `rcc db stats`.
#[derive(Debug, Clone)]
pub struct DbStats {
    pub records: usize,
    /// Distinct (workload fingerprint, platform) pairs.
    pub pairs: usize,
    pub workloads: Vec<String>,
    pub platforms: Vec<String>,
    /// Malformed JSONL lines skipped at load time.
    pub skipped_lines: usize,
    /// Lifetime malformed-line skips: the header-carried count, never less
    /// than what this load observed (gc preserves foreign lines in place,
    /// so a plain sum would double-count them).
    pub cum_skipped: usize,
    /// Outcome of the most recent `rcc db gc`, carried in the header line.
    pub last_gc: Option<GcInfo>,
}

impl DbStats {
    pub fn render(&self) -> String {
        let last_gc = match &self.last_gc {
            Some(g) => format!(
                "kept {} dropped {} at unix {}",
                g.kept, g.dropped, g.timestamp
            ),
            None => "never".to_string(),
        };
        format!(
            "{} records over {} (workload, platform) pairs\n\
             workloads: {}\nplatforms: {}\nskipped malformed lines: {}\n\
             telemetry: cumulative skipped lines: {}\ntelemetry: last gc: {}",
            self.records,
            self.pairs,
            if self.workloads.is_empty() { "-".to_string() } else { self.workloads.join(", ") },
            if self.platforms.is_empty() { "-".to_string() } else { self.platforms.join(", ") },
            self.skipped_lines,
            self.cum_skipped,
            last_gc
        )
    }
}

/// Telemetry snapshot of the most recent gc pass, persisted in the header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcInfo {
    pub kept: usize,
    pub dropped: usize,
    /// Unix seconds when the pass ran.
    pub timestamp: u64,
}

/// Marker key of the database header line. The header is telemetry only —
/// written (first line) exclusively by `gc`, recognized and excluded from
/// the skip count on load, and never emitted by `commit` (appends land
/// after it, so it stays first). Loaders that predate it see one more
/// unparseable line — version drift stays non-fatal in both directions.
const HEADER_KEY: &str = "rcc_db_header";

fn parse_header(line: &str) -> Option<(usize, Option<GcInfo>)> {
    let doc = Json::parse(line.trim())?;
    doc.get(HEADER_KEY)?;
    let cum = doc.get("cum_skipped").and_then(Json::as_f64).unwrap_or(0.0) as usize;
    let last_gc = doc.get("last_gc").map(|g| GcInfo {
        kept: g.get("kept").and_then(Json::as_f64).unwrap_or(0.0) as usize,
        dropped: g.get("dropped").and_then(Json::as_f64).unwrap_or(0.0) as usize,
        timestamp: g.get("timestamp").and_then(Json::as_f64).unwrap_or(0.0) as u64,
    });
    Some((cum, last_gc))
}

fn render_header(cum_skipped: usize, last_gc: &GcInfo) -> String {
    let mut gc = Json::obj();
    gc.set("kept", json::num(last_gc.kept as f64));
    gc.set("dropped", json::num(last_gc.dropped as f64));
    gc.set("timestamp", json::num(last_gc.timestamp as f64));
    let mut doc = Json::obj();
    doc.set(HEADER_KEY, json::num(1.0));
    doc.set("cum_skipped", json::num(cum_skipped as f64));
    doc.set("last_gc", gc);
    doc.to_string()
}

/// Outcome of a [`Database::gc`] compaction pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcReport {
    pub kept: usize,
    pub dropped: usize,
}

/// `<path><suffix>`: appends to the full file name. (`Path::with_extension`
/// would replace the db file's real extension, making `run.db` and
/// `run.jsonl` collide on one lock/temp path.)
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(suffix);
    PathBuf::from(name)
}

/// Advisory cross-process write lock on a database file, held for the
/// duration of a commit or gc. Acquisition creates `<db>.lock` with
/// create-new semantics (atomic on every platform std supports); a lock
/// older than [`DbLock::STALE`] is assumed abandoned by a crashed writer
/// and broken — writers must finish well inside that window (commits are
/// one append; gc rewrites a top-k-bounded file). Dropping the guard
/// releases the lock, but only if the lock file still carries this
/// guard's token: a holder whose lock was stolen as stale must not
/// cascade the failure by deleting the usurper's lock.
struct DbLock {
    path: PathBuf,
    token: String,
}

impl DbLock {
    /// How long acquisition retries before giving up.
    const TIMEOUT: Duration = Duration::from_secs(10);
    /// Age past which an existing lock file is considered abandoned.
    const STALE: Duration = Duration::from_secs(120);
    const RETRY: Duration = Duration::from_millis(10);

    fn acquire(db_path: &Path) -> Result<DbLock> {
        let path = sibling(db_path, ".lock");
        let token = format!(
            "{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        );
        let deadline = std::time::Instant::now() + Self::TIMEOUT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write as _;
                    let _ = writeln!(f, "{token}");
                    return Ok(DbLock { path, token });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let observed = std::fs::metadata(&path).and_then(|m| m.modified()).ok();
                    let stale = observed
                        .and_then(|t| t.elapsed().ok())
                        .map_or(false, |age| age > Self::STALE);
                    if stale {
                        // Re-stat immediately before breaking: if another
                        // waiter broke the stale lock and acquired a fresh
                        // one in between, its mtime changed and it must
                        // not be deleted. std has no atomic
                        // compare-and-unlink, so a stat-to-remove window
                        // remains, but reaching it takes two waiters
                        // interleaving within microseconds of a 30s-stale
                        // anomaly.
                        let still = std::fs::metadata(&path).and_then(|m| m.modified()).ok();
                        if still == observed {
                            let _ = std::fs::remove_file(&path);
                        }
                        continue;
                    }
                    if std::time::Instant::now() >= deadline {
                        anyhow::bail!(
                            "timed out waiting for db lock {} (held by another tuner?)",
                            path.display()
                        );
                    }
                    std::thread::sleep(Self::RETRY);
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating db lock {}", path.display()));
                }
            }
        }
    }
}

impl Drop for DbLock {
    fn drop(&mut self) {
        let ours = std::fs::read_to_string(&self.path)
            .map(|s| s.trim() == self.token)
            .unwrap_or(false);
        if ours {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// JSONL-backed tuning-record store.
#[derive(Debug, Clone)]
pub struct Database {
    /// Backing file; `None` for a purely in-memory database (tests, smoke).
    pub path: Option<PathBuf>,
    records: Vec<TuningRecord>,
    /// records[..committed] are already on disk.
    committed: usize,
    pub skipped_lines: usize,
    /// Cumulative skip count carried by the header line (0 when absent).
    header_cum_skipped: usize,
    /// Most recent gc outcome, carried by the header line.
    pub last_gc: Option<GcInfo>,
    /// ANN transfer index ([`Database::attach_transfer_index`]); kept in
    /// sync by `commit` (incremental) and `gc` (rebuild). `None` until a
    /// caller opts in — the db itself never needs it.
    index: Option<TransferIndex>,
}

impl Database {
    /// Open a database file. A missing file is an empty database;
    /// malformed lines are skipped and counted. Read-only callers (`rcc db
    /// stats`) get no filesystem side effects — parent directories are
    /// created by [`Database::commit`], on the write path.
    pub fn open(path: &Path) -> Result<Database> {
        let (records, skipped_lines, header_cum_skipped, last_gc) = Self::load(path)?;
        let committed = records.len();
        Ok(Database {
            path: Some(path.to_path_buf()),
            records,
            committed,
            skipped_lines,
            header_cum_skipped,
            last_gc,
            index: None,
        })
    }

    fn load(path: &Path) -> Result<(Vec<TuningRecord>, usize, usize, Option<GcInfo>)> {
        let mut records = Vec::new();
        let mut skipped_lines = 0;
        let mut header_cum_skipped = 0;
        let mut last_gc = None;
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading tuning db {}", path.display()))?;
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                match TuningRecord::from_jsonl(line) {
                    Some(r) => records.push(r),
                    None => match parse_header(line) {
                        Some((cum, gc)) => {
                            header_cum_skipped = header_cum_skipped.max(cum);
                            last_gc = gc.or(last_gc);
                        }
                        None => skipped_lines += 1,
                    },
                }
            }
        }
        Ok((records, skipped_lines, header_cum_skipped, last_gc))
    }

    /// A database with no backing file; `commit` is a no-op.
    pub fn in_memory() -> Database {
        Database {
            path: None,
            records: Vec::new(),
            committed: 0,
            skipped_lines: 0,
            header_cum_skipped: 0,
            last_gc: None,
            index: None,
        }
    }

    /// Attach the ANN transfer index: load the `<db>.idx` sidecar when it
    /// is fresh, rebuild (and re-save) it otherwise. Records without
    /// transfer metadata (persisted before shape classes existed) are
    /// excluded with one aggregated warning — mirroring the
    /// malformed-JSONL convention, never per-record spam. Idempotent when
    /// the attached index already covers every record.
    pub fn attach_transfer_index(&mut self, threshold: usize) {
        if self
            .index
            .as_ref()
            .map_or(false, |ix| ix.threshold() == threshold && ix.covered() == self.records.len())
        {
            return;
        }
        let ix = match &self.path {
            Some(path) => TransferIndex::load(path, &self.records, threshold).unwrap_or_else(|| {
                let ix = TransferIndex::build(&self.records, threshold);
                if let Err(e) = ix.save(path) {
                    eprintln!(
                        "warning: could not write transfer index sidecar for {}: {e}",
                        path.display()
                    );
                }
                ix
            }),
            None => TransferIndex::build(&self.records, threshold),
        };
        if ix.sentinel_skipped() > 0 {
            eprintln!(
                "warning: excluded {} pre-transfer record(s) without shape metadata from the transfer index{}",
                ix.sentinel_skipped(),
                self.path
                    .as_deref()
                    .map(|p| format!(" for {}", p.display()))
                    .unwrap_or_default()
            );
        }
        self.index = Some(ix);
    }

    /// The attached ANN transfer index, if any.
    pub fn transfer_index(&self) -> Option<&TransferIndex> {
        self.index.as_ref()
    }

    /// Lifetime malformed-line skips: whichever is larger of the
    /// header-carried count and what this load observed.
    pub fn cum_skipped(&self) -> usize {
        self.header_cum_skipped.max(self.skipped_lines)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[TuningRecord] {
        &self.records
    }

    /// Stage a record for the next commit.
    pub fn add(&mut self, rec: TuningRecord) {
        self.records.push(rec);
    }

    /// Append all staged records to the backing file, under the advisory
    /// file lock so parallel tuners sharing one database never interleave
    /// partial lines. Returns how many records were flushed.
    pub fn commit(&mut self) -> Result<usize> {
        // A gc that failed mid-rewrite can leave `committed` past the
        // merged in-memory length; clamp instead of panicking.
        self.committed = self.committed.min(self.records.len());
        let pending = &self.records[self.committed..];
        let n = pending.len();
        if n == 0 {
            return Ok(0);
        }
        let _sp = obs::span(obs::EventKind::DbCommit, n as u64);
        if let Some(path) = &self.path {
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)
                        .with_context(|| format!("creating db dir {}", parent.display()))?;
                }
            }
            let mut chunk = String::new();
            for rec in pending {
                chunk.push_str(&rec.to_jsonl());
                chunk.push('\n');
            }
            let _lock = DbLock::acquire(path)?;
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .with_context(|| format!("opening tuning db {}", path.display()))?;
            f.write_all(chunk.as_bytes())
                .with_context(|| format!("appending to tuning db {}", path.display()))?;
        }
        self.committed = self.records.len();
        // Grow the attached ANN index incrementally with the new tail and
        // re-stamp the sidecar against the file we just appended to.
        if let Some(ix) = &mut self.index {
            ix.extend_from(&self.records);
            if let Some(path) = &self.path {
                if let Err(e) = ix.save(path) {
                    eprintln!(
                        "warning: could not update transfer index sidecar for {}: {e}",
                        path.display()
                    );
                }
            }
        }
        Ok(n)
    }

    /// Compact the database: keep only the top-`k` records per
    /// (workload fingerprint, platform) pair — lowest latency first,
    /// deduplicated by trace like [`Database::top_k`] — and drop the rest.
    ///
    /// For a file-backed database the file is first re-read under the
    /// advisory lock (so records committed by concurrent tuners since this
    /// handle opened are compacted, not lost), this handle's
    /// staged-but-uncommitted records are appended to that set (they
    /// participate in compaction and are flushed by the rewrite, never
    /// silently dropped), and the result is atomically rewritten via a
    /// temp-file rename. Lines this version cannot parse — version drift
    /// must never be fatal — are preserved verbatim in place, and kept
    /// records preserve their original file order. In-memory bookkeeping
    /// is only updated after the rewrite is durable, so a failed rewrite
    /// leaves staged records staged. Returns how many (parseable) records
    /// were kept and dropped.
    pub fn gc(&mut self, k: usize) -> Result<GcReport> {
        self.gc_with(k, false)
    }

    /// [`Database::gc`] with the record-aging reaper: when
    /// `reap_dominated` is set, records strictly dominated by a fresher
    /// record of the same (workload, platform) pair — later timestamp
    /// (file position as tie-break) at equal-or-lower latency, the same
    /// relation that down-weights them at retrieval — are dropped even
    /// when they would otherwise make the per-pair top-k. Opt-in: a plain
    /// gc keeps every staged record it can (`rcc db gc --reap-dominated`).
    pub fn gc_with(&mut self, k: usize, reap_dominated: bool) -> Result<GcReport> {
        /// One line of the rewritten file: a compactable record (by index
        /// into the merged record list) or a foreign line kept verbatim.
        enum Line {
            Rec(usize),
            Foreign(String),
        }

        let mut gc_span = obs::span(obs::EventKind::DbGc, 0);
        let locked = match &self.path {
            Some(path) => {
                let lock = DbLock::acquire(path)?;
                let staged: Vec<TuningRecord> = self.records.split_off(self.committed);
                let mut records = Vec::new();
                let mut layout: Vec<Line> = Vec::new();
                let mut skipped = 0usize;
                if path.exists() {
                    let text = std::fs::read_to_string(path)
                        .with_context(|| format!("reading tuning db {}", path.display()))?;
                    for line in text.lines() {
                        if line.trim().is_empty() {
                            continue;
                        }
                        match TuningRecord::from_jsonl(line) {
                            Some(r) => {
                                layout.push(Line::Rec(records.len()));
                                records.push(r);
                            }
                            // A prior pass's header is telemetry, not a
                            // foreign line: absorb it (the rewrite emits a
                            // fresh one first) instead of preserving it
                            // verbatim mid-file.
                            None => match parse_header(line) {
                                Some((cum, gc)) => {
                                    self.header_cum_skipped = self.header_cum_skipped.max(cum);
                                    self.last_gc = gc.or(self.last_gc);
                                }
                                None => {
                                    skipped += 1;
                                    layout.push(Line::Foreign(line.to_string()));
                                }
                            },
                        }
                    }
                }
                for rec in staged {
                    layout.push(Line::Rec(records.len()));
                    records.push(rec);
                }
                self.records = records;
                self.skipped_lines = skipped;
                Some((lock, path.clone(), layout))
            }
            None => None,
        };

        let keep = self.keep_indices(k, reap_dominated);
        let total = self.records.len();
        let info = GcInfo {
            kept: keep.len(),
            dropped: total - keep.len(),
            timestamp: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        };
        let cum_skipped = self.cum_skipped();

        // Durable rewrite first; bookkeeping only after it succeeds.
        if let Some((_lock, path, layout)) = &locked {
            let mut text = String::new();
            text.push_str(&render_header(cum_skipped, &info));
            text.push('\n');
            for line in layout {
                match line {
                    Line::Foreign(raw) => {
                        text.push_str(raw);
                        text.push('\n');
                    }
                    Line::Rec(i) => {
                        if keep.contains(i) {
                            text.push_str(&self.records[*i].to_jsonl());
                            text.push('\n');
                        }
                    }
                }
            }
            let tmp = sibling(path, ".tmp");
            std::fs::write(&tmp, text.as_bytes())
                .with_context(|| format!("writing compacted db {}", tmp.display()))?;
            std::fs::rename(&tmp, path)
                .with_context(|| format!("replacing tuning db {}", path.display()))?;
        }

        let mut kept_records = Vec::with_capacity(keep.len());
        for (i, rec) in std::mem::take(&mut self.records).into_iter().enumerate() {
            if keep.contains(&i) {
                kept_records.push(rec);
            }
        }
        let report = GcReport { kept: kept_records.len(), dropped: total - kept_records.len() };
        self.records = kept_records;
        self.committed = self.records.len();
        self.header_cum_skipped = cum_skipped;
        self.last_gc = Some(info);
        // Record positions changed wholesale: rebuild the attached ANN
        // index from the compacted set and re-stamp its sidecar.
        if let Some(old) = self.index.take() {
            let ix = TransferIndex::build(&self.records, old.threshold());
            if let Some(path) = &self.path {
                if let Err(e) = ix.save(path) {
                    eprintln!(
                        "warning: could not update transfer index sidecar for {}: {e}",
                        path.display()
                    );
                }
            }
            self.index = Some(ix);
        }
        gc_span.set_args(report.kept as u64, report.dropped as u64);
        Ok(report)
    }

    /// Indices of the records `gc` keeps: per (workload_fp, platform) pair,
    /// the `k` lowest-latency distinct traces. Ties break on earlier file
    /// position, keeping the pass deterministic. With `reap_dominated`,
    /// records superseded by fresher equal-or-better work are skipped
    /// before the top-k is taken.
    fn keep_indices(&self, k: usize, reap_dominated: bool) -> BTreeSet<usize> {
        let dominated = if reap_dominated {
            dominated_positions(&self.records)
        } else {
            BTreeSet::new()
        };
        let mut by_pair: BTreeMap<(u64, &str), Vec<usize>> = BTreeMap::new();
        for (i, r) in self.records.iter().enumerate() {
            by_pair.entry((r.workload_fp, r.platform.as_str())).or_default().push(i);
        }
        let mut keep = BTreeSet::new();
        for (_, mut idxs) in by_pair {
            idxs.sort_by(|&a, &b| {
                self.records[a]
                    .latency
                    .partial_cmp(&self.records[b].latency)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut taken: Vec<usize> = Vec::new();
            for i in idxs {
                if taken.len() >= k {
                    break;
                }
                if dominated.contains(&i) {
                    continue;
                }
                if taken.iter().any(|&t| self.records[t].trace == self.records[i].trace) {
                    continue;
                }
                taken.push(i);
            }
            keep.extend(taken);
        }
        keep
    }

    /// The best records for a (workload fingerprint, platform) pair,
    /// deduplicated by trace, best first. Within a fixed pair the sort key
    /// is measured latency, not speedup: baselines are re-measured per run
    /// under seed noise, so speedup ratios from different runs are not
    /// comparable while latencies are.
    pub fn top_k(&self, workload_fp: u64, platform: &str, k: usize) -> Vec<&TuningRecord> {
        let mut matching: Vec<&TuningRecord> = self
            .records
            .iter()
            .filter(|r| r.workload_fp == workload_fp && r.platform == platform)
            .collect();
        matching.sort_by(|a, b| {
            a.latency
                .partial_cmp(&b.latency)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut out: Vec<&TuningRecord> = Vec::new();
        for r in matching {
            if out.len() >= k {
                break;
            }
            if !out.iter().any(|o| o.trace == r.trace) {
                out.push(r);
            }
        }
        out
    }

    /// Best record for a (workload fingerprint, platform) pair.
    pub fn best(&self, workload_fp: u64, platform: &str) -> Option<&TuningRecord> {
        self.top_k(workload_fp, platform, 1).into_iter().next()
    }

    /// True if an existing record already covers this trace at least as
    /// well (same fingerprint/platform/trace, equal-or-better latency).
    /// Sessions use this to avoid re-appending known results every run, so
    /// the append-only log does not grow without new information.
    pub fn has_equivalent(
        &self,
        workload_fp: u64,
        platform: &str,
        trace: &[Transform],
        latency: f64,
    ) -> bool {
        self.records.iter().any(|r| {
            r.workload_fp == workload_fp
                && r.platform == platform
                && r.trace == trace
                && r.latency <= latency
        })
    }

    /// Best record for a workload *name* across all platforms (serving-side
    /// lookup, where the host platform is not one of the simulated ones).
    /// Within a platform only latency is noise-free (baselines are
    /// re-measured per run); across platforms only speedup is comparable —
    /// so take each platform's latency-best record, then the highest
    /// speedup among those.
    pub fn best_for_workload(&self, workload: &str) -> Option<&TuningRecord> {
        let mut per_platform: BTreeMap<&str, &TuningRecord> = BTreeMap::new();
        for r in self.records.iter().filter(|r| r.workload == workload) {
            per_platform
                .entry(r.platform.as_str())
                .and_modify(|best| {
                    if r.latency < best.latency {
                        *best = r;
                    }
                })
                .or_insert(r);
        }
        per_platform.into_values().max_by(|a, b| {
            a.speedup()
                .partial_cmp(&b.speedup())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }

    pub fn stats(&self) -> DbStats {
        let mut pairs = BTreeSet::new();
        let mut workloads = BTreeSet::new();
        let mut platforms = BTreeSet::new();
        for r in &self.records {
            pairs.insert((r.workload_fp, r.platform.clone()));
            workloads.insert(r.workload.clone());
            platforms.insert(r.platform.clone());
        }
        DbStats {
            records: self.records.len(),
            pairs: pairs.len(),
            workloads: workloads.into_iter().collect(),
            platforms: platforms.into_iter().collect(),
            skipped_lines: self.skipped_lines,
            cum_skipped: self.cum_skipped(),
            last_gc: self.last_gc,
        }
    }

    /// Derive search hints for `base` on `platform`: the top-k traces as a
    /// [`WarmStart`], plus a [`MeasureCache`] pre-populated with every
    /// record's measured latency (keyed by the fingerprint of the program
    /// the trace replays to). Traces that no longer replay fully on `base`
    /// are dropped — records never poison a structurally drifted program.
    pub fn hints(&self, base: &Program, platform: &str, k: usize) -> (WarmStart, MeasureCache) {
        let fp = workload_fingerprint(base);
        let mut warm = WarmStart::default();
        let cache = MeasureCache::new();
        let base_sched = Schedule::new(base.clone());
        for rec in self.top_k(fp, platform, k) {
            let (replayed, applied) = base_sched.apply_all(&rec.trace);
            if applied != rec.trace.len() {
                continue;
            }
            // Distinct traces can replay to the same concrete program; keep
            // the best latency per fingerprint rather than last-write-wins,
            // so a worse duplicate never masks the recorded optimum.
            let pfp = program_fingerprint(&replayed.current);
            if cache.get(pfp, platform).map_or(true, |known| rec.latency < known) {
                cache.insert(pfp, platform, rec.latency);
            }
            warm.entries.push((rec.trace.clone(), rec.latency));
        }
        (warm, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::workload::WorkloadId;

    fn temp_db_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "rcc_db_{tag}_{}_{}.jsonl",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ))
    }

    fn rec(fp: u64, platform: &str, latency: f64, factor: i64) -> TuningRecord {
        TuningRecord {
            workload_fp: fp,
            workload: "deepseek_moe".to_string(),
            platform: platform.to_string(),
            strategy: "test".to_string(),
            trace: vec![Transform::TileSize { stage: 0, loop_idx: 2, factor }],
            latency,
            baseline_latency: 10.0,
            seed: 1,
            timestamp: 100,
            shape_class: 0,
            extents: Vec::new(),
        }
    }

    #[test]
    fn open_commit_reopen_roundtrip() {
        let path = temp_db_path("roundtrip");
        let mut db = Database::open(&path).unwrap();
        assert!(db.is_empty());
        db.add(rec(42, "core_i9", 2.0, 4));
        db.add(rec(42, "core_i9", 1.0, 8));
        assert_eq!(db.commit().unwrap(), 2);
        assert_eq!(db.commit().unwrap(), 0, "second commit flushes nothing");
        db.add(rec(42, "m2_pro", 3.0, 16));
        assert_eq!(db.commit().unwrap(), 1);

        let db2 = Database::open(&path).unwrap();
        assert_eq!(db2.len(), 3);
        assert_eq!(db2.records()[1], db.records()[1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn top_k_orders_by_latency_and_dedups() {
        let mut db = Database::in_memory();
        db.add(rec(7, "core_i9", 5.0, 4));
        db.add(rec(7, "core_i9", 2.0, 8));
        db.add(rec(7, "core_i9", 2.5, 8)); // same trace, worse: deduped
        db.add(rec(7, "xeon_e3", 1.0, 8)); // other platform
        db.add(rec(8, "core_i9", 0.5, 8)); // other workload
        let top = db.top_k(7, "core_i9", 10);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].latency, 2.0, "lowest latency first");
        assert_eq!(db.best(7, "core_i9").unwrap().latency, 2.0);
        assert!(db.best(9, "core_i9").is_none());
    }

    #[test]
    fn top_k_ignores_cross_run_baseline_noise() {
        // A record with a noisier (higher) baseline shows a higher speedup
        // but a slower latency; latency must win within a fixed pair.
        let mut db = Database::in_memory();
        let mut a = rec(7, "core_i9", 2.0, 4);
        a.baseline_latency = 11.5; // 5.75x
        let mut b = rec(7, "core_i9", 1.8, 8);
        b.baseline_latency = 9.0; // 5.0x
        db.add(a);
        db.add(b);
        assert_eq!(db.best(7, "core_i9").unwrap().latency, 1.8);
    }

    #[test]
    fn malformed_lines_skipped_not_fatal() {
        let path = temp_db_path("malformed");
        let good = rec(1, "core_i9", 1.0, 4);
        std::fs::write(
            &path,
            format!("{}\nnot json at all\n{{\"op\":1}}\n", good.to_jsonl()),
        )
        .unwrap();
        let db = Database::open(&path).unwrap();
        assert_eq!(db.len(), 1);
        assert_eq!(db.skipped_lines, 2);
        assert_eq!(db.stats().skipped_lines, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hints_replay_and_prepopulate_cache() {
        let base = WorkloadId::DeepSeekMoe.build();
        let fp = workload_fingerprint(&base);
        let mut db = Database::in_memory();
        db.add(TuningRecord {
            workload_fp: fp,
            workload: base.name.clone(),
            platform: "core_i9".to_string(),
            strategy: "test".to_string(),
            trace: vec![
                Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 },
                Transform::Parallel { stage: 0, loop_idx: 0 },
            ],
            latency: 0.004,
            baseline_latency: 0.02,
            seed: 3,
            timestamp: 1,
            shape_class: 0,
            extents: Vec::new(),
        });
        // A record whose trace cannot replay (bad loop index): dropped.
        db.add(TuningRecord {
            workload_fp: fp,
            workload: base.name.clone(),
            platform: "core_i9".to_string(),
            strategy: "test".to_string(),
            trace: vec![Transform::TileSize { stage: 0, loop_idx: 99, factor: 2 }],
            latency: 0.001,
            baseline_latency: 0.02,
            seed: 4,
            timestamp: 2,
            shape_class: 0,
            extents: Vec::new(),
        });
        let (warm, cache) = db.hints(&base, "core_i9", 8);
        assert_eq!(warm.entries.len(), 1, "non-replayable record dropped");
        assert_eq!(cache.len(), 1);
        // The cache key is the fingerprint of the replayed program.
        let sched = Schedule::new(base.clone());
        let (replayed, _) = sched.apply_all(&warm.entries[0].0);
        assert_eq!(
            cache.get(program_fingerprint(&replayed.current), "core_i9"),
            Some(0.004)
        );
        // Hints for an unrelated platform are empty.
        let (warm2, cache2) = db.hints(&base, "graviton2", 8);
        assert!(warm2.is_empty());
        assert!(cache2.is_empty());
    }

    #[test]
    fn gc_keeps_top_k_per_pair() {
        let path = temp_db_path("gc");
        let mut db = Database::open(&path).unwrap();
        db.add(rec(7, "core_i9", 5.0, 4));
        db.add(rec(7, "core_i9", 2.0, 8));
        db.add(rec(7, "core_i9", 3.0, 16));
        db.add(rec(7, "core_i9", 2.5, 8)); // duplicate trace of the 2.0 record
        db.add(rec(7, "m2_pro", 9.0, 2)); // other pair: always kept at k>=1
        db.commit().unwrap();

        let report = db.gc(2).unwrap();
        assert_eq!(report, GcReport { kept: 3, dropped: 2 });
        // Kept: core_i9 latencies {2.0, 3.0} (5.0 dropped, 2.5 deduped) + m2_pro.
        let mut lat: Vec<f64> = db.records().iter().map(|r| r.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(lat, vec![2.0, 3.0, 9.0]);

        // The rewrite is durable and re-parseable.
        let reread = Database::open(&path).unwrap();
        assert_eq!(reread.len(), 3);
        assert_eq!(reread.best(7, "core_i9").unwrap().latency, 2.0);
        // A second pass is a no-op.
        let mut db = reread;
        assert_eq!(db.gc(2).unwrap(), GcReport { kept: 3, dropped: 0 });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gc_flushes_staged_records_instead_of_dropping_them() {
        let path = temp_db_path("gc_staged");
        let mut db = Database::open(&path).unwrap();
        db.add(rec(7, "core_i9", 2.0, 8));
        db.commit().unwrap();
        db.add(rec(7, "core_i9", 1.0, 4)); // staged, never committed
        let report = db.gc(8).unwrap();
        assert_eq!(report, GcReport { kept: 2, dropped: 0 });
        let reread = Database::open(&path).unwrap();
        assert_eq!(reread.len(), 2, "staged record must be flushed by gc");
        assert_eq!(reread.best(7, "core_i9").unwrap().latency, 1.0);
        assert_eq!(db.commit().unwrap(), 0, "gc left nothing staged");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gc_reap_dominated_is_opt_in_and_spares_sentinels() {
        let path = temp_db_path("gc_reap");
        let mut db = Database::open(&path).unwrap();
        let eligible = |latency: f64, ts: u64, factor: i64| {
            let mut r = rec(7, "core_i9", latency, factor);
            r.shape_class = 0xC1A55;
            r.extents = vec![vec![16, 512, 512]];
            r.timestamp = ts;
            r
        };
        db.add(eligible(2.0, 100, 8)); // superseded by the fresher 1.5
        db.add(eligible(1.5, 200, 4)); // freshest of the pair
        db.add(eligible(1.0, 150, 16)); // best latency: nothing dominates it
        db.commit().unwrap();

        // A plain gc with room keeps everything.
        assert_eq!(db.gc(8).unwrap(), GcReport { kept: 3, dropped: 0 });
        // Reaping drops the superseded record even though k has room.
        assert_eq!(db.gc_with(8, true).unwrap(), GcReport { kept: 2, dropped: 1 });
        let mut lat: Vec<f64> = db.records().iter().map(|r| r.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(lat, vec![1.0, 1.5]);

        // Records without transfer metadata never participate in the
        // domination relation — in either direction.
        db.add(rec(9, "core_i9", 5.0, 2));
        db.add(rec(9, "core_i9", 4.0, 4));
        assert_eq!(db.gc_with(8, true).unwrap(), GcReport { kept: 4, dropped: 0 });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gc_preserves_unparseable_lines_verbatim() {
        // Version drift must never be fatal — nor destructive: lines a
        // newer binary wrote (unparseable here) survive compaction.
        let path = temp_db_path("gc_foreign");
        let good = rec(1, "core_i9", 1.0, 4);
        let worse = rec(1, "core_i9", 2.0, 8);
        std::fs::write(
            &path,
            format!(
                "{}\n{{\"from_the_future\":1}}\n{}\n",
                good.to_jsonl(),
                worse.to_jsonl()
            ),
        )
        .unwrap();
        let mut db = Database::open(&path).unwrap();
        assert_eq!(db.skipped_lines, 1);
        let report = db.gc(1).unwrap();
        assert_eq!(report, GcReport { kept: 1, dropped: 1 });
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.contains("from_the_future"),
            "foreign lines must survive gc: {text}"
        );
        let reread = Database::open(&path).unwrap();
        assert_eq!(reread.len(), 1);
        assert_eq!(reread.best(1, "core_i9").unwrap().latency, 1.0);
        assert_eq!(reread.skipped_lines, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gc_header_carries_cumulative_telemetry() {
        let path = temp_db_path("header");
        let good = rec(1, "core_i9", 1.0, 4);
        std::fs::write(&path, format!("{}\nnot json\n", good.to_jsonl())).unwrap();
        let mut db = Database::open(&path).unwrap();
        assert_eq!(db.cum_skipped(), 1);
        assert!(db.last_gc.is_none());
        db.gc(4).unwrap();

        // Header is the first line and re-loads as telemetry, not a skip.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().next().unwrap().contains("rcc_db_header"), "{text}");
        let reread = Database::open(&path).unwrap();
        assert_eq!(reread.len(), 1);
        assert_eq!(reread.skipped_lines, 1, "only the foreign line counts");
        assert_eq!(reread.cum_skipped(), 1);
        let gc = reread.last_gc.unwrap();
        assert_eq!((gc.kept, gc.dropped), (1, 0));
        let stats = reread.stats();
        assert_eq!(stats.cum_skipped, 1);
        assert!(stats.render().contains("last gc: kept 1 dropped 0"));

        // A second pass refreshes the header without duplicating it.
        let mut db2 = reread;
        db2.gc(4).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("rcc_db_header").count(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gc_compacts_concurrent_commits_it_did_not_stage() {
        let path = temp_db_path("gc_concurrent");
        let mut a = Database::open(&path).unwrap();
        // Another handle commits behind `a`'s back.
        let mut b = Database::open(&path).unwrap();
        b.add(rec(7, "core_i9", 1.0, 8));
        b.add(rec(7, "core_i9", 4.0, 16));
        b.commit().unwrap();
        // gc through `a` must see (and keep the best of) b's records.
        let report = a.gc(1).unwrap();
        assert_eq!(report, GcReport { kept: 1, dropped: 1 });
        assert_eq!(a.best(7, "core_i9").unwrap().latency, 1.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_commits_under_lock_lose_no_records() {
        let path = temp_db_path("lock");
        const WRITERS: u64 = 4;
        const RECORDS_EACH: u64 = 25;
        std::thread::scope(|scope| {
            for w in 0..WRITERS {
                let path = path.clone();
                scope.spawn(move || {
                    // Each writer is an independent handle on the shared
                    // file, as separate tuner processes would be.
                    let mut db = Database::open(&path).unwrap();
                    for i in 0..RECORDS_EACH {
                        db.add(rec(w * 1000 + i, "core_i9", 1.0 + i as f64, 4));
                        db.commit().unwrap();
                    }
                });
            }
        });
        let db = Database::open(&path).unwrap();
        assert_eq!(db.skipped_lines, 0, "no torn/interleaved lines");
        assert_eq!(db.len(), (WRITERS * RECORDS_EACH) as usize, "no lost appends");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn commit_waits_for_held_lock() {
        // std can't backdate mtimes, so the stale-break branch is exercised
        // indirectly; this covers the wait-and-proceed path: a held lock
        // blocks the commit, and releasing it lets the commit through.
        let path = temp_db_path("held_lock");
        let lock_path = PathBuf::from(format!("{}.lock", path.display()));
        std::fs::write(&lock_path, "999999\n").unwrap();
        let waiter = std::thread::spawn({
            let path = path.clone();
            move || {
                let mut db = Database::open(&path).unwrap();
                db.add(rec(1, "core_i9", 1.0, 4));
                db.commit().unwrap()
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        std::fs::remove_file(&lock_path).unwrap();
        assert_eq!(waiter.join().unwrap(), 1, "commit proceeds once lock is freed");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn best_for_workload_spans_platforms() {
        let mut db = Database::in_memory();
        db.add(rec(7, "core_i9", 5.0, 4));
        db.add(rec(7, "m2_pro", 2.0, 8));
        let b = db.best_for_workload("deepseek_moe").unwrap();
        assert_eq!(b.platform, "m2_pro");
        assert!(db.best_for_workload("nope").is_none());
        // Within a platform, a noisy-baseline record with higher speedup
        // but worse latency must not displace the latency-best one.
        let mut noisy = rec(7, "m2_pro", 2.5, 4);
        noisy.baseline_latency = 20.0; // 8x "speedup", slower schedule
        db.add(noisy);
        assert_eq!(db.best_for_workload("deepseek_moe").unwrap().latency, 2.0);
    }
}
