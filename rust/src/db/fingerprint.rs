//! Structural fingerprints over TIR programs.
//!
//! Two fingerprints with different invariances power the tuning database:
//!
//! - [`workload_fingerprint`] hashes only the *computation* — buffer shapes,
//!   iteration axes and the compute block — and deliberately ignores names
//!   and the current loop nest. Two programs with identical structure (e.g.
//!   the same MoE matmul built under different names) share a fingerprint,
//!   so tuning records transfer across identically-shaped programs.
//! - [`program_fingerprint`] extends the workload fingerprint with the
//!   *schedule state*: the current loop nest, axis-reconstruction
//!   expressions and performance annotations. Two schedule candidates that
//!   produce the same concrete program share a fingerprint, which is what
//!   makes the measurement cache sound — equal fingerprint ⇒ the hardware
//!   model would return the same latency distribution.
//!
//! Both are 64-bit FNV-1a-style hashes with per-field tags to keep
//! structurally different programs from colliding through commutativity.

use crate::tir::expr::{Expr, LinIdx};
use crate::tir::program::{BlockExpr, Program, Stage};

/// Incremental FNV-1a-style hasher over tagged integer fields.
#[derive(Debug, Clone)]
pub struct StructHasher {
    h: u64,
}

impl Default for StructHasher {
    fn default() -> Self {
        StructHasher { h: 0xcbf29ce484222325 }
    }
}

impl StructHasher {
    pub fn new() -> StructHasher {
        StructHasher::default()
    }

    #[inline]
    pub fn feed(&mut self, x: u64) {
        self.h ^= x;
        self.h = self.h.wrapping_mul(0x100000001b3);
    }

    #[inline]
    pub fn feed_i64(&mut self, x: i64) {
        self.feed(x as u64);
    }

    /// Field tag: keeps `[2, 3]` from colliding with `[3, 2]`-shaped feeds
    /// of a different field.
    #[inline]
    pub fn tag(&mut self, t: u64) {
        self.feed(0x9E37_79B9_7F4A_7C15 ^ t);
    }

    pub fn finish(&self) -> u64 {
        // Final avalanche (splitmix64 tail) so nearby inputs spread.
        let mut z = self.h;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

fn feed_linidx(h: &mut StructHasher, idx: &LinIdx) {
    h.tag(10);
    h.feed_i64(idx.offset);
    for &(axis, coeff) in &idx.terms {
        h.feed(axis as u64);
        h.feed_i64(coeff);
    }
}

fn feed_block_expr(h: &mut StructHasher, e: &BlockExpr) {
    match e {
        BlockExpr::Load(buf, idx) => {
            h.tag(20);
            h.feed(*buf as u64);
            for i in idx {
                feed_linidx(h, i);
            }
        }
        BlockExpr::Const(c) => {
            h.tag(21);
            h.feed(c.to_bits() as u64);
        }
        BlockExpr::Add(a, b) => {
            h.tag(22);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Sub(a, b) => {
            h.tag(23);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Mul(a, b) => {
            h.tag(24);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
        BlockExpr::Max(a, b) => {
            h.tag(25);
            feed_block_expr(h, a);
            feed_block_expr(h, b);
        }
    }
}

fn feed_expr(h: &mut StructHasher, e: &Expr) {
    match e {
        Expr::Var(v) => {
            h.tag(30);
            h.feed(*v as u64);
        }
        Expr::Const(c) => {
            h.tag(31);
            h.feed_i64(*c);
        }
        Expr::Add(a, b) => {
            h.tag(32);
            feed_expr(h, a);
            feed_expr(h, b);
        }
        Expr::Mul(a, k) => {
            h.tag(33);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
        Expr::Div(a, k) => {
            h.tag(34);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
        Expr::Mod(a, k) => {
            h.tag(35);
            feed_expr(h, a);
            h.feed_i64(*k);
        }
    }
}

/// Feed the schedule-invariant structure of one stage.
fn feed_stage_structure(h: &mut StructHasher, s: &Stage) {
    h.tag(2);
    for a in &s.axes {
        h.feed_i64(a.extent);
        h.feed(a.is_reduction as u64 + 1);
    }
    h.tag(3);
    h.feed(s.block.out as u64);
    for idx in &s.block.out_idx {
        feed_linidx(h, idx);
    }
    feed_block_expr(h, &s.block.rhs);
    h.feed(s.block.reduce as u64 + 1);
}

/// Canonical hash of the computation's structure: buffers, axes and compute
/// blocks. Invariant to program/stage/buffer *names* and to the current
/// loop nest, so records keyed by it transfer across identically-shaped
/// programs and across schedule states.
pub fn workload_fingerprint(p: &Program) -> u64 {
    let mut h = StructHasher::new();
    h.tag(1);
    for b in &p.buffers {
        h.feed(b.kind as u64 + 1);
        h.feed(b.shape.len() as u64);
        for &d in &b.shape {
            h.feed_i64(d);
        }
    }
    for s in &p.stages {
        feed_stage_structure(&mut h, s);
    }
    h.finish()
}

/// Hash of the *scheduled* program: the workload structure plus the current
/// loop nest (extents, annotations, axis-reconstruction expressions) and
/// performance annotations. Distinguishes different tile sizes, loop
/// orders, fusions and annotations on the same workload — the key for the
/// measurement cache.
pub fn program_fingerprint(p: &Program) -> u64 {
    let mut h = StructHasher::new();
    h.tag(1);
    for b in &p.buffers {
        h.feed(b.kind as u64 + 1);
        h.feed(b.shape.len() as u64);
        for &d in &b.shape {
            h.feed_i64(d);
        }
    }
    for s in &p.stages {
        feed_stage_structure(&mut h, s);
        h.tag(4);
        for l in &s.loops {
            h.feed_i64(l.extent);
            h.feed(l.kind as u64 + 1);
            h.feed(l.var as u64);
        }
        h.tag(5);
        for e in &s.axis_exprs {
            feed_expr(&mut h, e);
        }
        h.feed(s.cache_write as u64 + 17);
        h.feed(s.compute_at.map(|d| d as u64 + 1).unwrap_or(0));
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Schedule, Transform};
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn workload_fingerprint_stable_and_name_invariant() {
        let a = WorkloadId::DeepSeekMoe.build();
        let b = WorkloadId::DeepSeekMoe.build();
        assert_eq!(workload_fingerprint(&a), workload_fingerprint(&b));
        // Same structure under a different name: identical fingerprint.
        let renamed = workload::moe_matmul("totally_different_name", 16, 2048, 7168);
        assert_eq!(workload_fingerprint(&a), workload_fingerprint(&renamed));
    }

    #[test]
    fn workload_fingerprint_distinguishes_shapes_and_kernels() {
        let fps: Vec<u64> = WorkloadId::ALL
            .iter()
            .map(|w| workload_fingerprint(&w.build()))
            .collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "workloads {i} and {j} collide");
            }
        }
        // Test-scale shapes differ from production shapes.
        assert_ne!(
            workload_fingerprint(&WorkloadId::DeepSeekMoe.build()),
            workload_fingerprint(&WorkloadId::DeepSeekMoe.build_test())
        );
    }

    #[test]
    fn workload_fingerprint_invariant_under_scheduling() {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let tiled = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        assert_eq!(
            workload_fingerprint(&base.current),
            workload_fingerprint(&tiled.current),
            "scheduling must not change the workload fingerprint"
        );
    }

    #[test]
    fn program_fingerprint_distinguishes_tile_sizes() {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let t4 = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        let t8 = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 8 })
            .unwrap();
        assert_ne!(program_fingerprint(&base.current), program_fingerprint(&t4.current));
        assert_ne!(program_fingerprint(&t4.current), program_fingerprint(&t8.current));
        // Same transform sequence reproduces the same fingerprint.
        let t4b = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        assert_eq!(program_fingerprint(&t4.current), program_fingerprint(&t4b.current));
    }

    #[test]
    fn program_fingerprint_distinguishes_annotations() {
        let base = Schedule::new(WorkloadId::Llama4Mlp.build());
        let par = base.apply(Transform::Parallel { stage: 0, loop_idx: 0 }).unwrap();
        let cw = base.apply(Transform::CacheWrite { stage: 0 }).unwrap();
        let fps = [
            program_fingerprint(&base.current),
            program_fingerprint(&par.current),
            program_fingerprint(&cw.current),
        ];
        assert_ne!(fps[0], fps[1]);
        assert_ne!(fps[0], fps[2]);
        assert_ne!(fps[1], fps[2]);
    }
}
