//! Structural fingerprints over TIR programs.
//!
//! Two fingerprints with different invariances power the tuning database:
//!
//! - [`workload_fingerprint`] hashes only the *computation* — buffer shapes,
//!   iteration axes and the compute block — and deliberately ignores names
//!   and the current loop nest. Two programs with identical structure (e.g.
//!   the same MoE matmul built under different names) share a fingerprint,
//!   so tuning records transfer across identically-shaped programs.
//! - [`program_fingerprint`] extends the workload fingerprint with the
//!   *schedule state*: the current loop nest, axis-reconstruction
//!   expressions and performance annotations. Two schedule candidates that
//!   produce the same concrete program share a fingerprint, which is what
//!   makes the measurement cache sound — equal fingerprint ⇒ the hardware
//!   model would return the same latency distribution.
//!
//! **Incremental since PR 3:** `program_fingerprint` combines the memoized
//! per-stage hashes ([`crate::tir::Stage::struct_hash`]) with a cheap
//! buffer-table hash, so a one-stage edit rehashes exactly one stage (the
//! one whose memo `Stage::cow_mut` cleared) instead of the whole program —
//! a measurement-cache probe on a CoW-shared candidate is near-free. The
//! invalidation invariant: any stage mutation goes through `cow_mut`, which
//! clears the memo, so a changed stage hash always reflects the current
//! structure. The hashing primitives live in [`crate::tir::hash`]; both are
//! 64-bit FNV-1a-style hashes with per-field tags.

use crate::tir::hash::{feed_block_expr, feed_buffers, feed_linidx, feed_stage_structure};
use crate::tir::program::Program;

pub use crate::tir::hash::StructHasher;

/// Canonical hash of the computation's structure: buffers, axes and compute
/// blocks. Invariant to program/stage/buffer *names* and to the current
/// loop nest, so records keyed by it transfer across identically-shaped
/// programs and across schedule states.
pub fn workload_fingerprint(p: &Program) -> u64 {
    let mut h = StructHasher::new();
    h.tag(1);
    feed_buffers(&mut h, &p.buffers);
    for s in &p.stages {
        feed_stage_structure(&mut h, s);
    }
    h.finish()
}

/// Extent-abstracted structural fingerprint — the workload's *shape class*.
///
/// Hashes everything [`workload_fingerprint`] hashes **except concrete
/// extents**: buffer kinds and ranks, per-stage axis counts and reduction
/// flags, and the compute block (output indexing, load structure, reduction
/// op). Two workloads share a shape class iff they are the same computation
/// at different sizes — `matmul 512x512x512` and `matmul 1024x1024x1024`
/// collide here while `matmul` and `conv2d` do not. This is the grouping
/// key of the transfer-tuning subsystem (`crate::transfer`): records from a
/// structurally similar workload are candidates for trace rebasing and
/// few-shot exemplars even though their workload fingerprints differ.
///
/// Like the other fingerprints it is name-invariant and schedule-invariant
/// (axes and blocks are fixed for the life of a stage). `0` is reserved as
/// the "unknown" sentinel used by records predating this field.
pub fn shape_class(p: &Program) -> u64 {
    let mut h = StructHasher::new();
    h.tag(7);
    for b in p.buffers.iter() {
        h.feed(b.kind as u64 + 1);
        h.feed(b.shape.len() as u64);
    }
    for s in &p.stages {
        h.tag(8);
        for a in &s.axes {
            h.feed(a.is_reduction as u64 + 1);
        }
        h.tag(9);
        h.feed(s.block.out as u64);
        for idx in &s.block.out_idx {
            feed_linidx(&mut h, idx);
        }
        feed_block_expr(&mut h, &s.block.rhs);
        h.feed(s.block.reduce as u64 + 1);
    }
    h.finish()
}

/// Hash of the *scheduled* program: the workload structure plus the current
/// loop nest (extents, annotations, axis-reconstruction expressions) and
/// performance annotations. Distinguishes different tile sizes, loop
/// orders, fusions and annotations on the same workload — the key for the
/// measurement cache. Built from memoized per-stage hashes, so only stages
/// mutated since their last hash are rehashed.
pub fn program_fingerprint(p: &Program) -> u64 {
    let mut h = StructHasher::new();
    h.tag(1);
    feed_buffers(&mut h, &p.buffers);
    h.tag(6);
    for s in &p.stages {
        h.feed(s.struct_hash());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Schedule, Transform};
    use crate::tir::workload::{self, WorkloadId};

    #[test]
    fn workload_fingerprint_stable_and_name_invariant() {
        let a = WorkloadId::DeepSeekMoe.build();
        let b = WorkloadId::DeepSeekMoe.build();
        assert_eq!(workload_fingerprint(&a), workload_fingerprint(&b));
        // Same structure under a different name: identical fingerprint.
        let renamed = workload::moe_matmul("totally_different_name", 16, 2048, 7168);
        assert_eq!(workload_fingerprint(&a), workload_fingerprint(&renamed));
    }

    #[test]
    fn workload_fingerprint_distinguishes_shapes_and_kernels() {
        let fps: Vec<u64> = WorkloadId::ALL
            .iter()
            .map(|w| workload_fingerprint(&w.build()))
            .collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "workloads {i} and {j} collide");
            }
        }
        // Test-scale shapes differ from production shapes.
        assert_ne!(
            workload_fingerprint(&WorkloadId::DeepSeekMoe.build()),
            workload_fingerprint(&WorkloadId::DeepSeekMoe.build_test())
        );
    }

    #[test]
    fn workload_fingerprint_invariant_under_scheduling() {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let tiled = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        assert_eq!(
            workload_fingerprint(&base.current),
            workload_fingerprint(&tiled.current),
            "scheduling must not change the workload fingerprint"
        );
    }

    #[test]
    fn program_fingerprint_distinguishes_tile_sizes() {
        let base = Schedule::new(WorkloadId::DeepSeekMoe.build());
        let t4 = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        let t8 = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 8 })
            .unwrap();
        assert_ne!(program_fingerprint(&base.current), program_fingerprint(&t4.current));
        assert_ne!(program_fingerprint(&t4.current), program_fingerprint(&t8.current));
        // Same transform sequence reproduces the same fingerprint.
        let t4b = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 2, factor: 4 })
            .unwrap();
        assert_eq!(program_fingerprint(&t4.current), program_fingerprint(&t4b.current));
    }

    #[test]
    fn program_fingerprint_distinguishes_annotations() {
        let base = Schedule::new(WorkloadId::Llama4Mlp.build());
        let par = base.apply(Transform::Parallel { stage: 0, loop_idx: 0 }).unwrap();
        let cw = base.apply(Transform::CacheWrite { stage: 0 }).unwrap();
        let fps = [
            program_fingerprint(&base.current),
            program_fingerprint(&par.current),
            program_fingerprint(&cw.current),
        ];
        assert_ne!(fps[0], fps[1]);
        assert_ne!(fps[0], fps[2]);
        assert_ne!(fps[1], fps[2]);
    }

    #[test]
    fn shape_class_abstracts_extents() {
        // Same computation at different sizes: one class, different
        // workload fingerprints.
        let small = workload::moe_matmul("a", 16, 512, 512);
        let large = workload::moe_matmul("b", 64, 2048, 1024);
        assert_eq!(shape_class(&small), shape_class(&large));
        assert_ne!(workload_fingerprint(&small), workload_fingerprint(&large));
        // Production and test shapes of a stock workload share a class.
        assert_eq!(
            shape_class(&WorkloadId::DeepSeekMoe.build()),
            shape_class(&WorkloadId::DeepSeekMoe.build_test())
        );
        assert_eq!(
            shape_class(&WorkloadId::FluxConv.build()),
            shape_class(&WorkloadId::FluxConv.build_test())
        );
    }

    #[test]
    fn shape_class_distinguishes_kernels() {
        // Different computations never share a class: matmul vs conv vs
        // attention differ in axis structure and block shape.
        let moe = shape_class(&WorkloadId::DeepSeekMoe.build());
        let conv = shape_class(&WorkloadId::FluxConv.build());
        let attn = shape_class(&WorkloadId::Llama3Attention.build());
        assert_ne!(moe, conv);
        assert_ne!(moe, attn);
        assert_ne!(conv, attn);
        // The two attention variants differ only in extents: same class.
        assert_eq!(
            attn,
            shape_class(&WorkloadId::FluxAttention.build()),
            "llama3/flux attention are the same kernel at different sizes"
        );
        // The two MoE-style MLPs likewise.
        assert_eq!(moe, shape_class(&WorkloadId::Llama4Mlp.build()));
    }

    #[test]
    fn shape_class_invariant_under_scheduling_and_names() {
        let base = Schedule::new(WorkloadId::Llama4Mlp.build());
        let tiled = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap();
        assert_eq!(shape_class(&base.current), shape_class(&tiled.current));
        let renamed = workload::moe_matmul("other_name", 16, 8192, 5120);
        assert_eq!(shape_class(&base.current), shape_class(&renamed));
    }

    #[test]
    fn incremental_fingerprint_matches_from_scratch_rehash() {
        // The memoized path (CoW apply chain, stage memos warm) must agree
        // with a cold full rehash (deep clone clears every memo).
        let base = Schedule::new(WorkloadId::Llama3Attention.build());
        let sched = base
            .apply(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
            .unwrap()
            .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
            .unwrap()
            .apply(Transform::CacheWrite { stage: 1 })
            .unwrap();
        let warm = program_fingerprint(&sched.current);
        let cold = program_fingerprint(&sched.current.deep_clone());
        assert_eq!(warm, cold);
    }
}
