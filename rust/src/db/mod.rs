//! Persistent tuning-record database + measurement cache.
//!
//! The paper's central claim is sample efficiency: every hardware
//! measurement is expensive, so accumulated performance feedback must never
//! be thrown away. This subsystem makes that feedback durable and reusable
//! across processes:
//!
//! - [`fingerprint`] — structural hashes over TIR: a *workload* fingerprint
//!   (schedule-invariant, name-invariant — the database key) and a
//!   *program* fingerprint (schedule-sensitive — the measurement-cache
//!   key).
//! - [`record`] — [`TuningRecord`]: one (trace, cost, provenance) data
//!   point, serialized as one JSONL line.
//! - [`database`] — [`Database`]: the append-only JSONL store with top-k
//!   lookup, stats, and [`Database::hints`], which turns records into a
//!   [`WarmStart`] + pre-populated [`MeasureCache`] for a search run.
//! - [`cache`] — [`MeasureCache`]: (program fingerprint, platform) →
//!   latency, consulted by `search::Evaluator` before consuming a sample
//!   (the evaluator owns the hit/miss accounting).
//!
//! The flow: `coordinator::tuner` opens the database per session, derives
//! hints, hands them to `search::{mcts, evolutionary}` (which seed their
//! frontier/population and skip re-measuring known programs), then commits
//! each run's best trace back. `coordinator::server` reads the same
//! database to annotate served models with their best-known schedules.

pub mod cache;
pub mod database;
pub mod fingerprint;
pub mod record;

pub use cache::MeasureCache;
pub use database::{Database, DbStats, GcInfo, GcReport, WarmStart};
pub use fingerprint::{program_fingerprint, shape_class, workload_fingerprint};
pub use record::TuningRecord;
