//! Thread-safe in-memory measurement cache.
//!
//! Keyed by `(program fingerprint, platform)`: if two candidates lower to
//! the same concrete program on the same platform, the hardware model owes
//! us nothing new — the search can reuse the previous measurement without
//! consuming a sample from its budget. The cache is consulted by
//! `crate::search::Evaluator::measure` and pre-populated from database
//! records when a session warm-starts, which is how a warm run reports
//! nonzero hits before its first hardware measurement.
//!
//! The store is sharded behind mutexes so concurrent tuners (parallel
//! batch evaluation, `rcc serve` tuning several models at once) can share
//! one cache: `get`/`insert` take `&self`. Two handle semantics exist and
//! the distinction is load-bearing for determinism:
//!
//! - [`MeasureCache::clone`] **deep-copies** the entries. Independent
//!   search runs (the repeats of one session) each clone the session
//!   hints, so one run's discoveries never leak into another and every
//!   run stays bit-reproducible per seed.
//! - [`MeasureCache::share`] returns a handle over the **same** storage.
//!   Use it when sharing is the point (threads of one evaluator batch, or
//!   deliberately pooled measurements across concurrent sessions).
//!
//! The cache is a pure store; hit/miss accounting lives in the single
//! budget-aware consumer (`Evaluator`), where "miss" can be defined as
//! "actually invoked the hardware model".

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Per-platform fingerprint → latency map; one per shard.
type Shard = HashMap<String, HashMap<u64, f64>>;

/// Number of lock shards: enough that a worker pool rarely contends, small
/// enough that `len`/deep-clone stay trivial.
const SHARDS: usize = 8;

/// Measurement store: (program fingerprint, platform) → latency.
///
/// Entries are nested per platform so the per-candidate hot path (one
/// lookup per `Evaluator::measure`) hashes a borrowed `&str` + `u64` and
/// never allocates; a platform key is only allocated once per shard, on
/// the first insert for that platform.
#[derive(Debug)]
pub struct MeasureCache {
    shards: Arc<[Mutex<Shard>; SHARDS]>,
}

impl Default for MeasureCache {
    fn default() -> Self {
        MeasureCache {
            shards: Arc::new(std::array::from_fn(|_| Mutex::new(Shard::new()))),
        }
    }
}

impl Clone for MeasureCache {
    /// Deep copy: the clone has its own storage. See the module docs for
    /// why (per-run determinism); use [`MeasureCache::share`] for a handle
    /// over the same storage.
    fn clone(&self) -> Self {
        let copy = MeasureCache::new();
        for (src, dst) in self.shards.iter().zip(copy.shards.iter()) {
            *dst.lock().unwrap() = src.lock().unwrap().clone();
        }
        copy
    }
}

impl MeasureCache {
    pub fn new() -> MeasureCache {
        MeasureCache::default()
    }

    /// A second handle over the same storage: inserts through either handle
    /// are visible to both. This is what concurrent tuners share.
    pub fn share(&self) -> MeasureCache {
        MeasureCache { shards: Arc::clone(&self.shards) }
    }

    #[inline]
    fn shard(&self, program_fp: u64) -> &Mutex<Shard> {
        &self.shards[(program_fp % SHARDS as u64) as usize]
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().map(|m| m.len()).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a known measurement.
    pub fn get(&self, program_fp: u64, platform: &str) -> Option<f64> {
        self.shard(program_fp)
            .lock()
            .unwrap()
            .get(platform)
            .and_then(|m| m.get(&program_fp))
            .copied()
    }

    /// Copy every entry of `self` into `dst`, keeping the lower latency on
    /// a key collision — so the merge result is independent of merge order
    /// (the property the `rcc serve --tune` measurement pool relies on
    /// when several sessions splice their database hints into one shared
    /// pool concurrently). A no-op when `dst` shares this cache's storage.
    pub fn merge_into(&self, dst: &MeasureCache) {
        if Arc::ptr_eq(&self.shards, &dst.shards) {
            return; // self-merge: nothing to do (and locking would deadlock)
        }
        for shard in self.shards.iter() {
            // Snapshot the source shard before touching `dst`: holding a
            // source lock across destination inserts would hand two
            // opposite-direction merges an ABBA deadlock.
            let entries: Vec<(String, Vec<(u64, f64)>)> = shard
                .lock()
                .unwrap()
                .iter()
                .map(|(platform, m)| {
                    (platform.clone(), m.iter().map(|(&fp, &lat)| (fp, lat)).collect())
                })
                .collect();
            for (platform, entries) in entries {
                for (fp, lat) in entries {
                    dst.insert_if_better(fp, &platform, lat);
                }
            }
        }
    }

    /// Insert unless an equal-or-lower-latency entry already exists — one
    /// atomic check-and-set under the shard lock, so concurrent merges can
    /// never interleave into keeping the worse of two measurements.
    pub fn insert_if_better(&self, program_fp: u64, platform: &str, latency: f64) {
        let mut shard = self.shard(program_fp).lock().unwrap();
        match shard.get_mut(platform) {
            Some(m) => {
                let slot = m.entry(program_fp).or_insert(f64::INFINITY);
                if latency < *slot {
                    *slot = latency;
                }
            }
            None => {
                let mut m = HashMap::new();
                m.insert(program_fp, latency);
                shard.insert(platform.to_string(), m);
            }
        }
    }

    /// Snapshot every entry as `(platform, fingerprint, latency)`, sorted.
    /// Used by the session journal to diff a shared cache before/after a
    /// repeat (checkpointing exactly the measurements that repeat added);
    /// the sort makes the snapshot independent of shard and hash order.
    pub fn entries(&self) -> Vec<(String, u64, f64)> {
        let mut out: Vec<(String, u64, f64)> = Vec::with_capacity(self.len());
        for shard in self.shards.iter() {
            let shard = shard.lock().unwrap();
            for (platform, m) in shard.iter() {
                for (&fp, &lat) in m.iter() {
                    out.push((platform.clone(), fp, lat));
                }
            }
        }
        out.sort_by(|a, b| (&a.0, a.1).cmp(&(&b.0, b.1)));
        out
    }

    /// Record a measurement. Last write wins (re-measurement under a
    /// different seed refreshes the entry).
    pub fn insert(&self, program_fp: u64, platform: &str, latency: f64) {
        let mut shard = self.shard(program_fp).lock().unwrap();
        match shard.get_mut(platform) {
            Some(m) => {
                m.insert(program_fp, latency);
            }
            None => {
                let mut m = HashMap::new();
                m.insert(program_fp, latency);
                shard.insert(platform.to_string(), m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_get_per_platform() {
        let c = MeasureCache::new();
        assert!(c.get(1, "core_i9").is_none());
        c.insert(1, "core_i9", 0.5);
        assert_eq!(c.get(1, "core_i9"), Some(0.5));
        // Same fingerprint on a different platform is a distinct key.
        assert!(c.get(1, "m2_pro").is_none());
        c.insert(1, "m2_pro", 0.7);
        assert_eq!(c.len(), 2);
        // Last write wins.
        c.insert(1, "core_i9", 0.4);
        assert_eq!(c.get(1, "core_i9"), Some(0.4));
        assert_eq!(c.len(), 2);
        assert!(!c.is_empty());
        assert!(MeasureCache::new().is_empty());
    }

    #[test]
    fn clone_is_deep_share_is_shallow() {
        let c = MeasureCache::new();
        c.insert(7, "core_i9", 1.0);
        let deep = c.clone();
        let shallow = c.share();
        c.insert(8, "core_i9", 2.0);
        assert_eq!(deep.len(), 1, "clone must not see later inserts");
        assert_eq!(shallow.len(), 2, "share must see later inserts");
        deep.insert(9, "core_i9", 3.0);
        assert!(c.get(9, "core_i9").is_none(), "clone writes stay private");
    }

    #[test]
    fn merge_into_keeps_the_better_measurement_either_direction() {
        let a = MeasureCache::new();
        let b = MeasureCache::new();
        a.insert(1, "core_i9", 2.0);
        a.insert(2, "core_i9", 5.0);
        b.insert(1, "core_i9", 3.0); // worse than a's
        b.insert(3, "m2_pro", 7.0);
        a.merge_into(&b);
        assert_eq!(b.get(1, "core_i9"), Some(2.0), "lower latency wins");
        assert_eq!(b.get(2, "core_i9"), Some(5.0));
        assert_eq!(b.get(3, "m2_pro"), Some(7.0));
        assert_eq!(b.len(), 3);
        // Merge-order independence: the reverse merge yields the same map.
        let c = MeasureCache::new();
        let d = MeasureCache::new();
        b.merge_into(&c);
        c.merge_into(&d);
        assert_eq!(d.get(1, "core_i9"), Some(2.0));
        assert_eq!(d.len(), 3);
        // Merging a cache into a shared handle of itself is a safe no-op.
        let alias = d.share();
        d.merge_into(&alias);
        assert_eq!(d.len(), 3);
        // insert_if_better never downgrades an entry.
        d.insert_if_better(1, "core_i9", 9.0);
        assert_eq!(d.get(1, "core_i9"), Some(2.0));
        d.insert_if_better(1, "core_i9", 1.0);
        assert_eq!(d.get(1, "core_i9"), Some(1.0));
    }

    #[test]
    fn entries_snapshot_is_sorted_and_complete() {
        let c = MeasureCache::new();
        c.insert(9, "m2_pro", 3.0);
        c.insert(1, "core_i9", 1.0);
        c.insert(5, "core_i9", 2.0);
        assert_eq!(
            c.entries(),
            vec![
                ("core_i9".to_string(), 1, 1.0),
                ("core_i9".to_string(), 5, 2.0),
                ("m2_pro".to_string(), 9, 3.0),
            ]
        );
        assert!(MeasureCache::new().entries().is_empty());
    }

    #[test]
    fn concurrent_inserts_and_gets() {
        let cache = MeasureCache::new();
        const THREADS: u64 = 8;
        const PER_THREAD: u64 = 200;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let handle = cache.share();
                scope.spawn(move || {
                    for i in 0..PER_THREAD {
                        let fp = t * PER_THREAD + i;
                        handle.insert(fp, "core_i9", fp as f64);
                        assert_eq!(handle.get(fp, "core_i9"), Some(fp as f64));
                    }
                });
            }
        });
        assert_eq!(cache.len(), (THREADS * PER_THREAD) as usize);
    }
}
