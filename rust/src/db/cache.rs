//! In-memory measurement cache.
//!
//! Keyed by `(program fingerprint, platform)`: if two candidates lower to
//! the same concrete program on the same platform, the hardware model owes
//! us nothing new — the search can reuse the previous measurement without
//! consuming a sample from its budget. The cache is consulted by
//! `crate::search::Evaluator::measure` and pre-populated from database
//! records when a session warm-starts, which is how a warm run reports
//! nonzero hits before its first hardware measurement.
//!
//! The cache is a pure store; hit/miss accounting lives in the single
//! budget-aware consumer (`Evaluator`), where "miss" can be defined as
//! "actually invoked the hardware model".

use std::collections::HashMap;

/// Measurement store: (program fingerprint, platform) → latency.
///
/// Entries are nested per platform so the per-candidate hot path (one
/// lookup per `Evaluator::measure`) hashes a borrowed `&str` + `u64` and
/// never allocates; a platform key is only allocated once, on the first
/// insert for that platform.
#[derive(Debug, Clone, Default)]
pub struct MeasureCache {
    entries: HashMap<String, HashMap<u64, f64>>,
}

impl MeasureCache {
    pub fn new() -> MeasureCache {
        MeasureCache::default()
    }

    pub fn len(&self) -> usize {
        self.entries.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.values().all(|m| m.is_empty())
    }

    /// Look up a known measurement.
    pub fn get(&self, program_fp: u64, platform: &str) -> Option<f64> {
        self.entries
            .get(platform)
            .and_then(|m| m.get(&program_fp))
            .copied()
    }

    /// Record a measurement. Last write wins (re-measurement under a
    /// different seed refreshes the entry).
    pub fn insert(&mut self, program_fp: u64, platform: &str, latency: f64) {
        match self.entries.get_mut(platform) {
            Some(m) => {
                m.insert(program_fp, latency);
            }
            None => {
                let mut m = HashMap::new();
                m.insert(program_fp, latency);
                self.entries.insert(platform.to_string(), m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_get_per_platform() {
        let mut c = MeasureCache::new();
        assert!(c.get(1, "core_i9").is_none());
        c.insert(1, "core_i9", 0.5);
        assert_eq!(c.get(1, "core_i9"), Some(0.5));
        // Same fingerprint on a different platform is a distinct key.
        assert!(c.get(1, "m2_pro").is_none());
        c.insert(1, "m2_pro", 0.7);
        assert_eq!(c.len(), 2);
        // Last write wins.
        c.insert(1, "core_i9", 0.4);
        assert_eq!(c.get(1, "core_i9"), Some(0.4));
        assert_eq!(c.len(), 2);
        assert!(!c.is_empty());
        assert!(MeasureCache::new().is_empty());
    }
}
