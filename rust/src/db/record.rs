//! Tuning records and their JSONL wire format.
//!
//! One [`TuningRecord`] is one proven data point: "this transformation
//! trace, applied to the workload with this structural fingerprint, costs
//! this much on this platform". Records carry full provenance (strategy,
//! seed, timestamp) so `rcc db top` can answer *where a schedule came from*,
//! and serialize one-per-line (JSONL) so the database file is append-only
//! and partially-written tails never corrupt earlier records.
//!
//! Transforms are stored structurally (`{"op": "TileSize", "stage": 0,
//! "loop": 2, "factor": 64}`), not as rendered prompt text — the format the
//! proposal parser accepts can drift; this codec cannot.

use crate::schedule::Transform;
use crate::util::json::{arr, num, s, Json};

/// One persisted measurement: a trace, its cost, and provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningRecord {
    /// Structural workload fingerprint (see `db::fingerprint`).
    pub workload_fp: u64,
    /// Human-readable workload name at record time (informational; lookups
    /// key on the fingerprint).
    pub workload: String,
    /// Platform descriptor name (`core_i9`, ...).
    pub platform: String,
    /// Search strategy that found the trace (`mcts[llm[...]]`, ...).
    pub strategy: String,
    /// The transformation trace, replayable on any program with a matching
    /// workload fingerprint.
    pub trace: Vec<Transform>,
    /// Measured latency (seconds) of the traced program on the platform's
    /// hardware model.
    pub latency: f64,
    /// Baseline (untransformed) latency measured in the same run.
    pub baseline_latency: f64,
    /// Search seed, for reproducing the run.
    pub seed: u64,
    /// Unix timestamp (seconds) when the record was created.
    pub timestamp: u64,
    /// Extent-abstracted structural fingerprint of the source workload
    /// (`db::fingerprint::shape_class`). Groups records of the same
    /// computation at different sizes for cross-workload transfer. `0` =
    /// unknown (records written before this field existed); such records
    /// never participate in transfer but stay valid everywhere else.
    pub shape_class: u64,
    /// Per-stage original-axis extents of the source workload at record
    /// time, in stage/axis order. The transfer subsystem's feature-distance
    /// metric compares these against the target's extents; empty = unknown.
    pub extents: Vec<Vec<i64>>,
}

impl TuningRecord {
    pub fn speedup(&self) -> f64 {
        self.baseline_latency / self.latency
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("workload_fp", s(&format!("{:016x}", self.workload_fp)))
            .set("workload", s(&self.workload))
            .set("platform", s(&self.platform))
            .set("strategy", s(&self.strategy))
            .set(
                "trace",
                arr(self.trace.iter().map(transform_to_json).collect()),
            )
            .set("latency", num(self.latency))
            .set("baseline_latency", num(self.baseline_latency))
            // Seeds are full u64s ("for reproducing the run"); JSON numbers
            // are f64 and lose integers above 2^53, so encode as a decimal
            // string like workload_fp. Timestamps fit f64 comfortably.
            .set("seed", s(&self.seed.to_string()))
            .set("timestamp", num(self.timestamp as f64))
            .set("shape_class", s(&format!("{:016x}", self.shape_class)))
            .set(
                "extents",
                arr(self
                    .extents
                    .iter()
                    .map(|stage| arr(stage.iter().map(|&e| num(e as f64)).collect()))
                    .collect()),
            );
        doc
    }

    /// One JSONL line (compact, no interior newlines).
    pub fn to_jsonl(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json(doc: &Json) -> Option<TuningRecord> {
        let get_s = |k: &str| doc.get(k).and_then(|v| v.as_str());
        let get_n = |k: &str| doc.get(k).and_then(|v| v.as_f64());
        let workload_fp = u64::from_str_radix(get_s("workload_fp")?, 16).ok()?;
        let trace = doc
            .get("trace")?
            .as_arr()?
            .iter()
            .map(transform_from_json)
            .collect::<Option<Vec<_>>>()?;
        // Transfer metadata is optional: records written by older versions
        // decode with the "unknown" sentinels and simply never participate
        // in cross-workload transfer.
        let shape_class = get_s("shape_class")
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .unwrap_or(0);
        let extents = doc
            .get("extents")
            .and_then(|v| v.as_arr())
            .map(|stages| {
                stages
                    .iter()
                    .map(|stage| {
                        stage
                            .as_arr()
                            .map(|axes| {
                                axes.iter()
                                    .filter_map(|e| e.as_f64())
                                    .map(|e| e as i64)
                                    .collect()
                            })
                            .unwrap_or_default()
                    })
                    .collect()
            })
            .unwrap_or_default();
        Some(TuningRecord {
            workload_fp,
            workload: get_s("workload")?.to_string(),
            platform: get_s("platform")?.to_string(),
            strategy: get_s("strategy")?.to_string(),
            trace,
            latency: get_n("latency")?,
            baseline_latency: get_n("baseline_latency")?,
            seed: get_s("seed")?.parse().ok()?,
            timestamp: get_n("timestamp")? as u64,
            shape_class,
            extents,
        })
    }

    pub fn from_jsonl(line: &str) -> Option<TuningRecord> {
        Self::from_json(&Json::parse(line.trim())?)
    }
}

/// Structural JSON encoding of one transform.
pub fn transform_to_json(t: &Transform) -> Json {
    let mut o = Json::obj();
    o.set("op", s(t.op_name()));
    match t {
        Transform::TileSize { stage, loop_idx, factor } => {
            o.set("stage", num(*stage as f64))
                .set("loop", num(*loop_idx as f64))
                .set("factor", num(*factor as f64));
        }
        Transform::Reorder { stage, perm } => {
            o.set("stage", num(*stage as f64)).set(
                "perm",
                arr(perm.iter().map(|&i| num(i as f64)).collect()),
            );
        }
        Transform::Fuse { stage, loop_idx }
        | Transform::Parallel { stage, loop_idx }
        | Transform::Vectorize { stage, loop_idx }
        | Transform::Unroll { stage, loop_idx } => {
            o.set("stage", num(*stage as f64))
                .set("loop", num(*loop_idx as f64));
        }
        Transform::ComputeLocation { stage, depth } => {
            o.set("stage", num(*stage as f64))
                .set("depth", num(*depth as f64));
        }
        Transform::CacheWrite { stage } => {
            o.set("stage", num(*stage as f64));
        }
    }
    o
}

/// Decode one transform; `None` on unknown ops or missing fields.
pub fn transform_from_json(j: &Json) -> Option<Transform> {
    let op = j.get("op")?.as_str()?;
    let get_u = |k: &str| j.get(k).and_then(|v| v.as_f64()).map(|x| x as usize);
    let stage = get_u("stage")?;
    Some(match op {
        "TileSize" => Transform::TileSize {
            stage,
            loop_idx: get_u("loop")?,
            factor: j.get("factor")?.as_f64()? as i64,
        },
        "Reorder" => Transform::Reorder {
            stage,
            perm: j
                .get("perm")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|x| x as usize))
                .collect::<Option<Vec<_>>>()?,
        },
        "Fuse" => Transform::Fuse { stage, loop_idx: get_u("loop")? },
        "Parallel" => Transform::Parallel { stage, loop_idx: get_u("loop")? },
        "Vectorize" => Transform::Vectorize { stage, loop_idx: get_u("loop")? },
        "Unroll" => Transform::Unroll { stage, loop_idx: get_u("loop")? },
        "ComputeLocation" => Transform::ComputeLocation { stage, depth: get_u("depth")? },
        "CacheWrite" => Transform::CacheWrite { stage },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_transform_shapes() -> Vec<Transform> {
        vec![
            Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 },
            Transform::Reorder { stage: 1, perm: vec![2, 0, 1] },
            Transform::Fuse { stage: 0, loop_idx: 1 },
            Transform::Parallel { stage: 0, loop_idx: 0 },
            Transform::Vectorize { stage: 2, loop_idx: 3 },
            Transform::Unroll { stage: 0, loop_idx: 4 },
            Transform::ComputeLocation { stage: 0, depth: 2 },
            Transform::CacheWrite { stage: 1 },
        ]
    }

    #[test]
    fn transform_codec_roundtrips_every_op() {
        for t in all_transform_shapes() {
            let j = transform_to_json(&t);
            let back = transform_from_json(&j).unwrap_or_else(|| panic!("decode {t:?}"));
            assert_eq!(t, back);
        }
    }

    #[test]
    fn record_jsonl_roundtrip() {
        let rec = TuningRecord {
            workload_fp: 0xDEAD_BEEF_0123_4567,
            workload: "deepseek_moe".to_string(),
            platform: "core_i9".to_string(),
            strategy: "mcts[random]".to_string(),
            trace: all_transform_shapes(),
            latency: 1.25e-3,
            baseline_latency: 7.5e-3,
            seed: 42,
            timestamp: 1_753_000_000,
            shape_class: 0xA5A5_5A5A_DEAD_F00D,
            extents: vec![vec![16, 2048, 7168]],
        };
        let line = rec.to_jsonl();
        assert!(!line.contains('\n'), "JSONL lines must be single-line");
        let back = TuningRecord::from_jsonl(&line).unwrap();
        assert_eq!(rec, back);
        assert!((back.speedup() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn large_fingerprints_survive_serialization() {
        // u64 fingerprints exceed f64's 53-bit integer range; the hex-string
        // encoding must preserve every bit.
        let rec = TuningRecord {
            workload_fp: u64::MAX - 1,
            workload: "w".to_string(),
            platform: "p".to_string(),
            strategy: "s".to_string(),
            trace: vec![],
            latency: 1.0,
            baseline_latency: 2.0,
            seed: u64::MAX,
            timestamp: 0,
            shape_class: u64::MAX - 3,
            extents: vec![],
        };
        let back = TuningRecord::from_jsonl(&rec.to_jsonl()).unwrap();
        assert_eq!(back.workload_fp, u64::MAX - 1);
        assert_eq!(back.seed, u64::MAX, "seed must survive beyond 2^53");
        assert_eq!(
            back.shape_class,
            u64::MAX - 3,
            "shape class is hex-encoded like the workload fingerprint"
        );
    }

    #[test]
    fn records_without_transfer_metadata_still_decode() {
        // A pre-transfer record (no shape_class/extents fields) must decode
        // with the unknown sentinels — version drift is never fatal.
        let line = r#"{"workload_fp":"00000000000000ff","workload":"w","platform":"p","strategy":"s","trace":[],"latency":1.0,"baseline_latency":2.0,"seed":"7","timestamp":9}"#;
        let rec = TuningRecord::from_jsonl(line).expect("old-format line decodes");
        assert_eq!(rec.workload_fp, 0xff);
        assert_eq!(rec.shape_class, 0, "missing shape class = unknown sentinel");
        assert!(rec.extents.is_empty(), "missing extents = unknown");
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(TuningRecord::from_jsonl("{not json").is_none());
        assert!(TuningRecord::from_jsonl("{}").is_none());
        assert!(TuningRecord::from_jsonl(
            r#"{"workload_fp":"zz","workload":"w","platform":"p","strategy":"s","trace":[],"latency":1,"baseline_latency":1,"seed":0,"timestamp":0}"#
        )
        .is_none());
        assert!(transform_from_json(&Json::parse(r#"{"op":"Nope","stage":0}"#).unwrap()).is_none());
    }
}
